//! End-to-end validation driver (the DESIGN.md "headline" run): the full
//! SGG pipeline on the IEEE-Fraud stand-in workload, exercising all three
//! layers:
//!
//! 1. L3 fits structure (Kronecker MLE + degree fit), features, aligner;
//! 2. if `make artifacts` has been run, the feature generator is the
//!    CTGAN-style GAN whose fused ResNet blocks are the L1 Pallas kernel,
//!    trained via the L2 AOT train-step HLO on the PJRT runtime — the
//!    GAN loss curve is printed to prove real training happened;
//! 3. generation + alignment produce a synthetic dataset that is scored
//!    with the paper's Table-2 metrics against the original, plus the
//!    baseline comparison (random / graphworld) so the paper's headline
//!    ordering is reproduced in one run.
//!
//! Run: `make artifacts && cargo run --release --example fraud_pipeline`
//! The output is recorded in EXPERIMENTS.md §End-to-end.

use sgg::metrics;
use sgg::pipeline::{Pipeline, PipelineBuilder};

fn main() -> sgg::Result<()> {
    let ds = sgg::datasets::load("ieee-fraud", 42)?;
    println!("workload: {}", ds.summary());
    let have_artifacts = sgg::runtime::artifacts_available();
    println!("artifacts available: {have_artifacts} (GAN backend: {})",
             if have_artifacts { "PJRT/Pallas" } else { "resample fallback" });

    let arms: Vec<(&str, PipelineBuilder)> = vec![
        (
            "random",
            Pipeline::builder()
                .structure("erdos-renyi")
                .edge_features("random")
                .aligner("random"),
        ),
        (
            "graphworld",
            Pipeline::builder()
                .structure("graphworld") // alias for "sbm"
                .edge_features("gaussian")
                .aligner("random"),
        ),
        ("ours", Pipeline::builder()),
    ];

    let mut ours_beats_baselines = true;
    let mut scores = Vec::new();
    for (name, builder) in arms {
        let t0 = std::time::Instant::now();
        let fitted = builder.fit(&ds)?;
        let synth = fitted.generate(1, 7)?;
        let r = metrics::evaluate(&ds.edges, &ds.edge_features, &synth.edges, &synth.edge_features);
        println!(
            "{name:<12} degree_dist={:.4}  feature_corr={:.4}  degree_feat_dist={:.4}   ({:.1}s)",
            r.degree_dist,
            r.feature_corr,
            r.degree_feat_dist,
            t0.elapsed().as_secs_f64()
        );
        scores.push((name, r));
    }
    let ours = scores.last().unwrap().1;
    for (name, r) in &scores[..scores.len() - 1] {
        if ours.degree_dist < r.degree_dist || ours.degree_feat_dist > r.degree_feat_dist {
            ours_beats_baselines = false;
            println!("NOTE: ours does not dominate {name} on every metric in this run");
        }
    }

    // GAN demonstration leg: the L1/L2 compute path (Pallas ResNet blocks
    // inside the AOT train-step HLO, driven step-by-step from Rust)
    if have_artifacts {
        let t0 = std::time::Instant::now();
        let fitted = Pipeline::builder()
            .structure("kronecker")
            .edge_features("gan")
            .aligner("learned")
            .fit(&ds)?;
        let synth = fitted.generate(1, 7)?;
        let r = metrics::evaluate(&ds.edges, &ds.edge_features, &synth.edges, &synth.edge_features);
        println!(
            "ours (GAN)   degree_dist={:.4}  feature_corr={:.4}  degree_feat_dist={:.4}   ({:.1}s, PJRT train+sample)",
            r.degree_dist, r.feature_corr, r.degree_feat_dist,
            t0.elapsed().as_secs_f64()
        );
    }

    // scale-up leg: 2x nodes / 4x edges through the streaming path
    let fitted = Pipeline::builder().fit(&ds)?;
    let t0 = std::time::Instant::now();
    let big = fitted.generate(2, 9)?;
    println!(
        "scale 2x: {} edges in {:.1}s ({:.2} Medges/s incl. alignment)",
        big.edges.len(),
        t0.elapsed().as_secs_f64(),
        big.edges.len() as f64 / t0.elapsed().as_secs_f64() / 1e6
    );

    println!(
        "\nE2E RESULT: {}",
        if ours_beats_baselines {
            "PASS — fitted pipeline reproduces the paper's Table-2 ordering"
        } else {
            "PARTIAL — see per-metric rows above"
        }
    );
    Ok(())
}
