//! Quickstart: fit the framework on a stand-in dataset, generate a
//! same-size synthetic graph, and print the paper's three quality
//! metrics.
//!
//! Run: `cargo run --release --example quickstart`

use sgg::metrics;
use sgg::pipeline::Pipeline;

fn main() -> sgg::Result<()> {
    // 1. load a dataset (seeded stand-in for the paper's IEEE-Fraud set)
    let ds = sgg::datasets::load("ieee-fraud", 42)?;
    println!("input: {}", ds.summary());

    // 2. fit the three components (structure / features / aligner) by
    //    registry name — swap any backend by changing a string
    let fitted = Pipeline::builder()
        .structure("kronecker")
        .edge_features("kde")
        .aligner("learned")
        .fit(&ds)?;
    let (s, f, a) = fitted.component_names();
    println!("fitted components: structure={s} features={f} aligner={a}");

    // 3. generate a synthetic dataset of the same size...
    let synth = fitted.generate(1, 7)?;
    println!(
        "synthetic: {} edges, node features: {}",
        synth.edges.len(),
        synth.node_features.is_some()
    );

    // 4. ...and evaluate it with the paper's Table-2 metrics
    let report = metrics::evaluate(
        &ds.edges,
        &ds.edge_features,
        &synth.edges,
        &synth.edge_features,
    );
    println!("quality: {report}");

    // 5. scaling: double the nodes, quadruple the edges (density kept)
    let big = fitted.generate(2, 8)?;
    println!(
        "scaled 2x: {} nodes, {} edges",
        big.edges.n_nodes(),
        big.edges.len()
    );
    Ok(())
}
