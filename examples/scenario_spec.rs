//! Declarative-scenario demo: run the checked-in `scenarios/fraud.toml`
//! spec end to end (dataset → registry-resolved components → fit →
//! generate → sink), then run the same scenario with a shard-stream sink
//! to show both output paths behind the one `Sink` trait.
//!
//! Run: `cargo run --release --example scenario_spec`

use sgg::pipeline::{run_scenario, ScenarioSpec, SinkOutput, SinkSpec};
use sgg::structgen::chunked::ChunkConfig;

fn main() -> sgg::Result<()> {
    let path = std::path::Path::new("scenarios/fraud.toml");
    let spec = ScenarioSpec::from_file(path)?;
    println!(
        "scenario `{}`: dataset={} structure={} edge_features={} aligner={}",
        spec.name, spec.dataset, spec.structure.name, spec.edge_features.name, spec.aligner.name
    );

    // 1. in-memory: assembles a full Dataset (edge + node features)
    let out = run_scenario(&spec)?;
    println!("memory sink → {}", out.summary());
    let ds = out.into_dataset()?;
    assert!(ds.node_features.is_some(), "fraud spec generates node features");

    // 2. same scenario, streamed: only the sink stanza changes
    let mut streamed = spec.clone();
    streamed.sink = SinkSpec::Shards {
        dir: std::env::temp_dir().join("sgg_scenario_demo"),
        chunks: ChunkConfig::default(),
    };
    match run_scenario(&streamed)? {
        SinkOutput::Streamed(report) => println!("shard sink  → {report}"),
        SinkOutput::Dataset(_) => unreachable!("shard sink reports, never collects"),
    }
    Ok(())
}
