//! Scale-up demo (paper §4.5 / Table 3): fit on the MAG-mini stand-in and
//! stream progressively larger synthetic graphs to disk shards with the
//! chunked, backpressured generator — the per-scale time/memory rows of
//! Table 3 at CPU-class sizes.
//!
//! Run: `cargo run --release --example scale_up [-- --max-scale 4]`

use sgg::pipeline::orchestrator::stream_to_shards;
use sgg::structgen::chunked::ChunkConfig;
use sgg::structgen::fit::fit_kronecker;
use sgg::util::args::Args;

fn main() -> sgg::Result<()> {
    let args = Args::from_env();
    let max_scale: u64 = args.get_or("max-scale", 4);
    let base = sgg::datasets::load("mag-mini", 1)?;
    println!("base: {}", base.summary());
    let gen = fit_kronecker(&base.edges);
    println!(
        "fitted theta: a={:.3} b={:.3} c={:.3} d={:.3}",
        gen.theta.a, gen.theta.b, gen.theta.c, gen.theta.d
    );
    let out_root = std::env::temp_dir().join("sgg_scale_up");
    let mut scale = 1u64;
    while scale <= max_scale {
        let n_src = base.edges.spec.n_src * scale;
        let n_dst = base.edges.spec.n_dst * scale;
        let edges = base.edges.len() as u64 * scale * scale;
        let dir = out_root.join(format!("scale-{scale}"));
        let report = stream_to_shards(
            &gen,
            n_src,
            n_dst,
            edges,
            7,
            ChunkConfig::default(),
            &dir,
        )?;
        println!("scale {scale}x: {report}");
        std::fs::remove_dir_all(&dir).ok();
        scale *= 2;
    }
    Ok(())
}
