//! In-tree stub of the `xla` (PJRT) crate surface the runtime uses.
//!
//! The offline build environment has no crates.io access, so instead of a
//! `Cargo.toml` dependency the crate ships this API-compatible shim:
//! [`Literal`] is a real in-memory tensor container (everything the
//! literal-packing helpers and their tests need), while the client /
//! executable types compile and load fine but report a clear error the
//! moment an HLO execution is attempted. All PJRT call sites are already
//! gated on [`crate::runtime::artifacts_available`], so the stub only
//! surfaces when someone ships artifacts without the real backend.

use std::path::Path;

/// Error type mirroring `xla::Error` (stringly, like the real binding).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT backend unavailable (sgg was built with the in-tree xla stub; \
         link the real `xla` crate to execute HLO artifacts)"
    )))
}

/// Element storage for [`Literal`].
#[derive(Debug, Clone)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Scalar types a [`Literal`] can hold. Sealed to the two element types
/// the runtime actually moves across the boundary.
pub trait Element: Copy + Sized {
    /// Move a typed vector into untyped storage.
    fn wrap(data: Vec<Self>) -> Storage;
    /// Copy the typed vector back out (`None` on element-type mismatch).
    fn unwrap(storage: &Storage) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(data: Vec<Self>) -> Storage {
        Storage::F32(data)
    }
    fn unwrap(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            Storage::I32(_) => None,
        }
    }
}

impl Element for i32 {
    fn wrap(data: Vec<Self>) -> Storage {
        Storage::I32(data)
    }
    fn unwrap(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            Storage::F32(_) => None,
        }
    }
}

/// An in-memory tensor literal (flat data + dims), API-compatible with
/// the subset of `xla::Literal` used by [`crate::runtime::literal`].
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { storage: Storage::F32(vec![x]), dims: Vec::new() }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { storage: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims` (element count must match; empty dims = rank-0
    /// scalar, one element).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let numel: i64 = dims.iter().product();
        let have = match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        };
        if numel < 0 || numel as usize != have {
            return Err(Error(format!("reshape: {have} elements into dims {dims:?}")));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy the flat data out as `Vec<T>`.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.storage).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come out of executions), so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    /// Dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: retains nothing but proves the file exists).
pub struct HloModuleProto;

impl HloModuleProto {
    /// "Parse" an HLO text file. Only existence/readability is checked —
    /// compilation fails later with a clear message.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        std::fs::read_to_string(path.as_ref())
            .map(|_| HloModuleProto)
            .map_err(|e| Error(format!("{}: {e}", path.as_ref().display())))
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap an HLO proto (stub: the proto is not retained).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: constructs, never executes).
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Construction succeeds so artifact-free code paths
    /// (manifest/constants loading) keep working.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    /// Compilation is where the stub stops.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub: cannot be constructed, so `execute`
/// is unreachable in practice).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Unreachable in the stub: no executable can be constructed.
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Unreachable in the stub: no buffer can be constructed.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_type_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn reshape_rejects_bad_numel() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(l.reshape(&[2, 2]).is_err());
        // zero-element mismatches are rejected too
        let empty = Literal::vec1::<f32>(&[]);
        assert!(empty.reshape(&[1]).is_err());
        assert!(empty.reshape(&[0]).is_ok());
        assert!(Literal::scalar(1.0).reshape(&[0]).is_err());
    }

    #[test]
    fn execution_paths_report_stub() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation).err().unwrap();
        assert!(err.to_string().contains("stub"));
    }
}
