//! # SGG — Synthetic Graph Dataset Generation at Scale
//!
//! A Rust + JAX + Pallas reproduction of *"A Framework for Large Scale
//! Synthetic Graph Dataset Generation"* (Darabi et al., 2022).
//!
//! The framework decomposes graph dataset generation into three fitted,
//! swappable components (paper §3):
//!
//! 1. **Structure generation** ([`structgen`]) — a generalized stochastic
//!    Kronecker model over possibly non-square adjacency matrices
//!    (eq. 1–5), fitted to the input graph's in/out degree distributions
//!    (eq. 6–8), with optional per-level noise (paper §9) and a chunked,
//!    shared-nothing parallel sampler for graphs larger than memory
//!    (paper §10).
//! 2. **Feature generation** ([`featgen`]) — tabular generators over node
//!    and edge feature matrices: a CTGAN-style GAN (JAX/Pallas, AOT-compiled
//!    and driven from Rust via PJRT), kernel density estimation, per-column
//!    random, and multivariate Gaussian models, all sharing a
//!    mode-specific-normalization encoder.
//! 3. **Alignment** ([`aligner`]) — gradient-boosted trees over graph
//!    structural features (degree, PageRank, Katz, clustering, node2vec)
//!    that rank generated feature rows onto generated structure
//!    (eq. 15–19).
//!
//! ## The fit → artifact → generate lifecycle
//!
//! Components are wired together through a **string-keyed registry** and a
//! declarative **[`pipeline::ScenarioSpec`]** rather than closed enums, so
//! new backends plug in without touching the pipeline. Three entry points,
//! from most to least declarative:
//!
//! * **Spec file** — `sgg run scenario.toml` parses a minimal TOML-subset
//!   scenario (dataset *or* a fitted `model` artifact, per-component
//!   backends + params, scale or explicit sizes, seed, and a sink) and
//!   executes it end to end.
//! * **Builder** — [`pipeline::Pipeline::builder`] gives the same knobs
//!   programmatically:
//!
//!   ```no_run
//!   use sgg::pipeline::Pipeline;
//!   # fn main() -> sgg::Result<()> {
//!   let ds = sgg::datasets::load("ieee-fraud", 1)?;
//!   let fitted = Pipeline::builder()
//!       .structure("kronecker")
//!       .edge_features("kde")
//!       .aligner("learned")
//!       .fit(&ds)?;
//!   let synth = fitted.generate(2, 7)?;
//!   # let _ = synth;
//!   # Ok(())
//!   # }
//!   ```
//!
//! * **Model artifacts** — a fitted pipeline serializes to a versioned
//!   `.sggm` document ([`pipeline::artifact`]): every component
//!   implements the **ModelState** capability (`save_state` + a
//!   registry-registered state loader), so the *models* — not the
//!   possibly proprietary data — are the shareable unit (the paper's
//!   release premise). `sgg fit` writes the artifact, `sgg generate
//!   --model` samples from it anywhere, bit-identical to the
//!   fit-and-generate path for the same seed and any worker count:
//!
//!   ```no_run
//!   use sgg::pipeline::{FittedPipeline, Pipeline, Registries};
//!   # fn main() -> sgg::Result<()> {
//!   let ds = sgg::datasets::load("ieee-fraud", 1)?;
//!   Pipeline::builder().fit(&ds)?.save(std::path::Path::new("fraud.sggm"))?;
//!   // ... on any other machine, without the dataset:
//!   let p = FittedPipeline::load(std::path::Path::new("fraud.sggm"), &Registries::builtin())?;
//!   let synth = p.generate(2, 7)?;
//!   # let _ = synth;
//!   # Ok(())
//!   # }
//!   ```
//!
//! Datasets with node features get a second feature-generation + alignment
//! leg automatically; output goes to an in-memory [`datasets::Dataset`] or
//! streams to disk shards through the unified [`pipeline::Sink`] trait.
//!
//! ## Parallel generation
//!
//! Structure generation is chunked and runs on the
//! [`pipeline::parallel::ParallelChunkRunner`]: a worker pool samples
//! chunks concurrently (each chunk on its own deterministic PRNG stream),
//! a bounded channel applies backpressure, and a writer feeds the sink in
//! chunk-index order — so output is **bit-identical for any worker
//! count**. Pick the worker count with `workers = N` in a scenario spec,
//! `--workers N` on the CLI, or `ChunkConfig::workers` programmatically.
//! See `docs/ARCHITECTURE.md` for the full dataflow.
//!
//! [`metrics`] implements every evaluation metric in the paper (§4.3 +
//! appendix), and [`experiments`] regenerates every table and figure.

// Docs are part of the public API contract: every public item must carry
// rustdoc, and regressions surface as build warnings (CI runs `cargo doc`
// with warnings denied).
#![warn(missing_docs)]

pub mod error;
pub mod util;
pub mod xla;
pub mod graph;
pub mod structgen;
pub mod featgen;
pub mod aligner;
pub mod metrics;
pub mod datasets;
pub mod pipeline;
pub mod harness;
pub mod serve;
pub mod runtime;
pub mod gnn;
pub mod experiments;

pub use error::{Error, Result};
