//! # SGG — Synthetic Graph Dataset Generation at Scale
//!
//! A Rust + JAX + Pallas reproduction of *"A Framework for Large Scale
//! Synthetic Graph Dataset Generation"* (Darabi et al., 2022).
//!
//! The framework decomposes graph dataset generation into three fitted,
//! swappable components (paper §3):
//!
//! 1. **Structure generation** ([`structgen`]) — a generalized stochastic
//!    Kronecker model over possibly non-square adjacency matrices
//!    (eq. 1–5), fitted to the input graph's in/out degree distributions
//!    (eq. 6–8), with optional per-level noise (paper §9) and a chunked,
//!    shared-nothing parallel sampler for graphs larger than memory
//!    (paper §10).
//! 2. **Feature generation** ([`featgen`]) — tabular generators over node
//!    and edge feature matrices: a CTGAN-style GAN (JAX/Pallas, AOT-compiled
//!    and driven from Rust via PJRT), kernel density estimation, per-column
//!    random, and multivariate Gaussian models, all sharing a
//!    mode-specific-normalization encoder.
//! 3. **Alignment** ([`aligner`]) — gradient-boosted trees over graph
//!    structural features (degree, PageRank, Katz, clustering, node2vec)
//!    that rank generated feature rows onto generated structure
//!    (eq. 15–19).
//!
//! [`pipeline`] wires the three together into a streaming fit → generate →
//! align → emit pipeline; [`metrics`] implements every evaluation metric in
//! the paper (§4.3 + appendix), and [`experiments`] regenerates every table
//! and figure.

pub mod error;
pub mod util;
pub mod graph;
pub mod structgen;
pub mod featgen;
pub mod aligner;
pub mod metrics;
pub mod datasets;
pub mod pipeline;
pub mod runtime;
pub mod gnn;
pub mod experiments;

pub use error::{Error, Result};
