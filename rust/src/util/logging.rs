//! Minimal leveled logger writing to stderr, controlled by `SGG_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let parsed = match std::env::var("SGG_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if messages at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Emit a message (used by the macros; prefer `info!` etc.).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

/// Log at info level.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
/// Log at warn level.
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
/// Log at debug level.
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
