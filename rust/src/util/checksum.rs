//! Shared FNV-1a checksum helper.
//!
//! One streaming 64-bit FNV-1a hasher used everywhere the crate needs a
//! cheap content fingerprint: the harness's degree-profile hash, the
//! distributed-run manifest's model hash, and per-shard checksums. FNV
//! is not cryptographic — it detects corruption and accidental drift,
//! which is all the conformance and merge validation paths need.

use crate::Result;
use std::io::Read;
use std::path::Path;

/// Streaming 64-bit FNV-1a hasher.
///
/// Feed bytes with [`Fnv1a::write`] (or integers with
/// [`Fnv1a::write_u64`], eaten as little-endian bytes) and read the
/// digest with [`Fnv1a::finish`]. Hashing the same bytes in any chunking
/// yields the same digest.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a {
    state: u64,
}

impl Fnv1a {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    /// Hasher at the FNV-1a offset basis.
    pub fn new() -> Fnv1a {
        Fnv1a { state: Fnv1a::OFFSET }
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(Fnv1a::PRIME);
        }
    }

    /// Absorb one integer as its 8 little-endian bytes.
    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    /// Current digest.
    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// FNV-1a digest of a byte slice.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

/// FNV-1a digest of a file's contents, read in buffered 1 MiB chunks so
/// arbitrarily large shards hash in constant memory.
pub fn fnv1a_file(path: &Path) -> Result<u64> {
    let mut f = std::fs::File::open(path)?;
    let mut h = Fnv1a::new();
    let mut buf = vec![0u8; 1 << 20];
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        h.write(&buf[..n]);
    }
    Ok(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // classic FNV-1a test vectors
        assert_eq!(fnv1a_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_bytes(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn chunking_is_irrelevant() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a_bytes(b"foobar"));
    }

    #[test]
    fn write_u64_is_le_bytes() {
        let mut a = Fnv1a::new();
        a.write_u64(0x0102_0304_0506_0708);
        let mut b = Fnv1a::new();
        b.write(&[8, 7, 6, 5, 4, 3, 2, 1]);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn file_hash_matches_bytes() {
        let p = std::env::temp_dir().join(format!("sgg_fnv_{}", std::process::id()));
        std::fs::write(&p, b"shard bytes here").unwrap();
        assert_eq!(fnv1a_file(&p).unwrap(), fnv1a_bytes(b"shard bytes here"));
        std::fs::remove_file(&p).ok();
    }
}
