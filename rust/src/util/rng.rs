//! Deterministic, seedable PRNG and sampling distributions.
//!
//! The offline crate registry has no `rand`, so this module provides the
//! generator used throughout the framework: PCG64 (permuted congruential
//! generator, O'Neill 2014) plus the distributions the paper's components
//! need — uniforms, Box–Muller Gaussians, categorical sampling via Walker
//! alias tables, and shuffles. Every generator in SGG is seeded explicitly
//! so all experiments are reproducible bit-for-bit.

/// PCG-XSL-RR 128/64 pseudo random generator.
///
/// 128-bit LCG state with a 64-bit xorshift-rotate output permutation.
/// Passes BigCrush; period 2^128 per stream.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams in practice (seed is mixed through two rounds first).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id. Generators with the
    /// same seed but different streams are independent — used by the
    /// chunked generator to give each chunk its own stream.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Fill `buf` with exactly `n` raw outputs (cleared first, capacity
    /// reused). The values equal `n` successive [`Pcg64::next_u64`]
    /// calls — the batch prefetch primitive of the hot sampling loops.
    pub fn fill_u64(&mut self, buf: &mut Vec<u64>, n: usize) {
        buf.clear();
        buf.reserve(n);
        for _ in 0..n {
            buf.push(self.next_u64());
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        RandomSource::f64(self)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        RandomSource::below(self, n)
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not normal-bound anywhere in SGG).
    pub fn normal(&mut self) -> f64 {
        RandomSource::normal(self)
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        RandomSource::normal_ms(self, mean, std)
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Zipf-like heavy-tailed integer in [0, n): P(k) ∝ (k+1)^-alpha.
    /// Uses inverse-CDF on a precomputable tail; for one-off draws this
    /// rejection-free approximation is adequate for dataset synthesis.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // inverse transform on the continuous Pareto then clamp
        let u = self.f64().max(1e-12);
        let x = u.powf(-1.0 / (alpha - 1.0).max(1e-9)) - 1.0;
        (x as usize).min(n - 1)
    }

    /// Poisson (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        RandomSource::poisson(self, lambda)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below_usize(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Draw from a discrete distribution given (unnormalized) weights.
    /// O(n) per draw — build an [`AliasTable`] for repeated draws.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

impl RandomSource for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        Pcg64::next_u64(self)
    }
}

/// A deterministic uniform `u64` stream plus the canonical distribution
/// algorithms built on it.
///
/// This is the seam that lets the block-buffered [`BlockRng`] stand in
/// for a bare [`Pcg64`] on sampling hot paths: PCG output depends only
/// on the call count, so any source that serves the same raw outputs in
/// the same order is interchangeable **bit-for-bit**. The provided
/// methods are the single authoritative implementation of each
/// distribution — `Pcg64`'s inherent methods delegate here, so a
/// batched path and a scalar path can never drift apart.
pub trait RandomSource {
    /// Next raw 64-bit output.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in [0, 1) — top 53 bits of one raw output.
    #[inline]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (second value dropped).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Poisson (Knuth for small lambda, normal approx for large).
    fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }
}

/// Raw outputs prefetched per [`BlockRng`] refill (8 KiB buffer).
pub const RNG_BLOCK: usize = 1024;

/// Block-buffered PCG64: prefetches [`RNG_BLOCK`] raw outputs at a time
/// into a reused buffer and serves them in order.
///
/// The served stream is bit-identical to calling [`Pcg64::next_u64`]
/// directly (PCG output depends only on the call count), but hot
/// sampling loops pay one predictable refill branch per draw instead of
/// the serial 128-bit LCG multiply + rotate dependency chain, and the
/// refill loop itself is trivially pipelined by the compiler. Used by
/// generators whose per-edge draw count is data-dependent (TrillionG's
/// Poisson degrees, alias-table rejection) where a fixed-stride draw
/// buffer can't be sized up front.
///
/// The wrapper may leave the inner generator *ahead* of the served
/// position (a refill draws a full block eagerly), so callers must not
/// interleave draws from the inner generator afterwards.
#[derive(Clone, Debug)]
pub struct BlockRng {
    inner: Pcg64,
    buf: Vec<u64>,
    pos: usize,
}

impl BlockRng {
    /// Wrap a generator; no draws happen until the first request.
    pub fn new(inner: Pcg64) -> BlockRng {
        BlockRng { inner, buf: Vec::with_capacity(RNG_BLOCK), pos: 0 }
    }

    /// Next raw output — identical to what the wrapped generator's
    /// `next_u64` would have returned at the same call index.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.pos == self.buf.len() {
            self.inner.fill_u64(&mut self.buf, RNG_BLOCK);
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }
}

impl RandomSource for BlockRng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        BlockRng::next_u64(self)
    }
}

/// Walker alias table: O(1) categorical sampling after O(n) build.
///
/// Used on hot paths that repeatedly draw from a fixed discrete
/// distribution (degree-corrected SBM block picks, feature mode picks).
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights. Empty or all-zero
    /// weights yield a uniform table.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len().max(1);
        let total: f64 = weights.iter().sum();
        let uniform = total <= 0.0 || weights.is_empty();
        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0u32; n];
        let mut scaled: Vec<f64> = if uniform {
            vec![1.0; n]
        } else {
            weights.iter().map(|w| w * n as f64 / total).collect()
        };
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, s) in scaled.iter().enumerate() {
            if *s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let (s, l) = (small.pop().unwrap(), large.pop().unwrap());
            prob[s] = scaled[s];
            alias[s] = l as u32;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        for i in large.into_iter().chain(small) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Decompose into the internal `(prob, alias)` arrays for exact
    /// artifact serialization; rebuild with [`AliasTable::from_parts`].
    pub fn to_parts(&self) -> (&[f64], &[u32]) {
        (&self.prob, &self.alias)
    }

    /// Rebuild a table from arrays captured by [`AliasTable::to_parts`].
    /// The arrays must be the same length (panics otherwise) — this is a
    /// bit-exact inverse, not a re-derivation from weights.
    pub fn from_parts(prob: Vec<f64>, alias: Vec<u32>) -> AliasTable {
        assert_eq!(prob.len(), alias.len(), "alias table parts length mismatch");
        AliasTable { prob, alias }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if the table has no categories.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw a category index.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        self.sample_with(rng)
    }

    /// [`AliasTable::sample`] over any [`RandomSource`] — the same two
    /// draws in the same order, so a [`BlockRng`]-batched chunk loop
    /// picks the identical category sequence as the scalar path.
    #[inline]
    pub fn sample_with<R: RandomSource>(&self, rng: &mut R) -> usize {
        let i = rng.below(self.prob.len() as u64) as usize;
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(1, 0);
        let mut b = Pcg64::with_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let t = AliasTable::new(&weights);
        let mut rng = Pcg64::new(9);
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[t.sample(&mut rng)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            let want = weights[i] / 10.0;
            let got = *c as f64 / n as f64;
            assert!((got - want).abs() < 0.01, "i={i} got={got} want={want}");
        }
    }

    #[test]
    fn alias_table_degenerate() {
        let t = AliasTable::new(&[0.0, 0.0]);
        let mut rng = Pcg64::new(1);
        for _ in 0..10 {
            assert!(t.sample(&mut rng) < 2);
        }
        let single = AliasTable::new(&[3.5]);
        assert_eq!(single.sample(&mut rng), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(2);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(4);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Pcg64::new(6);
        for &lambda in &[2.0, 50.0] {
            let n = 50_000;
            let s: u64 = (0..n).map(|_| rng.poisson(lambda)).sum();
            let mean = s as f64 / n as f64;
            assert!((mean - lambda).abs() / lambda < 0.05, "lambda={lambda} mean={mean}");
        }
    }

    #[test]
    fn block_rng_serves_the_exact_pcg_stream() {
        // mixed draw widths, crossing several refill boundaries
        let mut scalar = Pcg64::new(42);
        let mut block = BlockRng::new(Pcg64::new(42));
        for i in 0..(RNG_BLOCK * 3 + 17) {
            match i % 4 {
                0 => assert_eq!(scalar.next_u64(), block.next_u64(), "raw @{i}"),
                1 => assert_eq!(scalar.f64().to_bits(), RandomSource::f64(&mut block).to_bits()),
                2 => assert_eq!(scalar.below(7), RandomSource::below(&mut block, 7)),
                _ => assert_eq!(scalar.poisson(3.5), RandomSource::poisson(&mut block, 3.5)),
            }
        }
    }

    #[test]
    fn fill_u64_matches_sequential_draws() {
        let mut a = Pcg64::new(5);
        let mut b = Pcg64::new(5);
        let mut buf = Vec::new();
        a.fill_u64(&mut buf, 100);
        assert_eq!(buf.len(), 100);
        for v in &buf {
            assert_eq!(*v, b.next_u64());
        }
        // reuse keeps the stream continuous
        a.fill_u64(&mut buf, 3);
        for v in &buf {
            assert_eq!(*v, b.next_u64());
        }
    }

    #[test]
    fn alias_sample_with_matches_scalar_sample() {
        let t = AliasTable::new(&[0.5, 2.0, 1.25, 0.25]);
        let mut scalar = Pcg64::new(77);
        let mut block = BlockRng::new(Pcg64::new(77));
        for _ in 0..5_000 {
            assert_eq!(t.sample(&mut scalar), t.sample_with(&mut block));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(8);
        let mut c0 = 0;
        for _ in 0..10_000 {
            if rng.categorical(&[9.0, 1.0]) == 0 {
                c0 += 1;
            }
        }
        assert!(c0 > 8_600 && c0 < 9_400, "c0={c0}");
    }
}
