//! Minimal JSON value model, parser, and serializer.
//!
//! The offline registry has no `serde`, so experiment outputs, config files
//! and artifact manifests use this self-contained implementation. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and preserves object insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (f64, like real JSON).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object. BTreeMap gives deterministic serialization order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Get a field of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Get a field of an object, treating an explicit `null` as absent.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self.get(key) {
            Some(Json::Null) | None => None,
            Some(v) => Some(v),
        }
    }

    /// Required object field; [`crate::Error::Data`] when absent.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key)
            .ok_or_else(|| crate::Error::Data(format!("artifact: missing field `{key}`")))
    }

    /// Required numeric field.
    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| crate::Error::Data(format!("artifact: field `{key}` must be a number")))
    }

    /// Required unsigned-integer field. Accepts an exactly-representable
    /// number or a decimal string (the encoding [`Json::u64_exact`] uses
    /// for values at or above 2^53).
    pub fn req_u64(&self, key: &str) -> crate::Result<u64> {
        u64_from_json(self.req(key)?).ok_or_else(|| {
            crate::Error::Data(format!(
                "artifact: field `{key}` must be a non-negative integer"
            ))
        })
    }

    /// Required `usize` field.
    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        Ok(self.req_u64(key)? as usize)
    }

    /// Required `u32` field.
    pub fn req_u32(&self, key: &str) -> crate::Result<u32> {
        let x = self.req_u64(key)?;
        u32::try_from(x).map_err(|_| {
            crate::Error::Data(format!("artifact: field `{key}` = {x} overflows u32"))
        })
    }

    /// Required boolean field.
    pub fn req_bool(&self, key: &str) -> crate::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| crate::Error::Data(format!("artifact: field `{key}` must be a bool")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| crate::Error::Data(format!("artifact: field `{key}` must be a string")))
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> crate::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| crate::Error::Data(format!("artifact: field `{key}` must be an array")))
    }

    /// Required array of numbers.
    pub fn req_f64s(&self, key: &str) -> crate::Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_f64().ok_or_else(|| {
                    crate::Error::Data(format!("artifact: `{key}` must hold numbers"))
                })
            })
            .collect()
    }

    /// Required array of unsigned integers.
    pub fn req_u64s(&self, key: &str) -> crate::Result<Vec<u64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                u64_from_json(v).ok_or_else(|| {
                    crate::Error::Data(format!("artifact: `{key}` must hold integers"))
                })
            })
            .collect()
    }

    /// Required array of `u32`s.
    pub fn req_u32s(&self, key: &str) -> crate::Result<Vec<u32>> {
        self.req_u64s(key)?
            .into_iter()
            .map(|x| {
                u32::try_from(x).map_err(|_| {
                    crate::Error::Data(format!("artifact: `{key}` entry {x} overflows u32"))
                })
            })
            .collect()
    }

    /// Required array of strings.
    pub fn req_strs(&self, key: &str) -> crate::Result<Vec<String>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_str().map(str::to_string).ok_or_else(|| {
                    crate::Error::Data(format!("artifact: `{key}` must hold strings"))
                })
            })
            .collect()
    }

    /// Encode a `u64` losslessly: values below 2^53 stay numeric, larger
    /// ones become decimal strings (JSON numbers are f64).
    pub fn u64_exact(x: u64) -> Json {
        if x < (1u64 << 53) {
            Json::Num(x as f64)
        } else {
            Json::Str(x.to_string())
        }
    }

    /// True when any number in the tree is NaN or infinite. JSON cannot
    /// represent non-finite values (serializing one produces an
    /// unparseable document), so writers that must stay round-trippable
    /// check this before serializing.
    pub fn has_non_finite(&self) -> bool {
        match self {
            Json::Num(x) => !x.is_finite(),
            Json::Arr(a) => a.iter().any(Json::has_non_finite),
            Json::Obj(o) => o.values().any(Json::has_non_finite),
            _ => false,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u16> for Json {
    fn from(x: u16) -> Self {
        Json::Num(x as f64)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Exact u64 decoding: an integral number below 2^53, or a decimal
/// string (the [`Json::u64_exact`] wide-value encoding).
fn u64_from_json(v: &Json) -> Option<u64> {
    match v {
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9_007_199_254_740_992.0 => {
            Some(*x as u64)
        }
        Json::Str(s) => s.parse().ok(),
        _ => None,
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("unknown escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""aA\t\"b\"""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t\"b\""));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn reject_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let v = Json::from(42u64);
        assert_eq!(v.to_string(), "42");
        let v = Json::Num(2.5);
        assert_eq!(v.to_string(), "2.5");
    }

    #[test]
    fn typed_field_helpers() {
        let src = r#"{"a": 3, "b": "x", "c": [1, 2], "d": null, "big": "18446744073709551615"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_u64("a").unwrap(), 3);
        assert_eq!(v.req_str("b").unwrap(), "x");
        assert_eq!(v.req_u64s("c").unwrap(), vec![1, 2]);
        assert!(v.opt("d").is_none());
        assert!(v.opt("missing").is_none());
        assert_eq!(v.req_u64("big").unwrap(), u64::MAX);
        assert!(v.req("nope").is_err());
        assert!(v.req_f64("b").is_err());
    }

    #[test]
    fn u64_exact_roundtrips_wide_values() {
        for x in [0u64, 7, (1 << 53) - 1, 1 << 53, u64::MAX] {
            let j = Json::u64_exact(x);
            let re = Json::parse(&j.to_string()).unwrap();
            let back = Json::obj(vec![("x", re)]).req_u64("x").unwrap();
            assert_eq!(back, x);
        }
    }

    #[test]
    fn nested_roundtrip() {
        let v = Json::obj(vec![
            ("rows", Json::from(vec![1.0f64, 2.0, 3.0])),
            ("name", Json::from("table2")),
            ("ok", Json::from(true)),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
