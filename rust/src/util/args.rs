//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options. Later occurrences win.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Get an option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Get an option parsed into T, or a default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// True if `--flag` was passed.
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        // NOTE: `--flag value`-style ambiguity is resolved greedily — a
        // bare `--verbose` must come last or use `--verbose=1`.
        let a = parse(&["generate", "extra", "--scale", "4", "--out=/tmp/x", "--verbose"]);
        assert_eq!(a.positional, vec!["generate", "extra"]);
        assert_eq!(a.get("scale"), Some("4"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_or("scale", 0usize), 4);
        assert_eq!(a.get_or("missing", 7usize), 7);
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["--dry-run", "--seed", "42"]);
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_or("seed", 0u64), 42);
    }

    #[test]
    fn repeated_option_last_wins() {
        let a = parse(&["--k", "1", "--k", "2"]);
        assert_eq!(a.get("k"), Some("2"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.positional, vec!["cmd"]);
    }
}
