//! Statistics helpers shared by the metrics, fitting and feature modules:
//! descriptive statistics, histograms, correlation measures, divergences,
//! and a small dense linear-algebra kit (Cholesky) for the multivariate
//! Gaussian generator.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Minimum and maximum (NaN-ignoring). Returns (0,0) for empty input.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        if x.is_nan() {
            continue;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Pearson correlation coefficient between two equal-length slices.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Correlation ratio η (categorical x, continuous y) — Fisher [12] in the
/// paper; measures how much of y's variance is explained by category.
pub fn correlation_ratio(categories: &[usize], values: &[f64]) -> f64 {
    assert_eq!(categories.len(), values.len());
    if values.is_empty() {
        return 0.0;
    }
    let k = categories.iter().copied().max().unwrap_or(0) + 1;
    let mut sums = vec![0.0; k];
    let mut counts = vec![0usize; k];
    for (&c, &v) in categories.iter().zip(values) {
        sums[c] += v;
        counts[c] += 1;
    }
    let total_mean = mean(values);
    let mut between = 0.0;
    for c in 0..k {
        if counts[c] > 0 {
            let m = sums[c] / counts[c] as f64;
            between += counts[c] as f64 * (m - total_mean) * (m - total_mean);
        }
    }
    let total: f64 = values.iter().map(|v| (v - total_mean) * (v - total_mean)).sum();
    if total <= 0.0 {
        0.0
    } else {
        (between / total).sqrt()
    }
}

/// Theil's U (uncertainty coefficient) U(x|y): how much knowing y reduces
/// uncertainty about x. Asymmetric, in [0,1].
pub fn theils_u(xs: &[usize], ys: &[usize]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n == 0 {
        return 0.0;
    }
    let hx = entropy_of(xs);
    if hx <= 0.0 {
        return 1.0; // x is constant: fully determined
    }
    // conditional entropy H(x|y)
    use std::collections::HashMap;
    let mut joint: HashMap<(usize, usize), usize> = HashMap::new();
    let mut ycount: HashMap<usize, usize> = HashMap::new();
    for (&x, &y) in xs.iter().zip(ys) {
        *joint.entry((x, y)).or_insert(0) += 1;
        *ycount.entry(y).or_insert(0) += 1;
    }
    let mut hxy = 0.0;
    for (&(_, y), &c) in &joint {
        let pxy = c as f64 / n as f64;
        let py = ycount[&y] as f64 / n as f64;
        hxy -= pxy * (pxy / py).ln();
    }
    ((hx - hxy) / hx).clamp(0.0, 1.0)
}

fn entropy_of(xs: &[usize]) -> f64 {
    use std::collections::HashMap;
    let mut counts: HashMap<usize, usize> = HashMap::new();
    for &x in xs {
        *counts.entry(x).or_insert(0) += 1;
    }
    let n = xs.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

/// Shannon entropy (nats) of a discrete sample.
pub fn entropy(xs: &[usize]) -> f64 {
    entropy_of(xs)
}

/// Jensen–Shannon divergence between two discrete distributions given as
/// unnormalized histograms over the same bins. Returns a value in [0, ln 2].
pub fn js_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    if sp <= 0.0 || sq <= 0.0 {
        return std::f64::consts::LN_2;
    }
    let mut jsd = 0.0;
    for i in 0..p.len() {
        let pi = p[i] / sp;
        let qi = q[i] / sq;
        let mi = 0.5 * (pi + qi);
        if pi > 0.0 {
            jsd += 0.5 * pi * (pi / mi).ln();
        }
        if qi > 0.0 {
            jsd += 0.5 * qi * (qi / mi).ln();
        }
    }
    jsd.max(0.0)
}

/// Normalized JS distance in [0,1]: sqrt(JSD / ln2).
pub fn js_distance(p: &[f64], q: &[f64]) -> f64 {
    (js_divergence(p, q) / std::f64::consts::LN_2).sqrt().clamp(0.0, 1.0)
}

/// Histogram with fixed equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<f64> {
    let mut h = vec![0.0; bins.max(1)];
    if hi <= lo {
        h[0] = xs.len() as f64;
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1.0;
    }
    h
}

/// Empirical CDF evaluated at sorted sample points: returns (sorted xs, F).
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = s.len();
    let f: Vec<f64> = (1..=n).map(|i| i as f64 / n as f64).collect();
    (s, f)
}

/// Gini coefficient of a non-negative sample (degree inequality in Table 10).
pub fn gini(xs: &[f64]) -> f64 {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| *x >= 0.0).collect();
    if s.is_empty() {
        return 0.0;
    }
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len() as f64;
    let total: f64 = s.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut cum = 0.0;
    let mut weighted = 0.0;
    for (i, x) in s.iter().enumerate() {
        cum += x;
        weighted += (i as f64 + 1.0) * x;
    }
    let _ = cum;
    (2.0 * weighted) / (n * total) - (n + 1.0) / n
}

/// In-place Cholesky factorization of a symmetric positive-definite matrix
/// stored row-major; returns the lower-triangular factor L (A = L Lᵀ).
/// Adds jitter to the diagonal if needed.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let mut l = vec![0.0; n * n];
    for j in 0..n {
        for i in j..n {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                let d = if sum > 1e-12 { sum } else { 1e-12 };
                l[j * n + j] = d.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Quantile of a sample (linear interpolation), q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn correlation_ratio_extremes() {
        // y fully determined by category
        let cats = [0, 0, 1, 1];
        let ys = [1.0, 1.0, 5.0, 5.0];
        assert!((correlation_ratio(&cats, &ys) - 1.0).abs() < 1e-12);
        // y independent of category
        let ys2 = [1.0, 5.0, 1.0, 5.0];
        assert!(correlation_ratio(&cats, &ys2).abs() < 1e-12);
    }

    #[test]
    fn theils_u_extremes() {
        let x = [0, 0, 1, 1, 2, 2];
        assert!((theils_u(&x, &x) - 1.0).abs() < 1e-12);
        let y = [0, 1, 0, 1, 0, 1];
        assert!(theils_u(&x, &y) < 0.15);
    }

    #[test]
    fn jsd_properties() {
        let p = [0.5, 0.5, 0.0];
        let q = [0.0, 0.5, 0.5];
        let d = js_divergence(&p, &q);
        assert!(d > 0.0 && d <= std::f64::consts::LN_2 + 1e-12);
        assert!((js_divergence(&p, &p)).abs() < 1e-12);
        // symmetric
        assert!((js_divergence(&p, &q) - js_divergence(&q, &p)).abs() < 1e-12);
        // disjoint support saturates at ln 2
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((js_divergence(&a, &b) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins() {
        let h = histogram(&[0.0, 0.5, 1.0, 2.0, 10.0], 0.0, 10.0, 5);
        assert_eq!(h.iter().sum::<f64>(), 5.0);
        assert_eq!(h[0], 3.0); // 0, 0.5, 1.0 in [0,2)
        assert_eq!(h[4], 1.0); // 10 clamps into last bin
    }

    #[test]
    fn gini_known() {
        // perfectly equal -> 0
        assert!(gini(&[1.0, 1.0, 1.0, 1.0]).abs() < 1e-9);
        // one holder of everything -> (n-1)/n
        let g = gini(&[0.0, 0.0, 0.0, 10.0]);
        assert!((g - 0.75).abs() < 1e-9, "g={g}");
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = [[4,2],[2,3]]
        let a = [4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        // L*L^T
        let mut re = [0.0; 4];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    re[i * 2 + j] += l[i * 2 + k] * l[j * 2 + k];
                }
            }
        }
        for i in 0..4 {
            assert!((re[i] - a[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn quantile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone() {
        let (xs, f) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
        assert!((f[2] - 1.0).abs() < 1e-12);
    }
}
