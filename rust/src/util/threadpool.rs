//! Data-parallel helpers over `std::thread::scope` (no rayon offline):
//! parallel map over index chunks and a bounded SPSC/MPSC channel used by
//! the streaming pipeline for backpressure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use: respects `SGG_THREADS`, defaults to
/// available parallelism capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SGG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel map over `0..n`: runs `f(i)` on `threads` workers and returns
/// results in index order. `f` must be `Sync`; results are written into
/// pre-allocated slots so no ordering pass is needed.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    drop(slots);
    out.into_iter().map(|v| v.expect("par_map slot unfilled")).collect()
}

/// Parallel for-each over disjoint mutable chunks of a slice.
/// Splits `data` into `threads` contiguous chunks and runs
/// `f(chunk_index, start_offset, chunk)` on each in parallel.
pub fn par_chunks_mut<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let threads = threads.max(1);
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, (off, slice)) in data
            .chunks_mut(chunk)
            .enumerate()
            .map(|(i, c)| (i, (i * chunk, c)))
        {
            let f = &f;
            s.spawn(move || f(ci, off, slice));
        }
    });
}

/// A bounded multi-producer multi-consumer channel built on
/// Mutex+Condvar. `send` blocks when the queue is full — this is the
/// backpressure mechanism of the streaming generation pipeline.
pub struct Bounded<T> {
    inner: Arc<BoundedInner<T>>,
}

struct BoundedInner<T> {
    q: Mutex<BoundedState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
    /// peak queue occupancy, for pipeline introspection/tests
    high_water: usize,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Bounded<T> {
    /// Create a channel with capacity `cap` (≥1).
    pub fn new(cap: usize) -> Self {
        Bounded {
            inner: Arc::new(BoundedInner {
                q: Mutex::new(BoundedState {
                    items: VecDeque::new(),
                    closed: false,
                    high_water: 0,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap: cap.max(1),
            }),
        }
    }

    /// Blocking send; returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.inner.cap {
                st.items.push_back(item);
                let n = st.items.len();
                st.high_water = st.high_water.max(n);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: enqueue `item` only when a slot is free.
    /// Returns `Err(item)` when the queue is full or the channel is
    /// closed — the admission-control primitive behind `sgg serve`'s
    /// 429 backpressure (a full queue rejects instead of blocking the
    /// acceptor thread).
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.items.len() >= self.inner.cap {
            return Err(item);
        }
        st.items.push_back(item);
        let n = st.items.len();
        st.high_water = st.high_water.max(n);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None when the channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Close the channel: senders fail, receivers drain then get None.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Highest queue occupancy observed (bounded by capacity).
    pub fn high_water(&self) -> usize {
        self.inner.q.lock().unwrap().high_water
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_ordered() {
        let out = par_map(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut data = vec![0u64; 1000];
        par_chunks_mut(&mut data, 7, |_ci, off, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (off + i) as u64;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn bounded_backpressure_and_order() {
        let ch: Bounded<usize> = Bounded::new(4);
        let tx = ch.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                tx.send(i).unwrap();
            }
            tx.close();
        });
        let mut got = Vec::new();
        while let Some(v) = ch.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
        assert!(ch.high_water() <= 4, "bound violated: {}", ch.high_water());
    }

    #[test]
    fn bounded_close_unblocks() {
        let ch: Bounded<usize> = Bounded::new(1);
        let rx = ch.clone();
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(std::time::Duration::from_millis(20));
        ch.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn try_send_rejects_when_full_or_closed() {
        let ch: Bounded<u8> = Bounded::new(2);
        assert!(ch.try_send(1).is_ok());
        assert!(ch.try_send(2).is_ok());
        assert_eq!(ch.try_send(3), Err(3));
        assert_eq!(ch.recv(), Some(1));
        assert!(ch.try_send(3).is_ok());
        ch.close();
        assert_eq!(ch.try_send(4), Err(4));
        // already-queued items still drain after close
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), Some(3));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn send_after_close_fails() {
        let ch: Bounded<u8> = Bounded::new(2);
        ch.close();
        assert!(ch.send(1).is_err());
    }

    #[test]
    fn multi_producer_consumer_counts() {
        let ch: Bounded<u64> = Bounded::new(8);
        let n_prod = 4;
        let per = 500u64;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for p in 0..n_prod {
                let tx = ch.clone();
                handles.push(s.spawn(move || {
                    for i in 0..per {
                        tx.send(p * per + i).unwrap();
                    }
                }));
            }
            let rx = ch.clone();
            let consumer = s.spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some(v) = rx.recv() {
                    sum += v;
                    count += 1;
                }
                (sum, count)
            });
            for h in handles {
                h.join().unwrap();
            }
            ch.close();
            let (sum, count) = consumer.join().unwrap();
            let total = n_prod * per;
            assert_eq!(count, total);
            assert_eq!(sum, (0..total).sum::<u64>());
        });
    }
}
