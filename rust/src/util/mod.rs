//! Foundation substrates built from scratch for the offline environment:
//! PRNG + distributions, JSON, CLI args, a scoped thread pool, statistics
//! helpers, logging, and a tiny property-testing driver.

pub mod args;
pub mod checksum;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
