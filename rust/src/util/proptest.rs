//! Hand-rolled property-testing driver (no `proptest` crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs drawn from a seeded [`Pcg64`]; on failure it reports the case
//! seed so the exact input can be replayed deterministically.

use super::rng::Pcg64;

/// Run `prop` on `cases` independent seeded RNGs. The property returns
/// `Err(description)` on violation. Panics with the failing seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        // decorrelate case seeds
        let seed = 0x9e37_79b9_7f4a_7c15u64
            .wrapping_mul(case + 1)
            .rotate_left(17)
            ^ 0x5bf0_3635;
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 plus zero", 50, |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(0) == x {
                Ok(())
            } else {
                Err("addition broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failures() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
