//! Machine-readable harness reports: the JSON document `sgg test
//! --report` writes and CI uploads as an artifact. One object per
//! scenario with its status, measured profile, per-metric golden
//! checks, and the fault-recovery verdict.

use super::{HarnessReport, ScenarioStatus};
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// Render a harness report as JSON.
pub fn report_json(report: &HarnessReport) -> Json {
    let scenarios: Vec<Json> = report
        .scenarios
        .iter()
        .map(|s| {
            let (status, reason) = match &s.status {
                ScenarioStatus::Passed => ("passed", None),
                ScenarioStatus::Blessed => ("blessed", None),
                ScenarioStatus::Failed(why) => ("failed", Some(why.clone())),
            };
            let mut fields = vec![
                ("name", Json::from(s.name.as_str())),
                ("status", Json::from(status)),
            ];
            if let Some(why) = reason {
                fields.push(("reason", Json::from(why)));
            }
            if let Some(p) = &s.profile {
                fields.push((
                    "profile",
                    Json::obj(vec![
                        ("edges", Json::from(p.edges)),
                        ("shards", Json::from(p.shards)),
                        ("degree_dist", Json::from(p.degree_dist)),
                        ("dcc", Json::from(p.dcc)),
                        ("edge_checksum", Json::from(format!("{:016x}", p.edge_checksum))),
                        ("effective_diameter", Json::from(p.effective_diameter)),
                        ("cpl", Json::from(p.cpl)),
                    ]),
                ));
            }
            if let Some(identical) = s.fault_identical {
                fields.push(("fault_identical", Json::from(identical)));
            }
            if !s.checks.is_empty() {
                let checks: Vec<Json> = s
                    .checks
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("name", Json::from(c.name.as_str())),
                            ("expected", Json::from(c.expected)),
                            ("measured", Json::from(c.measured)),
                            ("tol", Json::from(c.tol)),
                            ("passed", Json::from(c.passed)),
                        ])
                    })
                    .collect();
                fields.push(("checks", Json::from(checks)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("passed", Json::from(report.passed())),
        ("scenarios", Json::from(scenarios)),
    ])
}

/// Write the JSON report to `path` (parent directories created).
pub fn write_report(path: &Path, report: &HarnessReport) -> Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Config(format!("cannot create {}: {e}", dir.display())))?;
    }
    std::fs::write(path, format!("{}\n", report_json(report)))
        .map_err(|e| Error::Config(format!("cannot write report {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{MetricCheck, MetricProfile, ScenarioReport};

    fn sample_report() -> HarnessReport {
        HarnessReport {
            scenarios: vec![
                ScenarioReport {
                    name: "fraud".into(),
                    status: ScenarioStatus::Passed,
                    profile: Some(MetricProfile {
                        edges: 1000,
                        shards: 2,
                        degree_dist: 0.9,
                        dcc: 0.8,
                        profile_hash: 7,
                        edge_checksum: 0xabcd,
                        effective_diameter: 4.5,
                        cpl: 2.25,
                    }),
                    checks: vec![MetricCheck {
                        name: "edges".into(),
                        expected: 1000.0,
                        measured: 1000.0,
                        tol: 0.0,
                        passed: true,
                    }],
                    fault_identical: Some(true),
                },
                ScenarioReport {
                    name: "broken".into(),
                    status: ScenarioStatus::Failed("clean run failed: boom".into()),
                    profile: None,
                    checks: Vec::new(),
                    fault_identical: None,
                },
            ],
        }
    }

    #[test]
    fn report_roundtrips_and_carries_failures() {
        let report = sample_report();
        let doc = report_json(&report);
        assert_eq!(doc.get("passed").unwrap().as_bool(), Some(false));
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let scenarios = back.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scenarios.len(), 2);
        assert_eq!(scenarios[0].get("status").unwrap().as_str(), Some("passed"));
        assert_eq!(
            scenarios[0].get("fault_identical").unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(scenarios[1].get("status").unwrap().as_str(), Some("failed"));
        assert!(scenarios[1]
            .get("reason")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("boom"));
    }

    #[test]
    fn write_report_creates_parents() {
        let dir = std::env::temp_dir().join(format!("sgg_rep_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("report.json");
        write_report(&path, &sample_report()).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("passed").unwrap().as_bool(), Some(false));
        std::fs::remove_dir_all(&dir).ok();
    }
}
