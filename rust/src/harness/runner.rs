//! Scenario execution for the conformance harness: run one `.toml`
//! scenario into a hermetic shard directory (optionally under a fault
//! schedule) and measure its [`MetricProfile`] by streaming the shards
//! back — never materializing the generated graph.

use crate::graph::io;
use crate::metrics::degree::{self, DegreeProfile};
use crate::metrics::hopplot;
use crate::metrics::stream::{profile_shards_with, DCC_SAMPLES};
use crate::pipeline::fault::{FaultPlan, RetryPolicy};
use crate::pipeline::spec::{ScenarioSpec, SinkSpec};
use crate::pipeline::{run_scenario_opts, Registries, RunOptions};
use crate::structgen::chunked::ChunkConfig;
use crate::{Error, Result};
use std::path::Path;

/// BFS sample count pinned by the harness for the sampled path metrics
/// ([`MetricProfile::effective_diameter`] / [`MetricProfile::cpl`]).
/// Fixed together with [`BFS_SEED`] so golden values are deterministic.
pub const BFS_SAMPLES: usize = 64;

/// BFS source-sampling seed paired with [`BFS_SAMPLES`].
pub const BFS_SEED: u64 = 0x5667;

/// The measured fingerprint of one scenario run: output sizes, the
/// streamed structural scores against the scenario's source dataset,
/// a hash of the full synthetic degree profile (so "bit-identical"
/// covers every node's degree, not just the two scalar scores), the
/// decoded-edge multiset checksum of the output shards (so the
/// pinned identity is the *graph*, not the shard encoding — SGGEDGE1
/// and SGGEDGE2 runs of the same scenario measure equal), and the
/// BFS-sampled path metrics at the pinned
/// ([`BFS_SAMPLES`], [`BFS_SEED`]) schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricProfile {
    /// Total generated edges (from the validated shard headers).
    pub edges: u64,
    /// Shard files written.
    pub shards: usize,
    /// Table-2 "Degree Dist. ↑" against the fit source.
    pub degree_dist: f64,
    /// Degree Comparison Coefficient (paper eq. 20).
    pub dcc: f64,
    /// FNV-1a over the synthetic out/in degree arrays.
    pub profile_hash: u64,
    /// Order- and format-invariant multiset checksum over every decoded
    /// edge of every shard ([`io::decoded_checksum`]).
    pub edge_checksum: u64,
    /// 90%-effective diameter of the generated graph, BFS-sampled at
    /// the pinned ([`BFS_SAMPLES`], [`BFS_SEED`]) schedule (paper
    /// Figure 2 right).
    pub effective_diameter: f64,
    /// Characteristic path length under the same pinned BFS schedule.
    pub cpl: f64,
}

impl MetricProfile {
    /// True when `other` is indistinguishable from `self` bit for bit —
    /// exact counts, exact f64 bits, identical degree arrays.
    pub fn bit_identical(&self, other: &MetricProfile) -> bool {
        self.edges == other.edges
            && self.shards == other.shards
            && self.degree_dist.to_bits() == other.degree_dist.to_bits()
            && self.dcc.to_bits() == other.dcc.to_bits()
            && self.profile_hash == other.profile_hash
            && self.edge_checksum == other.edge_checksum
            && self.effective_diameter.to_bits() == other.effective_diameter.to_bits()
            && self.cpl.to_bits() == other.cpl.to_bits()
    }
}

/// Execute the scenario at `path` into a fresh shard directory at
/// `out_dir` and measure its profile. `faults` injects the same
/// deterministic schedule into generation (sampling + shard writes,
/// absorbed by the retrying sink) *and* into the read-back profiling
/// pass (absorbed by the [`crate::pipeline::FaultReader`]) — a
/// recovered run must therefore produce a profile bit-identical to a
/// fault-free one.
///
/// The scenario's own `[sink]` directory and `[evaluate]` flag are
/// overridden: the harness owns the output location and always scores
/// via the streamed read-back pass so clean and faulted runs are
/// measured identically.
pub fn run_scenario_profile(
    path: &Path,
    out_dir: &Path,
    workers: usize,
    faults: Option<FaultPlan>,
    _fault_seed: u64,
) -> Result<MetricProfile> {
    let mut spec = ScenarioSpec::from_file(path)?;
    if spec.model.is_some() {
        return Err(Error::Config(format!(
            "{}: harness scenarios must name a `dataset` (the golden profile is \
             scored against it); `model` artifacts carry no reference graph",
            path.display()
        )));
    }
    if workers > 0 {
        spec.workers = workers;
    }
    spec.evaluate = false;
    // redirect output into the hermetic workdir, keeping any chunking
    // knobs the scenario set; workers = 0 re-inherits spec.workers
    let mut chunks = match &spec.sink {
        SinkSpec::Shards { chunks, .. } => *chunks,
        SinkSpec::Memory => ChunkConfig::default(),
    };
    chunks.workers = 0;
    std::fs::remove_dir_all(out_dir).ok();
    spec.sink = SinkSpec::Shards { dir: out_dir.to_path_buf(), chunks };

    run_scenario_opts(
        &spec,
        &Registries::builtin(),
        RunOptions { resume: false, faults, ..RunOptions::default() },
    )?;

    let source = crate::datasets::load(&spec.dataset, spec.dataset_seed)?;
    let orig = DegreeProfile::of(&source.edges);
    let (synth, scan) =
        profile_shards_with(out_dir, spec.workers.max(1), faults, RetryPolicy::default())?;
    // The decoded-edge checksum is a second read pass: each shard is
    // decoded once on the worker pool and checksummed from the decoded
    // edges (wrapping-summing per-shard checksums equals the checksum of
    // the union multiset, so the value is independent of shard format,
    // edge order, and worker count). The same pass assembles the edges
    // in memory for the BFS-sampled path metrics — harness scenarios are
    // sized to fit.
    let (edge_checksum, effective_diameter, cpl) = if scan.shards == 0 {
        (0, 0.0, 0.0)
    } else {
        let reader = io::ShardReader::open(out_dir)?;
        let (all, sum) = reader.read_all_checksummed(spec.workers.max(1))?;
        (
            sum,
            hopplot::effective_diameter(&all, 0.9, BFS_SAMPLES, BFS_SEED),
            hopplot::characteristic_path_length(&all, BFS_SAMPLES, BFS_SEED),
        )
    };
    Ok(MetricProfile {
        edges: scan.edges,
        shards: scan.shards,
        degree_dist: degree::degree_dist_score_profiles(&orig, &synth),
        dcc: degree::dcc_profiles(&orig, &synth, DCC_SAMPLES),
        profile_hash: degree::profile_hash(&synth),
        edge_checksum,
        effective_diameter,
        cpl,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("sgg_hrun_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    const SCENARIO: &str = r#"
name = "runner-small"
dataset = "travel-insurance"
seed = 21

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[sink]
kind = "shards"
"#;

    #[test]
    fn clean_and_faulted_profiles_are_bit_identical() {
        let dir = tmp("scen");
        let path = dir.join("s.toml");
        std::fs::write(&path, SCENARIO).unwrap();
        let clean = run_scenario_profile(&path, &dir.join("clean"), 2, None, 7).unwrap();
        assert!(clean.edges > 0);
        assert!(clean.shards > 0);
        let plan = FaultPlan::transient(7);
        let faulted =
            run_scenario_profile(&path, &dir.join("faulted"), 2, Some(plan), 7).unwrap();
        assert!(clean.bit_identical(&faulted), "{clean:?} vs {faulted:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_scenarios_are_rejected() {
        let dir = tmp("model");
        let path = dir.join("m.toml");
        std::fs::write(&path, "model = \"m.sggm\"\n").unwrap();
        let err = run_scenario_profile(&path, &dir.join("out"), 1, None, 7).unwrap_err();
        assert!(err.to_string().contains("dataset"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn profile_hash_distinguishes_length_splits() {
        use crate::graph::{EdgeList, PartiteSpec};
        let mut a = EdgeList::new(PartiteSpec::square(4));
        a.push(0, 1);
        a.push(1, 2);
        let mut b = EdgeList::new(PartiteSpec::square(4));
        b.push(0, 2);
        b.push(1, 1);
        let ha = degree::profile_hash(&DegreeProfile::of(&a));
        let hb = degree::profile_hash(&DegreeProfile::of(&b));
        assert_ne!(ha, hb);
        assert_eq!(ha, degree::profile_hash(&DegreeProfile::of(&a)));
    }
}
