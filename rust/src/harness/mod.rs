//! The conformance harness behind `sgg test scenarios/` — every
//! checked-in scenario is executed end to end, its streamed structural
//! profile is compared against a golden JSON with per-metric
//! tolerances, and the whole run is repeated under a deterministic
//! fault schedule to assert that recovery converges to a bit-identical
//! profile. The harness is the gate every future backend, format, and
//! scenario type drops into (ROADMAP item 5).
//!
//! Split mirrors the classic harness shape:
//!
//! * [`runner`] — executes one scenario (clean and fault-injected) in a
//!   hermetic workdir and measures its [`runner::MetricProfile`].
//! * [`comparator`] — checks a measured profile against the checked-in
//!   golden, or blesses the golden when it is unpinned/missing.
//! * [`reporter`] — renders the machine-readable JSON report CI uploads.
//!
//! Golden files live next to the scenarios (`<scenarios>/golden/
//! <name>.json`). A golden with `"pinned": false` (or a missing one) is
//! *blessed* on the next run: the measured profile is written back with
//! `pinned: true`, and from then on every run must reproduce it within
//! the stored tolerances. `sgg test --bless` re-blesses explicitly
//! after an intentional change.

pub mod comparator;
pub mod reporter;
pub mod runner;

pub use comparator::{compare_or_bless, GoldenOutcome, MetricCheck};
pub use reporter::{report_json, write_report};
pub use runner::{run_scenario_profile, MetricProfile, BFS_SAMPLES, BFS_SEED};

use crate::pipeline::fault::FaultPlan;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Configuration of one `sgg test` invocation.
#[derive(Clone, Debug)]
pub struct HarnessConfig {
    /// Directory holding the `.toml` scenarios to execute.
    pub scenarios_dir: PathBuf,
    /// Hermetic working directory for generated shards (one
    /// subdirectory per scenario; recreated per run).
    pub workdir: PathBuf,
    /// Directory of golden profiles (`<scenarios>/golden` by default).
    pub golden_dir: PathBuf,
    /// Worker count for generation and profiling (0 = one per core).
    pub workers: usize,
    /// Re-bless every golden from this run's measurements.
    pub bless: bool,
    /// Seed of the fault schedule used for the fault-injected re-run.
    pub fault_seed: u64,
}

impl HarnessConfig {
    /// Default configuration over a scenario directory.
    pub fn new(scenarios_dir: &Path) -> HarnessConfig {
        HarnessConfig {
            scenarios_dir: scenarios_dir.to_path_buf(),
            workdir: std::env::temp_dir().join(format!("sgg-test-{}", std::process::id())),
            golden_dir: scenarios_dir.join("golden"),
            workers: 2,
            bless: false,
            fault_seed: 0xfa17,
        }
    }
}

/// Outcome of one scenario under the harness.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScenarioStatus {
    /// Matched its pinned golden within tolerances, and the
    /// fault-injected re-run converged bit-identically.
    Passed,
    /// No pinned golden existed (or `--bless`): the measured profile was
    /// written as the new golden. The fault re-run still had to
    /// converge bit-identically.
    Blessed,
    /// Any check failed; the message says which.
    Failed(String),
}

/// Per-scenario harness record.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name (file stem of the `.toml`).
    pub name: String,
    /// Pass/bless/fail.
    pub status: ScenarioStatus,
    /// Measured profile of the clean run (absent when the run errored).
    pub profile: Option<MetricProfile>,
    /// Per-metric golden checks (empty when blessed or errored).
    pub checks: Vec<MetricCheck>,
    /// Whether the fault-injected re-run reproduced the clean profile
    /// bit for bit (absent when the clean run already failed).
    pub fault_identical: Option<bool>,
}

/// Full harness result: one record per scenario, in path order.
#[derive(Clone, Debug, Default)]
pub struct HarnessReport {
    /// Per-scenario outcomes.
    pub scenarios: Vec<ScenarioReport>,
}

impl HarnessReport {
    /// True when no scenario failed.
    pub fn passed(&self) -> bool {
        self.scenarios
            .iter()
            .all(|s| !matches!(s.status, ScenarioStatus::Failed(_)))
    }
}

/// Execute every `.toml` scenario under the harness: clean run →
/// profile → golden compare/bless → fault-injected re-run → bit-identity
/// check. Scenario-level errors are captured as `Failed` records, not
/// propagated — one broken scenario must not hide the others' results.
pub fn run_harness(cfg: &HarnessConfig) -> Result<HarnessReport> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&cfg.scenarios_dir)
        .map_err(|e| {
            Error::Config(format!(
                "cannot read scenario directory {}: {e}",
                cfg.scenarios_dir.display()
            ))
        })?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "toml").unwrap_or(false))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(Error::Config(format!(
            "no .toml scenarios in {}",
            cfg.scenarios_dir.display()
        )));
    }
    let mut report = HarnessReport::default();
    for path in &paths {
        report.scenarios.push(run_one(cfg, path));
    }
    Ok(report)
}

/// One scenario through the full pipeline of checks.
fn run_one(cfg: &HarnessConfig, path: &Path) -> ScenarioReport {
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("scenario")
        .to_string();
    let fail = |msg: String| ScenarioReport {
        name: name.clone(),
        status: ScenarioStatus::Failed(msg),
        profile: None,
        checks: Vec::new(),
        fault_identical: None,
    };

    // clean run
    let clean_dir = cfg.workdir.join(&name).join("clean");
    let clean = match run_scenario_profile(path, &clean_dir, cfg.workers, None, cfg.fault_seed)
    {
        Ok(p) => p,
        Err(e) => return fail(format!("clean run failed: {e}")),
    };

    // fault-injected re-run: transient sample/sink/read faults plus one
    // injected worker panic — must converge to the exact same profile
    let fault_dir = cfg.workdir.join(&name).join("faulted");
    let plan = FaultPlan::transient(cfg.fault_seed);
    let faulted =
        match run_scenario_profile(path, &fault_dir, cfg.workers, Some(plan), cfg.fault_seed) {
            Ok(p) => p,
            Err(e) => return fail(format!("fault-injected run failed to recover: {e}")),
        };
    let identical = clean.bit_identical(&faulted);
    if !identical {
        return ScenarioReport {
            name,
            status: ScenarioStatus::Failed(
                "fault-injected run diverged from the clean profile".into(),
            ),
            profile: Some(clean),
            checks: Vec::new(),
            fault_identical: Some(false),
        };
    }

    // golden compare (or bless)
    let golden_path = cfg.golden_dir.join(format!("{name}.json"));
    match compare_or_bless(&golden_path, &clean, cfg.bless) {
        Ok(GoldenOutcome::Matched(checks)) => ScenarioReport {
            name,
            status: ScenarioStatus::Passed,
            profile: Some(clean),
            checks,
            fault_identical: Some(true),
        },
        Ok(GoldenOutcome::Blessed) => ScenarioReport {
            name,
            status: ScenarioStatus::Blessed,
            profile: Some(clean),
            checks: Vec::new(),
            fault_identical: Some(true),
        },
        Ok(GoldenOutcome::Mismatched(checks)) => {
            let bad: Vec<String> = checks
                .iter()
                .filter(|c| !c.passed)
                .map(|c| c.to_string())
                .collect();
            ScenarioReport {
                name,
                status: ScenarioStatus::Failed(format!(
                    "golden mismatch: {}",
                    bad.join("; ")
                )),
                profile: Some(clean),
                checks,
                fault_identical: Some(true),
            }
        }
        Err(e) => fail(format!("golden check errored: {e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("sgg_harness_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn write_scenario(dir: &Path, name: &str, body: &str) {
        std::fs::write(dir.join(format!("{name}.toml")), body).unwrap();
    }

    const SMALL: &str = r#"
name = "harness-small"
dataset = "travel-insurance"
seed = 11
workers = 2

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[sink]
kind = "shards"
"#;

    #[test]
    fn harness_blesses_then_passes_then_catches_drift() {
        let scen = tmp("scen");
        write_scenario(&scen, "small", SMALL);
        let mut cfg = HarnessConfig::new(&scen);
        cfg.workdir = tmp("work");
        // no golden: first run blesses
        let r1 = run_harness(&cfg).unwrap();
        assert!(r1.passed());
        assert_eq!(r1.scenarios[0].status, ScenarioStatus::Blessed);
        assert_eq!(r1.scenarios[0].fault_identical, Some(true));
        // second run compares against the freshly pinned golden
        let r2 = run_harness(&cfg).unwrap();
        assert!(r2.passed(), "{:?}", r2.scenarios[0].status);
        assert_eq!(r2.scenarios[0].status, ScenarioStatus::Passed);
        assert!(r2.scenarios[0].checks.iter().all(|c| c.passed));
        // corrupt the golden edge count: the harness must fail loudly
        let gp = cfg.golden_dir.join("small.json");
        let doc = std::fs::read_to_string(&gp).unwrap();
        std::fs::write(&gp, doc.replace("\"edges\":", "\"edges\": 1, \"was\":")).unwrap();
        let r3 = run_harness(&cfg).unwrap();
        assert!(!r3.passed());
        assert!(matches!(r3.scenarios[0].status, ScenarioStatus::Failed(_)));
        std::fs::remove_dir_all(&scen).ok();
        std::fs::remove_dir_all(&cfg.workdir).ok();
    }

    #[test]
    fn empty_scenario_dir_is_config_error() {
        let scen = tmp("empty");
        let cfg = HarnessConfig::new(&scen);
        assert!(run_harness(&cfg).is_err());
        std::fs::remove_dir_all(&scen).ok();
    }
}
