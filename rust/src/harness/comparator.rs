//! Golden-profile comparison for the conformance harness.
//!
//! A golden file is a small JSON document pinning one scenario's
//! expected profile:
//!
//! ```json
//! {
//!   "pinned": true,
//!   "edges": 12000,
//!   "shards": 4,
//!   "edge_checksum": "00a1b2c3d4e5f607",
//!   "metrics": {
//!     "degree_dist": {"value": 0.9321, "tol": 1e-9},
//!     "dcc":         {"value": 0.8712, "tol": 1e-9}
//!   }
//! }
//! ```
//!
//! `edges` and `shards` are exact (generation is deterministic down to
//! the chunk split); the scalar scores carry a per-metric tolerance
//! because they pass through `libm` territory (ln/sqrt), which may
//! differ in the last ulps across toolchains. `edge_checksum` is the
//! decoded-edge multiset checksum of the output shards
//! ([`crate::graph::io::decoded_checksum`]) — exact, stored as a
//! 16-digit hex string because the value is a full u64 and JSON numbers
//! only carry 53 bits; goldens pinned before the field existed simply
//! skip the check. The BFS-sampled path metrics (`effective_diameter`,
//! `cpl`, measured at the pinned
//! [`crate::harness::runner::BFS_SAMPLES`]/[`crate::harness::runner::BFS_SEED`]
//! schedule) are **required** on a pinned golden: a pinned document
//! missing either field is a config error, never a silent skip —
//! re-bless (`sgg test --bless`) to pin them. A golden with
//! `"pinned": false` — the checked-in
//! placeholder state — or a missing file is *blessed*: the measured
//! profile is written back pinned, so the repository converges to real
//! measured goldens on the first `sgg test` run in any environment.

use super::runner::MetricProfile;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// Default tolerance written when blessing a scalar metric.
pub const DEFAULT_TOL: f64 = 1e-9;

/// One golden check: a named quantity, what the golden pins, what this
/// run measured, and whether it is within tolerance.
#[derive(Clone, Debug)]
pub struct MetricCheck {
    /// Quantity name (`edges`, `shards`, `edge_checksum`, `degree_dist`,
    /// `dcc`).
    pub name: String,
    /// Pinned golden value.
    pub expected: f64,
    /// Measured value.
    pub measured: f64,
    /// Allowed absolute deviation (0 for exact counts).
    pub tol: f64,
    /// `|measured - expected| <= tol`.
    pub passed: bool,
}

impl MetricCheck {
    fn new(name: &str, expected: f64, measured: f64, tol: f64) -> MetricCheck {
        MetricCheck {
            name: name.to_string(),
            expected,
            measured,
            tol,
            passed: (measured - expected).abs() <= tol,
        }
    }
}

impl std::fmt::Display for MetricCheck {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: expected {} ± {}, measured {}",
            self.name, self.expected, self.tol, self.measured
        )
    }
}

/// What [`compare_or_bless`] did.
#[derive(Clone, Debug)]
pub enum GoldenOutcome {
    /// A pinned golden existed and every check passed.
    Matched(Vec<MetricCheck>),
    /// A pinned golden existed and at least one check failed.
    Mismatched(Vec<MetricCheck>),
    /// No pinned golden (missing file, `"pinned": false`, or `--bless`):
    /// the measured profile was written back as the new pinned golden.
    Blessed,
}

/// Compare `measured` against the golden at `path`, or bless the golden
/// from the measurement when it is missing/unpinned (or `bless` forces
/// it).
pub fn compare_or_bless(
    path: &Path,
    measured: &MetricProfile,
    bless: bool,
) -> Result<GoldenOutcome> {
    let golden = match std::fs::read_to_string(path) {
        Ok(text) => Some(Json::parse(&text).map_err(|e| {
            Error::Config(format!("golden {} is not valid JSON: {e}", path.display()))
        })?),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
        Err(e) => {
            return Err(Error::Config(format!(
                "cannot read golden {}: {e}",
                path.display()
            )));
        }
    };
    let pinned = golden
        .as_ref()
        .and_then(|g| g.get("pinned"))
        .and_then(|p| p.as_bool())
        .unwrap_or(false);
    if bless || !pinned {
        write_golden(path, measured, golden.as_ref())?;
        return Ok(GoldenOutcome::Blessed);
    }
    let g = golden.expect("pinned implies parsed");
    let checks = check_all(&g, measured, path)?;
    if checks.iter().all(|c| c.passed) {
        Ok(GoldenOutcome::Matched(checks))
    } else {
        Ok(GoldenOutcome::Mismatched(checks))
    }
}

/// Run every check a pinned golden defines.
fn check_all(g: &Json, m: &MetricProfile, path: &Path) -> Result<Vec<MetricCheck>> {
    let bad = |what: &str| {
        Error::Config(format!("golden {} is missing `{what}`", path.display()))
    };
    let edges = g.get("edges").and_then(|v| v.as_f64()).ok_or_else(|| bad("edges"))?;
    let shards = g.get("shards").and_then(|v| v.as_f64()).ok_or_else(|| bad("shards"))?;
    let mut checks = vec![
        MetricCheck::new("edges", edges, m.edges as f64, 0.0),
        MetricCheck::new("shards", shards, m.shards as f64, 0.0),
    ];
    // Optional for back-compat: goldens pinned before the decoded-edge
    // checksum existed skip this check until re-blessed. Compared as
    // exact u64s (the f64 fields are display-only approximations, since
    // a u64 doesn't fit in 53 mantissa bits).
    if let Some(entry) = g.get("edge_checksum") {
        let hex = entry.as_str().ok_or_else(|| bad("edge_checksum"))?;
        let expected = u64::from_str_radix(hex, 16).map_err(|_| {
            Error::Config(format!(
                "golden {}: `edge_checksum` is not a hex u64 (got `{hex}`)",
                path.display()
            ))
        })?;
        checks.push(MetricCheck {
            name: "edge_checksum".to_string(),
            expected: expected as f64,
            measured: m.edge_checksum as f64,
            tol: 0.0,
            passed: expected == m.edge_checksum,
        });
    }
    let metrics = g.get("metrics").ok_or_else(|| bad("metrics"))?;
    for (name, got) in [("degree_dist", m.degree_dist), ("dcc", m.dcc)] {
        let entry = metrics.get(name).ok_or_else(|| bad(name))?;
        let value =
            entry.get("value").and_then(|v| v.as_f64()).ok_or_else(|| bad(name))?;
        let tol = entry
            .get("tol")
            .and_then(|v| v.as_f64())
            .unwrap_or(DEFAULT_TOL);
        checks.push(MetricCheck::new(name, value, got, tol));
    }
    // Required since the goldens were re-blessed with BFS path metrics:
    // a pinned golden missing either field errors loudly (ROADMAP 6(c))
    // instead of silently skipping the check — re-bless to pin them.
    for (name, got) in [("effective_diameter", m.effective_diameter), ("cpl", m.cpl)] {
        let entry = metrics.get(name).ok_or_else(|| bad(name))?;
        let value =
            entry.get("value").and_then(|v| v.as_f64()).ok_or_else(|| bad(name))?;
        let tol = entry
            .get("tol")
            .and_then(|v| v.as_f64())
            .unwrap_or(DEFAULT_TOL);
        checks.push(MetricCheck::new(name, value, got, tol));
    }
    Ok(checks)
}

/// Write `measured` as a pinned golden, keeping any tolerances the
/// previous (placeholder or stale) golden carried.
fn write_golden(path: &Path, m: &MetricProfile, prev: Option<&Json>) -> Result<()> {
    let tol_of = |name: &str| {
        prev.and_then(|g| g.get("metrics"))
            .and_then(|ms| ms.get(name))
            .and_then(|e| e.get("tol"))
            .and_then(|t| t.as_f64())
            .unwrap_or(DEFAULT_TOL)
    };
    let metric = |value: f64, tol: f64| {
        Json::obj(vec![("value", Json::from(value)), ("tol", Json::from(tol))])
    };
    let doc = Json::obj(vec![
        ("pinned", Json::from(true)),
        ("edges", Json::from(m.edges)),
        ("shards", Json::from(m.shards)),
        ("edge_checksum", Json::from(format!("{:016x}", m.edge_checksum))),
        (
            "metrics",
            Json::obj(vec![
                ("degree_dist", metric(m.degree_dist, tol_of("degree_dist"))),
                ("dcc", metric(m.dcc, tol_of("dcc"))),
                (
                    "effective_diameter",
                    metric(m.effective_diameter, tol_of("effective_diameter")),
                ),
                ("cpl", metric(m.cpl, tol_of("cpl"))),
            ]),
        ),
    ]);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Config(format!("cannot create {}: {e}", dir.display())))?;
    }
    std::fs::write(path, format!("{doc}\n"))
        .map_err(|e| Error::Config(format!("cannot write golden {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("sgg_cmp_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn profile() -> MetricProfile {
        MetricProfile {
            edges: 1200,
            shards: 3,
            degree_dist: 0.875,
            dcc: 0.6125,
            profile_hash: 42,
            // deliberately > 2^53 so the test fails if the comparator
            // ever routes the checksum through f64 equality
            edge_checksum: 0xdead_beef_cafe_f00d,
            effective_diameter: 3.25,
            cpl: 2.5,
        }
    }

    #[test]
    fn missing_golden_blesses_then_matches_exactly() {
        let dir = tmp("bless");
        let path = dir.join("g.json");
        let m = profile();
        assert!(matches!(
            compare_or_bless(&path, &m, false).unwrap(),
            GoldenOutcome::Blessed
        ));
        // the blessed golden round-trips to a full match
        match compare_or_bless(&path, &m, false).unwrap() {
            GoldenOutcome::Matched(checks) => {
                assert_eq!(checks.len(), 7);
                assert!(checks.iter().all(|c| c.passed));
            }
            other => panic!("expected match, got {other:?}"),
        }
        // the checksum is stored as a hex string, not a lossy JSON number
        let g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            g.get("edge_checksum").unwrap().as_str(),
            Some("deadbeefcafef00d")
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn edge_checksum_mismatches_exactly_and_old_goldens_skip_it() {
        let dir = tmp("checksum");
        let path = dir.join("g.json");
        compare_or_bless(&path, &profile(), false).unwrap();

        // a 1-bit decoded-edge difference fails even though the f64
        // projections of the two checksums are equal
        let mut off = profile();
        off.edge_checksum ^= 1;
        assert_eq!(off.edge_checksum as f64, profile().edge_checksum as f64);
        match compare_or_bless(&path, &off, false).unwrap() {
            GoldenOutcome::Mismatched(checks) => {
                let bad: Vec<_> = checks.iter().filter(|c| !c.passed).collect();
                assert_eq!(bad.len(), 1);
                assert_eq!(bad[0].name, "edge_checksum");
            }
            other => panic!("expected mismatch, got {other:?}"),
        }

        // a pre-checksum golden (no field) runs only the legacy checks
        let mut g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(o) = &mut g {
            o.remove("edge_checksum");
        }
        std::fs::write(&path, g.to_string()).unwrap();
        match compare_or_bless(&path, &off, false).unwrap() {
            GoldenOutcome::Matched(checks) => assert_eq!(checks.len(), 6),
            other => panic!("expected legacy match, got {other:?}"),
        }

        // a malformed checksum string is a config error, not a pass
        if let Json::Obj(o) = &mut g {
            o.insert("edge_checksum".into(), Json::from("not-hex"));
        }
        std::fs::write(&path, g.to_string()).unwrap();
        let err = compare_or_bless(&path, &off, false).unwrap_err();
        assert!(err.to_string().contains("hex"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pinned_golden_missing_bfs_fields_is_config_error() {
        let dir = tmp("reqbfs");
        let path = dir.join("g.json");
        compare_or_bless(&path, &profile(), false).unwrap();
        // a pinned golden that drops a BFS path metric (the pre-re-bless
        // state) must fail loudly instead of silently skipping the check
        for dropped in ["effective_diameter", "cpl"] {
            let mut g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            if let Json::Obj(o) = &mut g {
                if let Some(Json::Obj(ms)) = o.get_mut("metrics") {
                    ms.remove(dropped);
                }
            }
            let stale = dir.join(format!("stale_{dropped}.json"));
            std::fs::write(&stale, g.to_string()).unwrap();
            let err = compare_or_bless(&stale, &profile(), false).unwrap_err();
            assert!(err.to_string().contains(dropped), "{err}");
        }
        // re-blessing a stale golden restores the full 7-check pin,
        // including the BFS fields
        let stale = dir.join("stale_effective_diameter.json");
        compare_or_bless(&stale, &profile(), true).unwrap();
        match compare_or_bless(&stale, &profile(), false).unwrap() {
            GoldenOutcome::Matched(checks) => {
                assert_eq!(checks.len(), 7);
                assert!(checks.iter().any(|c| c.name == "effective_diameter"));
                assert!(checks.iter().any(|c| c.name == "cpl"));
            }
            other => panic!("expected match, got {other:?}"),
        }
        // and a pinned BFS drift is a mismatch, not a skip
        let mut moved = profile();
        moved.effective_diameter += 10.0;
        match compare_or_bless(&path, &moved, false).unwrap() {
            GoldenOutcome::Mismatched(checks) => {
                let bad: Vec<_> = checks.iter().filter(|c| !c.passed).collect();
                assert_eq!(bad.len(), 1);
                assert_eq!(bad[0].name, "effective_diameter");
            }
            other => panic!("expected mismatch, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unpinned_placeholder_is_blessed_and_keeps_its_tolerances() {
        let dir = tmp("placeholder");
        let path = dir.join("g.json");
        std::fs::write(
            &path,
            r#"{"pinned": false, "metrics": {"degree_dist": {"tol": 0.05}, "dcc": {}}}"#,
        )
        .unwrap();
        assert!(matches!(
            compare_or_bless(&path, &profile(), false).unwrap(),
            GoldenOutcome::Blessed
        ));
        let g = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(g.get("pinned").unwrap().as_bool(), Some(true));
        let dd = g.get("metrics").unwrap().get("degree_dist").unwrap();
        assert_eq!(dd.get("tol").unwrap().as_f64(), Some(0.05));
        assert_eq!(dd.get("value").unwrap().as_f64(), Some(0.875));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn drift_beyond_tolerance_mismatches() {
        let dir = tmp("drift");
        let path = dir.join("g.json");
        compare_or_bless(&path, &profile(), false).unwrap();
        let mut drifted = profile();
        drifted.degree_dist += 1e-3;
        match compare_or_bless(&path, &drifted, false).unwrap() {
            GoldenOutcome::Mismatched(checks) => {
                let bad: Vec<_> = checks.iter().filter(|c| !c.passed).collect();
                assert_eq!(bad.len(), 1);
                assert_eq!(bad[0].name, "degree_dist");
            }
            other => panic!("expected mismatch, got {other:?}"),
        }
        // --bless overwrites the pin with the new measurement
        assert!(matches!(
            compare_or_bless(&path, &drifted, true).unwrap(),
            GoldenOutcome::Blessed
        ));
        assert!(matches!(
            compare_or_bless(&path, &drifted, false).unwrap(),
            GoldenOutcome::Matched(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_golden_is_config_error() {
        let dir = tmp("bad");
        let path = dir.join("g.json");
        std::fs::write(&path, "not json").unwrap();
        assert!(compare_or_bless(&path, &profile(), false).is_err());
        // pinned but incomplete documents also error rather than pass
        std::fs::write(&path, r#"{"pinned": true, "edges": 1200}"#).unwrap();
        assert!(compare_or_bless(&path, &profile(), false).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
