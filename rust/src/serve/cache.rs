//! Content-addressed model cache for the generation service.
//!
//! Fitted pipelines are stored under their FNV-1a content hash
//! (`<16-hex>.sggm`, the hash of the serialized artifact bytes), so a
//! model reference in a submitted scenario is a stable, host-portable
//! name: `model = "a1b2c3d4e5f60718"` resolves to the same bytes on any
//! server that has seen the artifact. `POST /fit` memoizes on a second
//! key — a canonical digest of the fit-relevant spec fields — mapping
//! "what you asked to fit" onto "the artifact that fit produced", so
//! refitting an identical spec is a cache hit that never touches the
//! dataset.

use crate::pipeline::spec::{ComponentSpec, NodeFeatureSpec, ScenarioSpec, Value};
use crate::pipeline::FittedPipeline;
use crate::util::checksum::{fnv1a_bytes, fnv1a_file};
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter making concurrent temp-file names unique within
/// the process (the pid makes them unique across processes).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Render a content hash the way the HTTP API spells it: 16 lowercase
/// hex digits, zero-padded.
pub fn hash_hex(hash: u64) -> String {
    format!("{hash:016x}")
}

/// Parse a 16-hex-digit content hash. `None` for anything else — the
/// strict shape check doubles as the path-traversal guard for
/// `GET /artifacts/<hash>`.
pub fn parse_hash(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// A directory of content-addressed `.sggm` artifacts plus fit-key
/// memo files. All writes are atomic (temp file + rename), so a cache
/// shared by concurrent requests never exposes a partial artifact.
#[derive(Debug)]
pub struct ArtifactCache {
    dir: PathBuf,
}

impl ArtifactCache {
    /// Open (creating if needed) a cache rooted at `dir`.
    pub fn open(dir: &Path) -> Result<ArtifactCache> {
        std::fs::create_dir_all(dir)?;
        Ok(ArtifactCache { dir: dir.to_path_buf() })
    }

    /// Root directory of the cache.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path an artifact with this content hash lives at (whether or not
    /// it exists yet).
    pub fn model_path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("{}.sggm", hash_hex(hash)))
    }

    /// Path of a cached artifact, `None` when the hash is unknown.
    pub fn lookup_model(&self, hash: u64) -> Option<PathBuf> {
        let path = self.model_path(hash);
        path.is_file().then_some(path)
    }

    /// Serialize `fitted` into the cache and return its content hash.
    /// The artifact is written to a temp file, hashed, and renamed into
    /// place; storing bytes that already exist is a no-op rename.
    pub fn store_model(&self, fitted: &FittedPipeline) -> Result<u64> {
        let tmp = self.tmp_path();
        fitted.save(&tmp)?;
        let hash = fnv1a_file(&tmp)?;
        let dest = self.model_path(hash);
        std::fs::rename(&tmp, &dest)?;
        Ok(hash)
    }

    /// Model hash previously recorded for this fit key, validated
    /// against the artifact store (a dangling key is a miss).
    pub fn lookup_fit(&self, key: u64) -> Option<u64> {
        let text = std::fs::read_to_string(self.fit_key_path(key)).ok()?;
        let hash = parse_hash(text.trim())?;
        self.lookup_model(hash).map(|_| hash)
    }

    /// Record that fitting the spec digested as `key` produced the
    /// artifact `hash`.
    pub fn record_fit(&self, key: u64, hash: u64) -> Result<()> {
        let tmp = self.tmp_path();
        std::fs::write(&tmp, format!("{}\n", hash_hex(hash)))?;
        std::fs::rename(&tmp, self.fit_key_path(key))?;
        Ok(())
    }

    /// Canonical digest of the fields that determine a fit's outcome:
    /// dataset (+ its seed), generation seed, and the four component
    /// selections with their parameters. Size, sink, worker count, and
    /// evaluation flags don't participate — they shape generation, not
    /// the fitted model.
    pub fn fit_key(&self, spec: &ScenarioSpec) -> u64 {
        let mut canon = String::new();
        canon.push_str(&format!(
            "dataset={};dataset_seed={};seed={};",
            spec.dataset, spec.dataset_seed, spec.seed
        ));
        push_component(&mut canon, "structure", &spec.structure);
        push_component(&mut canon, "edge_features", &spec.edge_features);
        match &spec.node_features {
            NodeFeatureSpec::Auto => canon.push_str("node_features=auto;"),
            NodeFeatureSpec::Off => canon.push_str("node_features=off;"),
            NodeFeatureSpec::Component(c) => push_component(&mut canon, "node_features", c),
        }
        push_component(&mut canon, "aligner", &spec.aligner);
        fnv1a_bytes(canon.as_bytes())
    }

    /// Rewrite a `model = "<16-hex>"` reference onto the cached artifact
    /// path. References that already name an existing file pass through
    /// untouched; a hash-shaped reference not present in the cache is an
    /// error (the client should `POST /fit` or upload first).
    pub fn resolve_model_ref(&self, spec: &mut ScenarioSpec) -> Result<()> {
        let Some(path) = &spec.model else { return Ok(()) };
        if path.is_file() {
            return Ok(());
        }
        let name = path.to_string_lossy();
        match parse_hash(&name) {
            Some(hash) => match self.lookup_model(hash) {
                Some(cached) => {
                    spec.model = Some(cached);
                    Ok(())
                }
                None => Err(Error::Config(format!(
                    "model `{name}` is not in the artifact cache; fit it first"
                ))),
            },
            None => Err(Error::Config(format!(
                "model `{name}` is neither a file nor a 16-hex artifact hash"
            ))),
        }
    }

    fn fit_key_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("fit-{}.key", hash_hex(key)))
    }

    fn tmp_path(&self) -> PathBuf {
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        self.dir.join(format!(".store-{}-{seq}.tmp", std::process::id()))
    }
}

/// Append one component's canonical form: name plus every parameter in
/// `Params`' sorted key order. Numbers are digested by their IEEE bits
/// so the key never depends on float formatting.
fn push_component(out: &mut String, slot: &str, c: &ComponentSpec) {
    out.push_str(&format!("{slot}={}(", c.name));
    for (k, v) in c.params.iter() {
        match v {
            Value::Str(s) => out.push_str(&format!("{k}=s:{s},")),
            Value::Num(n) => out.push_str(&format!("{k}=n:{:016x},", n.to_bits())),
            Value::Bool(b) => out.push_str(&format!("{k}=b:{b},")),
        }
    }
    out.push_str(");");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("sgg_cache_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    const SPEC: &str = r#"
dataset = "travel-insurance"
seed = 5

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"
"#;

    #[test]
    fn hash_roundtrips_and_rejects_bad_shapes() {
        assert_eq!(parse_hash(&hash_hex(0xdead_beef_0102_0304)), Some(0xdead_beef_0102_0304));
        assert_eq!(parse_hash("0000000000000000"), Some(0));
        assert_eq!(parse_hash("short"), None);
        assert_eq!(parse_hash("../../etc/passwd!"), None);
        assert_eq!(parse_hash("00000000000000000"), None);
    }

    #[test]
    fn fit_key_tracks_fit_relevant_fields_only() {
        let cache = ArtifactCache::open(&tmp("key")).unwrap();
        let base = ScenarioSpec::parse(SPEC).unwrap();
        let mut same = base.clone();
        same.workers = 7;
        same.evaluate = true;
        assert_eq!(cache.fit_key(&base), cache.fit_key(&same));
        let mut other = base.clone();
        other.seed = 6;
        assert_ne!(cache.fit_key(&base), cache.fit_key(&other));
        let mut comp = base.clone();
        comp.structure.name = "kronecker".into();
        assert_ne!(cache.fit_key(&base), cache.fit_key(&comp));
        std::fs::remove_dir_all(cache.dir()).ok();
    }

    #[test]
    fn store_lookup_and_fit_memo_roundtrip() {
        let cache = ArtifactCache::open(&tmp("store")).unwrap();
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        let ds = crate::datasets::load(&spec.dataset, spec.dataset_seed).unwrap();
        let fitted =
            spec.to_builder().fit_with(&ds, &crate::pipeline::Registries::builtin()).unwrap();
        let hash = cache.store_model(&fitted).unwrap();
        let path = cache.lookup_model(hash).unwrap();
        assert_eq!(fnv1a_file(&path).unwrap(), hash);

        let key = cache.fit_key(&spec);
        assert_eq!(cache.lookup_fit(key), None);
        cache.record_fit(key, hash).unwrap();
        assert_eq!(cache.lookup_fit(key), Some(hash));

        // a model reference by hash resolves onto the cached path
        let mut by_ref = ScenarioSpec::parse(&format!("model = \"{}\"\n", hash_hex(hash))).unwrap();
        cache.resolve_model_ref(&mut by_ref).unwrap();
        assert_eq!(by_ref.model.as_deref(), Some(path.as_path()));
        let mut missing = ScenarioSpec::parse("model = \"ffffffffffffffff\"\n").unwrap();
        assert!(cache.resolve_model_ref(&mut missing).is_err());
        std::fs::remove_dir_all(cache.dir()).ok();
    }
}
