//! Request/response bodies of the HTTP API, built on the canonical
//! [`Json`] writer so every byte the service emits is reproducible:
//! object keys sort, numbers follow the shared formatting rules, and
//! progress lines are the exact [`crate::pipeline::StreamReport`]
//! serialization `sgg run --json` prints.

use super::cache::hash_hex;
use super::jobs::{Job, JobState};
use crate::util::json::Json;

/// `{"error": <msg>}` — every non-2xx body.
pub fn error(msg: &str) -> Json {
    Json::obj(vec![("error", Json::from(msg))])
}

/// `{"job": <id>}` — `POST /jobs` accepted.
pub fn job_accepted(id: u64) -> Json {
    Json::obj(vec![("job", Json::u64_exact(id))])
}

/// `{"cancelled": true, "job": <id>}` — `DELETE /jobs/<id>`.
pub fn job_cancelled(id: u64) -> Json {
    Json::obj(vec![("cancelled", Json::Bool(true)), ("job", Json::u64_exact(id))])
}

/// `{"cached": <bool>, "model": <16-hex>}` — `POST /fit`.
pub fn fit_response(hash: u64, cached: bool) -> Json {
    Json::obj(vec![("cached", Json::Bool(cached)), ("model", Json::from(hash_hex(hash)))])
}

/// Point-in-time job snapshot: `GET /jobs/<id>?wait=0`.
///
/// `report` is the final [`crate::pipeline::StreamReport`] for done
/// jobs, the latest in-flight snapshot while running (or after a
/// mid-run cancel), and `null` before the first progress update.
/// `error` is non-null only for failed jobs.
pub fn job_status(job: &Job) -> Json {
    let state = job.state();
    let report = match &state {
        JobState::Done(r) => r.to_json(),
        _ => job.progress().map(|r| r.to_json()).unwrap_or(Json::Null),
    };
    let error = match &state {
        JobState::Failed(msg) => Json::from(msg.as_str()),
        _ => Json::Null,
    };
    Json::obj(vec![
        ("error", error),
        ("job", Json::u64_exact(job.id())),
        ("report", report),
        ("state", Json::from(state.label())),
    ])
}

/// Terminal line of a streamed `GET /jobs/<id>` body. Done jobs close
/// with the verbatim final [`crate::pipeline::StreamReport`] (quality
/// scores included when the scenario evaluated); failed and cancelled
/// jobs close with an `{"error": ...}` / `{"cancelled": true}` marker
/// so clients can always classify the last line by its keys.
pub fn terminal_line(state: &JobState) -> Option<Json> {
    match state {
        JobState::Done(r) => Some(r.to_json()),
        JobState::Failed(msg) => Some(error(msg)),
        JobState::Cancelled => Some(Json::obj(vec![("cancelled", Json::Bool(true))])),
        JobState::Queued | JobState::Running => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bodies_serialize_with_sorted_keys() {
        assert_eq!(job_accepted(3).to_string(), "{\"job\":3}");
        assert_eq!(job_cancelled(3).to_string(), "{\"cancelled\":true,\"job\":3}");
        assert_eq!(
            fit_response(0xdead_beef_0102_0304, true).to_string(),
            "{\"cached\":true,\"model\":\"deadbeef01020304\"}"
        );
        assert_eq!(error("nope").to_string(), "{\"error\":\"nope\"}");
    }

    #[test]
    fn terminal_lines_classify_by_keys() {
        assert!(terminal_line(&JobState::Queued).is_none());
        assert!(terminal_line(&JobState::Running).is_none());
        let cancelled = terminal_line(&JobState::Cancelled).unwrap().to_string();
        assert_eq!(cancelled, "{\"cancelled\":true}");
        let failed = terminal_line(&JobState::Failed("boom".into())).unwrap();
        assert_eq!(failed.get("error").and_then(|j| j.as_str()), Some("boom"));
    }
}
