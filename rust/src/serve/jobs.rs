//! Bounded job queue + worker pool for the generation service.
//!
//! Submitted scenarios become [`Job`]s: queued in a [`Bounded`] channel
//! whose capacity is the admission-control knob (a full queue rejects
//! with [`SubmitError::QueueFull`], which the HTTP layer maps to `429`
//! + `Retry-After`), then executed by a fixed pool of worker threads
//! via [`crate::pipeline::run_scenario_opts`]. Each job carries a
//! [`CancelToken`] (tripped by `DELETE /jobs/<id>`, aborting at the
//! next chunk boundary through the runner's first-error path) and a
//! [`ProgressHandle`] the shard sink publishes [`StreamReport`]
//! snapshots into, which `GET /jobs/<id>` streams back out.

use crate::pipeline::spec::{ScenarioSpec, SinkSpec};
use crate::pipeline::{
    run_scenario_opts, CancelToken, ProgressHandle, Registries, RunOptions, SinkOutput,
    StreamReport,
};
use crate::util::threadpool::Bounded;
use std::sync::{Arc, Mutex};

/// Lifecycle of one submitted job.
#[derive(Clone, Debug)]
pub enum JobState {
    /// Admitted, waiting for a worker.
    Queued,
    /// A worker is generating.
    Running,
    /// Finished; the final [`StreamReport`] (with quality scores when
    /// the scenario asked to `[evaluate]`).
    Done(StreamReport),
    /// Generation failed.
    Failed(String),
    /// Cancelled before or during generation. Shards written before the
    /// abort form a consecutive, resumable prefix on disk.
    Cancelled,
}

impl JobState {
    /// Short lowercase label used by the HTTP status body.
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// True once the job will never change state again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_) | JobState::Cancelled)
    }
}

/// Why a submission was not admitted.
#[derive(Clone, Debug)]
pub enum SubmitError {
    /// The bounded queue is at capacity — retry later (`429`).
    QueueFull,
    /// The spec cannot run as a service job.
    Invalid(String),
}

/// One admitted generation job.
#[derive(Debug)]
pub struct Job {
    id: u64,
    spec: ScenarioSpec,
    state: Mutex<JobState>,
    cancel: CancelToken,
    progress: ProgressHandle,
}

impl Job {
    /// Server-assigned id (dense, starting at 0).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The submitted scenario.
    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    /// Current lifecycle state (cloned snapshot).
    pub fn state(&self) -> JobState {
        self.state.lock().unwrap().clone()
    }

    /// Latest in-flight [`StreamReport`] published by the shard sink,
    /// `None` until the first shard-path progress update.
    pub fn progress(&self) -> Option<StreamReport> {
        self.progress.lock().unwrap().clone()
    }

    fn set_state(&self, next: JobState) {
        *self.state.lock().unwrap() = next;
    }
}

/// The service's job registry, admission queue, and worker pool.
pub struct JobManager {
    jobs: Mutex<Vec<Arc<Job>>>,
    queue: Bounded<u64>,
}

impl JobManager {
    /// Start a manager with `workers` executor threads and an admission
    /// queue of `queue_depth` jobs. `workers == 0` starts no executors —
    /// jobs are admitted but never run, which pins queue occupancy and
    /// makes the `429` path deterministic to test.
    pub fn start(workers: usize, queue_depth: usize) -> Arc<JobManager> {
        let mgr = Arc::new(JobManager {
            jobs: Mutex::new(Vec::new()),
            queue: Bounded::new(queue_depth.max(1)),
        });
        for _ in 0..workers {
            let m = Arc::clone(&mgr);
            std::thread::spawn(move || m.worker_loop());
        }
        mgr
    }

    /// Admit a scenario. Fails with [`SubmitError::Invalid`] for memory
    /// sinks (a service job's output must outlive the request) and with
    /// [`SubmitError::QueueFull`] when the bounded queue rejects.
    pub fn submit(&self, spec: ScenarioSpec) -> Result<Arc<Job>, SubmitError> {
        if matches!(spec.sink, SinkSpec::Memory) {
            return Err(SubmitError::Invalid(
                "service jobs need `[sink] kind = \"shards\"`; memory-sink output \
                 would vanish with the request"
                    .into(),
            ));
        }
        let mut jobs = self.jobs.lock().unwrap();
        let id = jobs.len() as u64;
        let job = Arc::new(Job {
            id,
            spec,
            state: Mutex::new(JobState::Queued),
            cancel: CancelToken::new(),
            progress: Arc::new(Mutex::new(None)),
        });
        if self.queue.try_send(id).is_err() {
            return Err(SubmitError::QueueFull);
        }
        jobs.push(Arc::clone(&job));
        Ok(job)
    }

    /// Look up a job by id.
    pub fn get(&self, id: u64) -> Option<Arc<Job>> {
        self.jobs.lock().unwrap().get(id as usize).cloned()
    }

    /// Trip a job's cancel token. Queued jobs flip to
    /// [`JobState::Cancelled`] immediately; running jobs abort at the
    /// next chunk boundary (the outermost [`crate::pipeline::CancelSink`]
    /// surfaces a fatal worker error the pool drains on). Returns
    /// `false` for unknown ids.
    pub fn cancel(&self, id: u64) -> bool {
        let Some(job) = self.get(id) else { return false };
        job.cancel.cancel();
        let mut state = job.state.lock().unwrap();
        if matches!(*state, JobState::Queued) {
            *state = JobState::Cancelled;
        }
        true
    }

    /// Close the admission queue; idle workers exit once it drains.
    pub fn shutdown(&self) {
        self.queue.close();
    }

    fn worker_loop(&self) {
        while let Some(id) = self.queue.recv() {
            let Some(job) = self.get(id) else { continue };
            self.run(&job);
        }
    }

    fn run(&self, job: &Job) {
        if job.cancel.is_cancelled() {
            job.set_state(JobState::Cancelled);
            return;
        }
        job.set_state(JobState::Running);
        // resume=true on evaluate-free jobs: a fresh directory has
        // watermark 0 (output identical to a non-resuming run), and a
        // directory left behind by a killed server picks up after its
        // last complete shard. Evaluated jobs must see every chunk, so
        // they always start clean.
        let opts = RunOptions {
            resume: !job.spec.evaluate,
            cancel: Some(job.cancel.clone()),
            progress: Some(Arc::clone(&job.progress)),
            ..RunOptions::default()
        };
        match run_scenario_opts(&job.spec, &Registries::builtin(), opts) {
            Ok(SinkOutput::Streamed(report)) => job.set_state(JobState::Done(report)),
            Ok(SinkOutput::Dataset(_)) => {
                job.set_state(JobState::Failed("memory-sink output in a service job".into()))
            }
            Err(_) if job.cancel.is_cancelled() => job.set_state(JobState::Cancelled),
            Err(e) => job.set_state(JobState::Failed(e.to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("sgg_jobs_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn shard_spec(dir: &std::path::Path) -> ScenarioSpec {
        ScenarioSpec::parse(&format!(
            r#"
dataset = "travel-insurance"
seed = 11
workers = 2

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[sink]
kind = "shards"
dir = "{}"
"#,
            dir.display()
        ))
        .unwrap()
    }

    #[test]
    fn memory_sink_specs_are_rejected() {
        let mgr = JobManager::start(0, 2);
        let spec = ScenarioSpec::parse("dataset = \"travel-insurance\"\n").unwrap();
        match mgr.submit(spec) {
            Err(SubmitError::Invalid(msg)) => assert!(msg.contains("shards"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        mgr.shutdown();
    }

    #[test]
    fn full_queue_rejects_and_queued_jobs_cancel_immediately() {
        let dir = tmp("full");
        // no workers: admitted jobs stay queued, so occupancy is pinned
        let mgr = JobManager::start(0, 1);
        let first = mgr.submit(shard_spec(&dir.join("a"))).unwrap();
        match mgr.submit(shard_spec(&dir.join("b"))) {
            Err(SubmitError::QueueFull) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert!(mgr.cancel(first.id()));
        assert!(matches!(first.state(), JobState::Cancelled));
        assert!(!mgr.cancel(99));
        mgr.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jobs_run_to_done_with_progress_snapshots() {
        let dir = tmp("run");
        let mgr = JobManager::start(1, 4);
        let job = mgr.submit(shard_spec(&dir)).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            let state = job.state();
            if state.is_terminal() {
                match state {
                    JobState::Done(report) => {
                        assert!(report.shards > 0);
                        assert!(report.edges_written > 0);
                    }
                    other => panic!("expected Done, got {other:?}"),
                }
                break;
            }
            assert!(std::time::Instant::now() < deadline, "job did not finish");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let last = job.progress().expect("shard sink published progress");
        let done_shards = match job.state() {
            JobState::Done(r) => r.shards,
            _ => unreachable!(),
        };
        assert_eq!(last.shards, done_shards);
        mgr.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
