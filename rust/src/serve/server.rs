//! Hand-rolled HTTP/1.1 generation service (`sgg serve`).
//!
//! A [`std::net::TcpListener`] accept loop dispatches one thread per
//! connection; requests are parsed from the raw socket (request line,
//! headers, `Content-Length` body — the subset the API needs), routed,
//! and answered with canonical-JSON bodies from [`super::api`]. There
//! is no TLS, no keep-alive, and no chunked transfer coding: every
//! response closes the connection, and the streaming `GET /jobs/<id>`
//! body is newline-delimited JSON terminated by connection close.
//!
//! Routes:
//!
//! | Method + path            | Behaviour                                      |
//! |--------------------------|------------------------------------------------|
//! | `POST /jobs`             | Submit a scenario (TOML body) → `202 {"job"}`  |
//! | `GET /jobs/<id>`         | Stream progress lines until terminal           |
//! | `GET /jobs/<id>?wait=0`  | One status snapshot, no blocking               |
//! | `DELETE /jobs/<id>`      | Cancel (abort at the next chunk boundary)      |
//! | `POST /fit`              | Fit-and-cache (TOML body) → `{"model","cached"}` |
//! | `GET /artifacts/<hash>`  | Fetch a cached `.sggm` artifact                |
//!
//! A full admission queue answers `429` with `Retry-After`.

use super::api;
use super::cache::{parse_hash, ArtifactCache};
use super::jobs::{JobManager, SubmitError};
use crate::pipeline::spec::ScenarioSpec;
use crate::pipeline::Registries;
use crate::util::json::Json;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Largest accepted request body (scenario TOML is tiny; this is a
/// hard stop against junk input, answered with `400`).
const MAX_BODY: usize = 1 << 20;

/// Poll interval of the streaming `GET /jobs/<id>` body.
const STREAM_POLL: Duration = Duration::from_millis(50);

/// Configuration of one [`Server`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port —
    /// read it back from [`Server::local_addr`]).
    pub addr: String,
    /// Artifact-cache directory (created if missing).
    pub cache_dir: std::path::PathBuf,
    /// Job executor threads. `0` admits jobs without running them
    /// (test/drain mode); the CLI maps `0` to one per core instead.
    pub workers: usize,
    /// Admission-queue depth — jobs beyond this are answered `429`.
    pub queue_depth: usize,
}

/// A bound generation service, ready to [`Server::run`] on the caller
/// thread or [`Server::spawn`] in the background.
pub struct Server {
    listener: TcpListener,
    jobs: Arc<JobManager>,
    cache: Arc<ArtifactCache>,
    shutdown: Arc<AtomicBool>,
}

/// Handle to a background server: address + clean shutdown.
#[derive(Debug)]
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl ServerHandle {
    /// The bound listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept loop. In-flight
    /// connection threads finish on their own; queued jobs are dropped
    /// with the closed queue.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Release);
        // unblock the accept loop with a no-op connection
        let _ = TcpStream::connect(self.addr);
        let _ = self.thread.join();
    }
}

impl Server {
    /// Bind the listener, open the artifact cache, and start the job
    /// worker pool.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let cache = Arc::new(ArtifactCache::open(&cfg.cache_dir)?);
        let jobs = JobManager::start(cfg.workers, cfg.queue_depth);
        Ok(Server { listener, jobs, cache, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve on the caller thread until shut down (the CLI entry).
    pub fn run(self) -> Result<()> {
        self.accept_loop();
        Ok(())
    }

    /// Serve on a background thread; the returned handle stops it.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let shutdown = Arc::clone(&self.shutdown);
        let thread = std::thread::spawn(move || self.accept_loop());
        Ok(ServerHandle { addr, shutdown, thread })
    }

    fn accept_loop(self) {
        for conn in self.listener.incoming() {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let jobs = Arc::clone(&self.jobs);
            let cache = Arc::clone(&self.cache);
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &jobs, &cache);
            });
        }
        self.jobs.shutdown();
    }
}

/// One parsed request.
struct Request {
    method: String,
    path: String,
    query: String,
    body: String,
}

fn handle_connection(
    mut stream: TcpStream,
    jobs: &Arc<JobManager>,
    cache: &Arc<ArtifactCache>,
) -> std::io::Result<()> {
    let req = match read_request(&stream) {
        Ok(req) => req,
        Err(msg) => return respond_json(&mut stream, 400, "Bad Request", &[], &api::error(&msg)),
    };
    let segments: Vec<&str> =
        req.path.trim_matches('/').split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("POST", ["jobs"]) => post_job(&mut stream, jobs, cache, &req.body),
        ("GET", ["jobs", id]) => get_job(&mut stream, jobs, id, &req.query),
        ("DELETE", ["jobs", id]) => delete_job(&mut stream, jobs, id),
        ("POST", ["fit"]) => post_fit(&mut stream, cache, &req.body),
        ("GET", ["artifacts", hash]) => get_artifact(&mut stream, cache, hash),
        _ => respond_json(&mut stream, 404, "Not Found", &[], &api::error("no such route")),
    }
}

/// Parse request line + headers + `Content-Length` body off the socket.
/// Errors are client errors (answered `400`) described by the string.
fn read_request(stream: &TcpStream) -> std::result::Result<Request, String> {
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let target = parts.next().ok_or("request line has no target")?.to_string();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| "bad Content-Length".to_string())?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(format!("body exceeds {MAX_BODY} bytes"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| e.to_string())?;
    let body = String::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    Ok(Request { method, path, query, body })
}

fn post_job(
    stream: &mut TcpStream,
    jobs: &Arc<JobManager>,
    cache: &Arc<ArtifactCache>,
    body: &str,
) -> std::io::Result<()> {
    let mut spec = match ScenarioSpec::parse(body) {
        Ok(spec) => spec,
        Err(e) => {
            return respond_json(stream, 400, "Bad Request", &[], &api::error(&e.to_string()))
        }
    };
    if let Err(e) = cache.resolve_model_ref(&mut spec) {
        return respond_json(stream, 400, "Bad Request", &[], &api::error(&e.to_string()));
    }
    match jobs.submit(spec) {
        Ok(job) => respond_json(stream, 202, "Accepted", &[], &api::job_accepted(job.id())),
        Err(SubmitError::Invalid(msg)) => {
            respond_json(stream, 400, "Bad Request", &[], &api::error(&msg))
        }
        Err(SubmitError::QueueFull) => respond_json(
            stream,
            429,
            "Too Many Requests",
            &[("Retry-After", "1")],
            &api::error("job queue is full; retry later"),
        ),
    }
}

fn get_job(
    stream: &mut TcpStream,
    jobs: &Arc<JobManager>,
    id: &str,
    query: &str,
) -> std::io::Result<()> {
    let job = match id.parse::<u64>().ok().and_then(|id| jobs.get(id)) {
        Some(job) => job,
        None => return respond_json(stream, 404, "Not Found", &[], &api::error("no such job")),
    };
    if query.split('&').any(|kv| kv == "wait=0") {
        return respond_json(stream, 200, "OK", &[], &api::job_status(&job));
    }
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n",
    )?;
    let mut last_line: Option<String> = None;
    loop {
        let state = job.state();
        if let Some(line) = api::terminal_line(&state) {
            stream.write_all(format!("{line}\n").as_bytes())?;
            return stream.flush();
        }
        if let Some(report) = job.progress() {
            let line = report.to_json().to_string();
            if last_line.as_deref() != Some(&line) {
                stream.write_all(format!("{line}\n").as_bytes())?;
                stream.flush()?;
                last_line = Some(line);
            }
        }
        std::thread::sleep(STREAM_POLL);
    }
}

fn delete_job(stream: &mut TcpStream, jobs: &Arc<JobManager>, id: &str) -> std::io::Result<()> {
    match id.parse::<u64>().ok().filter(|&id| jobs.cancel(id)) {
        Some(id) => respond_json(stream, 200, "OK", &[], &api::job_cancelled(id)),
        None => respond_json(stream, 404, "Not Found", &[], &api::error("no such job")),
    }
}

fn post_fit(stream: &mut TcpStream, cache: &Arc<ArtifactCache>, body: &str) -> std::io::Result<()> {
    match fit_cached(cache, body) {
        Ok((hash, true)) => respond_json(stream, 200, "OK", &[], &api::fit_response(hash, true)),
        Ok((hash, false)) => {
            respond_json(stream, 201, "Created", &[], &api::fit_response(hash, false))
        }
        Err(e) => respond_json(stream, 400, "Bad Request", &[], &api::error(&e.to_string())),
    }
}

/// Fit the spec in `body`, memoized on the cache's fit key. Returns
/// `(model_hash, cache_hit)`.
fn fit_cached(cache: &ArtifactCache, body: &str) -> Result<(u64, bool)> {
    let spec = ScenarioSpec::parse(body)?;
    if spec.model.is_some() {
        return Err(Error::Config(
            "`POST /fit` fits from a `dataset`; the spec already names a `model`".into(),
        ));
    }
    let key = cache.fit_key(&spec);
    if let Some(hash) = cache.lookup_fit(key) {
        return Ok((hash, true));
    }
    let ds = crate::datasets::load(&spec.dataset, spec.dataset_seed)?;
    let fitted = spec.to_builder().fit_with(&ds, &Registries::builtin())?;
    let hash = cache.store_model(&fitted)?;
    cache.record_fit(key, hash)?;
    Ok((hash, false))
}

fn get_artifact(
    stream: &mut TcpStream,
    cache: &Arc<ArtifactCache>,
    hash: &str,
) -> std::io::Result<()> {
    let found = parse_hash(hash).and_then(|h| cache.lookup_model(h));
    let path = match found {
        Some(path) => path,
        None => {
            return respond_json(stream, 404, "Not Found", &[], &api::error("no such artifact"))
        }
    };
    match std::fs::read(&path) {
        Ok(bytes) => respond_raw(stream, 200, "OK", "application/json", &[], &bytes),
        Err(e) => {
            respond_json(stream, 500, "Internal Server Error", &[], &api::error(&e.to_string()))
        }
    }
}

fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra: &[(&str, &str)],
    body: &Json,
) -> std::io::Result<()> {
    let text = format!("{body}\n");
    respond_raw(stream, status, reason, "application/json", extra, text.as_bytes())
}

fn respond_raw(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}
