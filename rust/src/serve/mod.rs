//! Generation-as-a-service: a dependency-free HTTP/1.1 front end over
//! the scenario pipeline (`sgg serve`).
//!
//! The service composes three pieces, each independently testable:
//!
//! * [`server`] — hand-rolled HTTP over [`std::net::TcpListener`]:
//!   request parsing, routing, canonical-JSON responses, and the
//!   newline-delimited progress stream of `GET /jobs/<id>`.
//! * [`jobs`] — a bounded admission queue + worker pool. Queue depth is
//!   the backpressure contract (`429` + `Retry-After` when full); every
//!   job carries a cancel token (`DELETE /jobs/<id>`) and a progress
//!   slot the shard sink publishes into.
//! * [`cache`] — a content-addressed `.sggm` artifact store. Models are
//!   named by the FNV-1a hash of their bytes; `POST /fit` memoizes on a
//!   canonical digest of the fit-relevant spec fields, so refitting an
//!   identical spec never touches the dataset again.
//!
//! Because jobs run through the same
//! [`crate::pipeline::run_scenario_opts`] path as the CLI with atomic
//! shard writes, an HTTP job's output is byte-identical to `sgg run` on
//! the same spec/seed/workers, a killed server's half-finished jobs are
//! resumable from their shard watermark, and a cancelled job leaves a
//! consecutive, resumable shard prefix.

pub mod api;
pub mod cache;
pub mod jobs;
pub mod server;

pub use cache::{hash_hex, parse_hash, ArtifactCache};
pub use jobs::{Job, JobManager, JobState, SubmitError};
pub use server::{ServeConfig, Server, ServerHandle};
