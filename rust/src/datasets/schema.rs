//! Column schema descriptions for the stand-in datasets (paper Table 11
//! documents how each original tabular dataset was turned into a graph;
//! the stand-ins encode the resulting column mixes here).

/// How a feature column of a stand-in is synthesized.
#[derive(Clone, Debug)]
pub enum ColSpec {
    /// Log-normal continuous (e.g. transaction amount), optionally
    /// correlated with source-node degree by `deg_corr` ∈ [0,1].
    LogNormal { name: &'static str, mu: f64, sigma: f64, deg_corr: f64 },
    /// Gaussian continuous.
    Normal { name: &'static str, mean: f64, std: f64, deg_corr: f64 },
    /// Uniform continuous in [lo, hi].
    Uniform { name: &'static str, lo: f64, hi: f64 },
    /// Zipf-ish categorical with `k` values (head-heavy, like MCC codes),
    /// optionally degree-correlated.
    Categorical { name: &'static str, k: u32, alpha: f64, deg_corr: f64 },
}

impl ColSpec {
    /// Column name.
    pub fn name(&self) -> &'static str {
        match self {
            ColSpec::LogNormal { name, .. }
            | ColSpec::Normal { name, .. }
            | ColSpec::Uniform { name, .. }
            | ColSpec::Categorical { name, .. } => name,
        }
    }
}

/// Schema of a stand-in: edge columns + optional node columns.
#[derive(Clone, Debug)]
pub struct DatasetSchema {
    /// Per-edge feature columns.
    pub edge_cols: Vec<ColSpec>,
    /// Per-node feature columns (empty when the stand-in has none).
    pub node_cols: Vec<ColSpec>,
}

/// Transaction-style edge schema (Tabformer / Credit stand-ins).
pub fn transaction_schema(n_extra: usize) -> DatasetSchema {
    let mut edge_cols = vec![
        ColSpec::LogNormal { name: "amount", mu: 3.0, sigma: 1.2, deg_corr: 0.5 },
        ColSpec::Categorical { name: "mcc", k: 24, alpha: 1.6, deg_corr: 0.4 },
        ColSpec::Uniform { name: "hour", lo: 0.0, hi: 24.0 },
        ColSpec::Categorical { name: "chip", k: 3, alpha: 1.2, deg_corr: 0.0 },
        ColSpec::Normal { name: "zipdist", mean: 40.0, std: 25.0, deg_corr: 0.2 },
    ];
    for i in 0..n_extra {
        edge_cols.push(ColSpec::Normal {
            name: Box::leak(format!("v{i}").into_boxed_str()),
            mean: 0.0,
            std: 1.0,
            deg_corr: if i % 3 == 0 { 0.6 } else { 0.0 },
        });
    }
    DatasetSchema { edge_cols, node_cols: vec![] }
}

/// Fraud-profile schema: transaction edges plus card/account profile
/// columns on the source partite (Table 11: the IEEE original carries
/// identity/profile features per card), so the node-feature pipeline leg
/// has something to fit.
pub fn fraud_profile_schema(n_extra: usize) -> DatasetSchema {
    let mut schema = transaction_schema(n_extra);
    schema.node_cols = vec![
        ColSpec::LogNormal { name: "credit_limit", mu: 8.5, sigma: 0.9, deg_corr: 0.45 },
        ColSpec::Normal { name: "account_age", mean: 48.0, std: 20.0, deg_corr: 0.3 },
        ColSpec::Categorical { name: "region", k: 12, alpha: 1.4, deg_corr: 0.3 },
        ColSpec::Categorical { name: "card_tier", k: 4, alpha: 1.1, deg_corr: 0.2 },
    ];
    schema
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transaction_schema_sizes() {
        let s = transaction_schema(7);
        assert_eq!(s.edge_cols.len(), 12);
        assert_eq!(s.edge_cols[0].name(), "amount");
    }
}
