//! Stand-in dataset constructors (see module docs of [`super`]).

use super::schema::{fraud_profile_schema, transaction_schema, ColSpec, DatasetSchema};
use super::Dataset;
use crate::featgen::table::{Column, ColumnData, FeatureTable};
use crate::graph::{EdgeList, PartiteSpec};
use crate::structgen::kronecker::KroneckerGen;
use crate::structgen::theta::ThetaS;
use crate::structgen::StructureGenerator;
use crate::util::rng::Pcg64;

/// Standardized degree signal per edge: ln(1 + deg(src)) z-scored.
fn degree_signal(edges: &EdgeList) -> Vec<f64> {
    let deg = edges.out_degrees();
    let raw: Vec<f64> = edges.iter().map(|(s, _)| ((deg[s as usize] + 1) as f64).ln()).collect();
    let m = crate::util::stats::mean(&raw);
    let sd = crate::util::stats::std_dev(&raw).max(1e-9);
    raw.iter().map(|x| (x - m) / sd).collect()
}

/// Node-level degree signal.
fn node_degree_signal(edges: &EdgeList) -> Vec<f64> {
    let deg = edges.out_degrees();
    let raw: Vec<f64> = deg.iter().map(|&d| ((d + 1) as f64).ln()).collect();
    let m = crate::util::stats::mean(&raw);
    let sd = crate::util::stats::std_dev(&raw).max(1e-9);
    raw.iter().map(|x| (x - m) / sd).collect()
}

/// Synthesize feature columns per schema, mixing in the degree signal.
fn synth_columns(specs: &[ColSpec], signal: &[f64], rng: &mut Pcg64) -> FeatureTable {
    let n = signal.len();
    let columns = specs
        .iter()
        .map(|spec| match *spec {
            ColSpec::LogNormal { name, mu, sigma, deg_corr } => {
                let v: Vec<f64> = (0..n)
                    .map(|i| {
                        let z = deg_corr * signal[i]
                            + (1.0 - deg_corr * deg_corr).sqrt() * rng.normal();
                        (mu + sigma * z).exp()
                    })
                    .collect();
                Column::continuous(name, v)
            }
            ColSpec::Normal { name, mean, std, deg_corr } => {
                let v: Vec<f64> = (0..n)
                    .map(|i| {
                        let z = deg_corr * signal[i]
                            + (1.0 - deg_corr * deg_corr).sqrt() * rng.normal();
                        mean + std * z
                    })
                    .collect();
                Column::continuous(name, v)
            }
            ColSpec::Uniform { name, lo, hi } => {
                Column::continuous(name, (0..n).map(|_| rng.range(lo, hi)).collect())
            }
            ColSpec::Categorical { name, k, alpha, deg_corr } => {
                let codes: Vec<u32> = (0..n)
                    .map(|i| {
                        if deg_corr > 0.0 && rng.bool(deg_corr) {
                            // degree-linked head/tail split
                            if signal[i] > 0.0 {
                                rng.zipf(k as usize / 2 + 1, alpha) as u32
                            } else {
                                (k as usize / 2
                                    + rng.zipf(k as usize - k as usize / 2, alpha))
                                    as u32
                            }
                        } else {
                            rng.zipf(k as usize, alpha) as u32
                        }
                    })
                    .map(|c| c.min(k - 1))
                    .collect();
                Column {
                    name: name.to_string(),
                    data: ColumnData::Categorical { codes, cardinality: k },
                }
            }
        })
        .collect();
    FeatureTable::new(columns).expect("schema columns are equal length")
}

/// Core builder: skewed Kronecker structure + schema features.
fn build(
    name: &str,
    spec: PartiteSpec,
    edges: u64,
    theta: ThetaS,
    schema: &DatasetSchema,
    seed: u64,
) -> Dataset {
    let gen = KroneckerGen::new(theta, spec, edges).with_noise(0.3);
    let graph = gen.generate(1, seed).unwrap();
    let mut rng = Pcg64::with_stream(seed, 0xfea7);
    let edge_features = synth_columns(&schema.edge_cols, &degree_signal(&graph), &mut rng);
    let node_features = if schema.node_cols.is_empty() {
        None
    } else {
        Some(synth_columns(&schema.node_cols, &node_degree_signal(&graph), &mut rng))
    };
    Dataset {
        name: name.to_string(),
        edges: graph,
        edge_features,
        node_features,
        node_labels: None,
        edge_labels: None,
    }
}

/// Tabformer stand-in: bipartite user-card × merchant transactions,
/// 5 edge features (Table 1 row 1, scaled 106k×978k → 8k×60k).
pub fn tabformer(seed: u64) -> Dataset {
    build(
        "tabformer",
        PartiteSpec::bipartite(1 << 13, 1 << 9),
        60_000,
        ThetaS::new(0.52, 0.22, 0.18, 0.08),
        &transaction_schema(0),
        seed,
    )
}

/// IEEE-Fraud stand-in: bipartite card-profile × address-profile graph,
/// 12 edge features (scaled from 48), 4 card-profile node features, and
/// fraud edge labels (~3.5% positive, degree- and feature-correlated so
/// a GNN can learn it).
pub fn ieee_fraud(seed: u64) -> Dataset {
    let mut ds = build(
        "ieee-fraud",
        PartiteSpec::bipartite(1 << 10, 1 << 8),
        26_000,
        ThetaS::new(0.45, 0.25, 0.2, 0.1),
        &fraud_profile_schema(7),
        seed,
    );
    // fraud labels: logistic in amount + degree signal
    let sig = degree_signal(&ds.edges);
    let amount = ds.edge_features.column("amount").unwrap().as_continuous().to_vec();
    let la = crate::util::stats::mean(&amount);
    let mut rng = Pcg64::with_stream(seed, 0xf4a6d);
    let labels: Vec<u32> = (0..ds.edges.len())
        .map(|i| {
            let score = 0.8 * (amount[i] / la - 1.0) - 1.2 * sig[i] - 3.3;
            let p = 1.0 / (1.0 + (-score).exp());
            rng.bool(p) as u32
        })
        .collect();
    ds.edge_labels = Some(labels);
    ds
}

/// Paysim stand-in: mobile-money transfers orig → dest, 8 features
/// (scaled 9M nodes → 16k).
pub fn paysim(seed: u64) -> Dataset {
    build(
        "paysim",
        PartiteSpec::bipartite(1 << 13, 1 << 13),
        50_000,
        ThetaS::new(0.62, 0.16, 0.14, 0.08),
        &transaction_schema(3),
        seed,
    )
}

/// Credit stand-in: small, very dense card-holder × merchant graph
/// (Table 1: 1 666 nodes, 476 k edges — the densest set; scaled edges).
pub fn credit(seed: u64) -> Dataset {
    build(
        "credit",
        PartiteSpec::bipartite(832, 834),
        48_000,
        ThetaS::new(0.36, 0.27, 0.24, 0.13),
        &transaction_schema(15),
        seed,
    )
}

/// Home-Credit stand-in: applicant graph keyed by shared attributes.
pub fn home_credit(seed: u64) -> Dataset {
    build(
        "home-credit",
        PartiteSpec::bipartite(1 << 12, 1 << 7),
        70_000,
        ThetaS::new(0.48, 0.24, 0.19, 0.09),
        &transaction_schema(11),
        seed,
    )
}

/// Travel-Insurance stand-in: policy-holder graph (small, dense-ish).
pub fn travel_insurance(seed: u64) -> Dataset {
    build(
        "travel-insurance",
        PartiteSpec::bipartite(993, 993),
        40_000,
        ThetaS::new(0.4, 0.26, 0.22, 0.12),
        &transaction_schema(4),
        seed,
    )
}

/// OGBN-MAG stand-in: paper × author-ish bipartite graph, 16 features.
pub fn ogbn_mag_mini(seed: u64) -> Dataset {
    build(
        "ogbn-mag-mini",
        PartiteSpec::bipartite(1 << 12, 1 << 10),
        100_000,
        ThetaS::new(0.56, 0.19, 0.17, 0.08),
        &transaction_schema(11),
        seed,
    )
}

/// MAG240m stand-in at integer `scale` (Table 3's base unit, heavily
/// scaled down: scale 1 ≈ 2^14 src nodes / 200k edges on this testbed).
pub fn mag_mini(scale: u64, seed: u64) -> Dataset {
    let spec = PartiteSpec::bipartite((1 << 14) * scale, (1 << 12) * scale);
    build(
        "mag-mini",
        spec,
        200_000 * scale * scale,
        ThetaS::new(0.57, 0.19, 0.17, 0.07),
        &transaction_schema(3),
        seed,
    )
}

/// Cora stand-in: homophilous citation network with 7 topic classes,
/// 32-dim multi-hot node features (scaled from 1433), node labels.
/// Structure: community-biased sampling so GNNs beat feature-only models.
pub fn cora(seed: u64) -> Dataset {
    citation_graph("cora", 2708, 5429, 7, 32, 0.81, seed)
}

/// CORA-ML stand-in (Table 10's benchmark: 2810 nodes, 7981 edges).
pub fn cora_ml(seed: u64) -> Dataset {
    citation_graph("cora-ml", 2810, 7981, 7, 32, 0.78, seed)
}

/// Homophilous multi-class graph with degree skew: class-conditioned
/// preferential attachment + multi-hot class-correlated node features.
fn citation_graph(
    name: &str,
    n: u64,
    m: u64,
    classes: u32,
    feat_dim: usize,
    homophily: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let labels: Vec<u32> = (0..n).map(|_| rng.below(classes as u64) as u32).collect();
    // preferential attachment with homophily bias
    let mut deg = vec![1.0f64; n as usize];
    let mut edges = EdgeList::with_capacity(PartiteSpec::square(n), m as usize);
    for _ in 0..m {
        // source: uniform; destination: degree-weighted, same-class biased
        let s = rng.below(n);
        let mut d;
        loop {
            // degree-proportional proposal via two-step: pick random edge
            // endpoint or random node
            d = if rng.bool(0.7) && !edges.is_empty() {
                let e = rng.below_usize(edges.len());
                if rng.bool(0.5) {
                    edges.src[e]
                } else {
                    edges.dst[e]
                }
            } else {
                rng.below(n)
            };
            if d == s {
                continue;
            }
            let same = labels[s as usize] == labels[d as usize];
            let accept = if same { homophily } else { 1.0 - homophily };
            if rng.bool(accept.clamp(0.05, 0.95)) {
                break;
            }
        }
        deg[s as usize] += 1.0;
        deg[d as usize] += 1.0;
        edges.push(s, d);
    }
    // multi-hot node features: class signature bits + noise bits
    let bits_per_class = feat_dim / classes as usize;
    let mut cols: Vec<Column> = Vec::with_capacity(feat_dim);
    let mut data: Vec<Vec<f64>> = vec![Vec::with_capacity(n as usize); feat_dim];
    for v in 0..n as usize {
        let c = labels[v] as usize;
        for (f, col) in data.iter_mut().enumerate() {
            let in_sig = f / bits_per_class.max(1) == c;
            let p = if in_sig { 0.45 } else { 0.04 };
            col.push(if rng.bool(p) { 1.0 } else { 0.0 });
        }
    }
    for (f, vals) in data.into_iter().enumerate() {
        cols.push(Column::continuous(
            Box::leak(format!("w{f}").into_boxed_str()),
            vals,
        ));
    }
    let node_features = FeatureTable::new(cols).unwrap();
    // simple edge feature (citation weight)
    let sig = degree_signal(&edges);
    let ef: Vec<f64> = sig.iter().map(|&s| 1.0 + (0.5 * s + rng.normal() * 0.3).exp()).collect();
    Dataset {
        name: name.to_string(),
        edges,
        edge_features: FeatureTable::new(vec![Column::continuous("weight", ef)]).unwrap(),
        node_features: Some(node_features),
        node_labels: Some(labels),
        edge_labels: None,
    }
}

/// Figure 4's controlled synthetic: SBM with homophily `h` and feature
/// signal-to-noise `snr`. 1000 nodes, ~24k edges (density 0.06 as in
/// §8.5), `classes` clusters; returns (edges, node features, labels).
pub fn homophily_snr(h: f64, snr: f64, classes: u32, seed: u64) -> Dataset {
    let n = 1000u64;
    let density = 0.06 * 0.5; // undirected pairs stored once
    let target_edges = (density * (n * (n - 1)) as f64 / 2.0) as usize;
    let mut rng = Pcg64::new(seed);
    let labels: Vec<u32> = (0..n).map(|_| rng.below(classes as u64) as u32).collect();
    let mut edges = EdgeList::with_capacity(PartiteSpec::square(n), target_edges);
    while edges.len() < target_edges {
        let a = rng.below(n);
        let b = rng.below(n);
        if a == b {
            continue;
        }
        let same = labels[a as usize] == labels[b as usize];
        // homophily h: intra-cluster edges h/(1-h) times more likely
        let p = if same { h } else { 1.0 - h };
        if rng.bool(p.clamp(0.02, 0.98)) {
            edges.push(a, b);
        }
    }
    // features: class mean separated by snr, unit noise
    let dim = 8usize;
    let mut class_means = vec![vec![0.0f64; dim]; classes as usize];
    let mut dir_rng = Pcg64::new(0xd14);
    for mean in class_means.iter_mut() {
        for x in mean.iter_mut() {
            *x = dir_rng.normal();
        }
        let norm: f64 = mean.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
        for x in mean.iter_mut() {
            *x = *x / norm * snr;
        }
    }
    let mut cols: Vec<Vec<f64>> = vec![Vec::with_capacity(n as usize); dim];
    for v in 0..n as usize {
        let c = labels[v] as usize;
        for (f, col) in cols.iter_mut().enumerate() {
            col.push(class_means[c][f] + rng.normal());
        }
    }
    let node_features = FeatureTable::new(
        cols.into_iter()
            .enumerate()
            .map(|(f, v)| Column::continuous(Box::leak(format!("x{f}").into_boxed_str()), v))
            .collect(),
    )
    .unwrap();
    let sig = degree_signal(&edges);
    Dataset {
        name: format!("synth-h{h}-snr{snr}"),
        edge_features: FeatureTable::new(vec![Column::continuous(
            "w",
            sig.iter().map(|&s| s + rng.normal() * 0.1).collect(),
        )])
        .unwrap(),
        edges,
        node_features: Some(node_features),
        node_labels: Some(labels),
        edge_labels: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn ieee_fraud_label_rate_realistic() {
        let ds = ieee_fraud(3);
        let labels = ds.edge_labels.as_ref().unwrap();
        let rate = labels.iter().filter(|&&l| l == 1).count() as f64 / labels.len() as f64;
        assert!(rate > 0.01 && rate < 0.12, "rate={rate}");
    }

    #[test]
    fn cora_is_homophilous() {
        let ds = cora(1);
        let labels = ds.node_labels.as_ref().unwrap();
        let same = ds
            .edges
            .iter()
            .filter(|(s, d)| labels[*s as usize] == labels[*d as usize])
            .count() as f64
            / ds.edges.len() as f64;
        // 7 classes: random baseline ≈ 1/7 ≈ 0.14
        assert!(same > 0.4, "same-class edge fraction={same}");
    }

    #[test]
    fn cora_features_class_informative() {
        let ds = cora(2);
        let labels = ds.node_labels.as_ref().unwrap();
        let nf = ds.node_features.as_ref().unwrap();
        // class-0 signature columns should be denser for class-0 nodes
        let col = nf.columns[0].as_continuous();
        let in0: Vec<f64> = (0..col.len()).filter(|&v| labels[v] == 0).map(|v| col[v]).collect();
        let out0: Vec<f64> = (0..col.len()).filter(|&v| labels[v] != 0).map(|v| col[v]).collect();
        assert!(
            crate::util::stats::mean(&in0) > crate::util::stats::mean(&out0) + 0.2,
            "{} vs {}",
            crate::util::stats::mean(&in0),
            crate::util::stats::mean(&out0)
        );
    }

    #[test]
    fn degree_skew_present_in_transactions() {
        let ds = tabformer(1);
        let deg = ds.edges.out_degrees();
        let mean = ds.edges.len() as f64 / ds.edges.spec.n_src as f64;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max > 10.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn features_degree_correlated() {
        let ds = tabformer(2);
        let sig = super::degree_signal(&ds.edges);
        let amount: Vec<f64> = ds
            .edge_features
            .column("amount")
            .unwrap()
            .as_continuous()
            .iter()
            .map(|&x| x.ln())
            .collect();
        let corr = crate::util::stats::pearson(&sig, &amount);
        assert!(corr > 0.3, "corr={corr}");
    }

    #[test]
    fn homophily_snr_extremes() {
        let hi = homophily_snr(0.85, 1.5, 4, 1);
        let lo = homophily_snr(0.15, 0.5, 4, 2);
        let frac_same = |ds: &Dataset| {
            let l = ds.node_labels.as_ref().unwrap();
            ds.edges
                .iter()
                .filter(|(s, d)| l[*s as usize] == l[*d as usize])
                .count() as f64
                / ds.edges.len() as f64
        };
        assert!(frac_same(&hi) > 0.5, "hi={}", frac_same(&hi));
        assert!(frac_same(&lo) < 0.2, "lo={}", frac_same(&lo));
        // edge count near 24k (paper: ~24,000 directed ≈ 15k stored here)
        assert!(hi.edges.len() > 10_000);
    }

    #[test]
    fn mag_mini_scales_quadratically() {
        let s1 = mag_mini(1, 1);
        let s2 = mag_mini(2, 1);
        assert_eq!(s2.edges.len(), 4 * s1.edges.len());
        assert_eq!(s2.edges.spec.n_src, 2 * s1.edges.spec.n_src);
    }

    #[test]
    fn cora_connected_enough() {
        let ds = cora(5);
        let csr = Csr::undirected(&ds.edges);
        let lcc = crate::graph::traversal::largest_component(&csr);
        assert!(lcc > 2000, "lcc={lcc}");
    }
}
