//! Dataset registry: seeded synthetic stand-ins for every dataset in
//! paper Table 1 / Table 11.
//!
//! The originals (Tabformer, IEEE-Fraud, Paysim, Credit, Home-Credit,
//! Travel-Insurance, MAG240m, OGBN-MAG, Cora) are proprietary or too
//! large for this testbed, so each stand-in reproduces the dataset's
//! *shape* — partite structure, skewed degree profile, column schema
//! (continuous/categorical mix per Table 1's feature counts, scaled), and
//! degree-correlated features so the aligner has real signal to learn.
//! All are deterministic in the seed. See DESIGN.md §Substitutions.

pub mod schema;
pub mod synth;

use crate::featgen::FeatureTable;
use crate::graph::EdgeList;
use crate::Result;

/// A graph dataset: structure + features (+ optional task labels),
/// the triple `G(S, F_V, F_E)` of paper §3.1.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Registry name.
    pub name: String,
    /// Graph structure.
    pub edges: EdgeList,
    /// Edge features — one row per edge.
    pub edge_features: FeatureTable,
    /// Node features over source-partite nodes (None for edge-only sets).
    pub node_features: Option<FeatureTable>,
    /// Node class labels (node-classification tasks, e.g. Cora).
    pub node_labels: Option<Vec<u32>>,
    /// Edge class labels (edge-classification tasks, e.g. fraud).
    pub edge_labels: Option<Vec<u32>>,
}

impl Dataset {
    /// Summary line matching paper Table 1's columns.
    pub fn summary(&self) -> String {
        format!(
            "{:<18} nodes={:<10} edges={:<10} features={}",
            self.name,
            self.edges.n_nodes(),
            self.edges.len(),
            self.edge_features.n_cols()
                + self.node_features.as_ref().map(|f| f.n_cols()).unwrap_or(0)
        )
    }
}

/// Names available in the registry (the Table 1 rows).
pub const REGISTRY: &[&str] = &[
    "tabformer",
    "ieee-fraud",
    "paysim",
    "credit",
    "home-credit",
    "travel-insurance",
    "cora",
    "cora-ml",
    "ogbn-mag-mini",
    "mag-mini",
];

/// Load a stand-in dataset by name.
pub fn load(name: &str, seed: u64) -> Result<Dataset> {
    match name {
        "tabformer" => Ok(synth::tabformer(seed)),
        "ieee-fraud" => Ok(synth::ieee_fraud(seed)),
        "paysim" => Ok(synth::paysim(seed)),
        "credit" => Ok(synth::credit(seed)),
        "home-credit" => Ok(synth::home_credit(seed)),
        "travel-insurance" => Ok(synth::travel_insurance(seed)),
        "cora" => Ok(synth::cora(seed)),
        "cora-ml" => Ok(synth::cora_ml(seed)),
        "ogbn-mag-mini" => Ok(synth::ogbn_mag_mini(seed)),
        "mag-mini" => Ok(synth::mag_mini(1, seed)),
        other => Err(crate::Error::Config(format!(
            "unknown dataset `{other}`; known: {REGISTRY:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_loads_everything() {
        for name in REGISTRY {
            let ds = load(name, 1).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(ds.edges.len() > 100, "{name} too small");
            assert_eq!(ds.edge_features.n_rows(), ds.edges.len(), "{name} edge feats");
            assert!(ds.edges.validate().is_ok(), "{name} invalid edges");
            if let Some(nf) = &ds.node_features {
                assert_eq!(nf.n_rows(), ds.edges.spec.n_src as usize, "{name} node feats");
            }
            if let Some(el) = &ds.edge_labels {
                assert_eq!(el.len(), ds.edges.len());
            }
            if let Some(nl) = &ds.node_labels {
                assert_eq!(nl.len(), ds.edges.spec.n_src as usize);
            }
        }
    }

    #[test]
    fn deterministic_loading() {
        let a = load("ieee-fraud", 7).unwrap();
        let b = load("ieee-fraud", 7).unwrap();
        assert_eq!(a.edges.src, b.edges.src);
        assert_eq!(a.edge_features, b.edge_features);
    }

    #[test]
    fn unknown_name_errors() {
        assert!(load("nope", 1).is_err());
    }
}
