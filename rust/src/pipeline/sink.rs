//! Output sinks: where generated structure goes. One [`Sink`] trait
//! serves both the in-memory path (collect chunks, then assemble a full
//! [`Dataset`] with features) and the out-of-core path (write each chunk
//! to its own disk shard, paper §4.5 / Table 3) — `generate` and the
//! streaming orchestrator share one code path through it.

use crate::datasets::Dataset;
use crate::graph::{io, EdgeList};
use crate::pipeline::fault::{retry_transient, RetryPolicy};
use crate::pipeline::parallel::CancelToken;
use crate::structgen::chunked::{Chunk, ChunkConfig};
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// What a sink hands back after the last chunk.
pub enum SinkFinish {
    /// The sink retained the structure in memory; the pipeline should run
    /// feature generation + alignment over it.
    Collected(EdgeList),
    /// Everything is already persisted; only a report remains.
    Streamed(StreamReport),
}

/// Final output of a pipeline run.
#[derive(Debug)]
pub enum SinkOutput {
    /// Fully assembled in-memory dataset (memory sink).
    Dataset(Dataset),
    /// Stream report (shard sink).
    Streamed(StreamReport),
}

impl SinkOutput {
    /// Unwrap the in-memory dataset; errors for streamed runs.
    pub fn into_dataset(self) -> Result<Dataset> {
        match self {
            SinkOutput::Dataset(ds) => Ok(ds),
            SinkOutput::Streamed(r) => Err(Error::Config(format!(
                "scenario streamed to shards under {} — no in-memory dataset",
                r.out_dir.display()
            ))),
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        match self {
            SinkOutput::Dataset(ds) => format!(
                "{}: {} nodes, {} edges, {} edge feature cols, {} node feature cols",
                ds.name,
                ds.edges.n_nodes(),
                ds.edges.len(),
                ds.edge_features.n_cols(),
                ds.node_features.as_ref().map(|f| f.n_cols()).unwrap_or(0)
            ),
            SinkOutput::Streamed(r) => r.to_string(),
        }
    }
}

/// A consumer of generated structure chunks.
///
/// [`Sink::edges`] errors abort generation early (workers stop at their
/// next chunk boundary) and propagate out of the pipeline run. When
/// generation is driven by the
/// [`ParallelChunkRunner`](crate::pipeline::parallel::ParallelChunkRunner)
/// chunks arrive strictly in chunk-index order regardless of the worker
/// count; sinks should nevertheless stay order-agnostic (as
/// [`MemorySink`] is) so they also work when fed directly.
pub trait Sink {
    /// Sink name (for logs / registry-style selection).
    fn name(&self) -> &'static str;

    /// Receive one structure chunk. The chunk arrives by `&mut` so the
    /// runner can recycle its edge buffer afterwards: streaming sinks
    /// just borrow the edges, retaining sinks take them with
    /// `std::mem::take(&mut chunk.edges)` and leave an empty list for
    /// the arena.
    fn edges(&mut self, chunk: &mut Chunk) -> Result<()>;

    /// Called once after the last chunk.
    fn finish(&mut self) -> Result<SinkFinish>;
}

/// Collects every chunk into one in-memory edge list. Chunks are
/// reassembled in chunk-index order at finish time, so the output is
/// deterministic in the seed even though parallel workers deliver chunks
/// in scheduling-dependent order.
#[derive(Default)]
pub struct MemorySink {
    chunks: Vec<Chunk>,
}

impl MemorySink {
    /// Empty sink.
    pub fn new() -> MemorySink {
        MemorySink::default()
    }
}

impl Sink for MemorySink {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        self.chunks.push(Chunk {
            index: chunk.index,
            worker: chunk.worker,
            sample_secs: chunk.sample_secs,
            encode_secs: chunk.encode_secs,
            edges: std::mem::take(&mut chunk.edges),
            encoded: None,
        });
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        self.chunks.sort_by_key(|c| c.index);
        let total: usize = self.chunks.iter().map(|c| c.edges.len()).sum();
        let mut out: Option<EdgeList> = None;
        for chunk in self.chunks.drain(..) {
            match &mut out {
                None => {
                    let mut first = EdgeList::with_capacity(chunk.edges.spec, total);
                    first.extend_from(&chunk.edges);
                    out = Some(first);
                }
                Some(acc) => acc.extend_from(&chunk.edges),
            }
        }
        Ok(SinkFinish::Collected(out.unwrap_or_default()))
    }
}

/// Streaming run report (rows of paper Table 3).
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// Total edges persisted.
    pub edges_written: u64,
    /// Number of shard files written.
    pub shards: usize,
    /// End-to-end wall-clock seconds.
    pub wall_secs: f64,
    /// Peak resident edge-buffer bytes, derived from the actual sizes of
    /// the largest chunks that can be in flight at once (the parallel
    /// runner's reorder window: queue + workers, plus the writer's
    /// chunk), at 16 B/edge.
    pub peak_buffer_bytes: u64,
    /// Seconds each pool worker spent sampling (indexed by worker id;
    /// one entry on the sequential path). Lets throughput reports
    /// attribute time to sampling vs. writing and keeps
    /// `peak_buffer_bytes` honest about how many workers were live.
    pub worker_busy_secs: Vec<f64>,
    /// Total seconds spent sampling, summed across workers (the scalar
    /// counterpart of `worker_busy_secs` — the first stage of the
    /// sample → encode → write breakdown).
    pub sample_secs: f64,
    /// Total seconds spent encoding chunks into shard wire bytes —
    /// on the sampling workers when worker-side encoding is on, on the
    /// writer when a chunk arrived raw.
    pub encode_secs: f64,
    /// Total seconds the IO stage spent in shard writes (write + fsync
    /// + rename), overlapped with reordering when the async write stage
    /// is active.
    pub write_secs: f64,
    /// Seconds the writer thread itself was busy inside the sink — the
    /// serial-section residue that caps parallel speedup (Amdahl). With
    /// worker-side encoding and overlapped IO this should be a small
    /// fraction of `wall_secs`.
    pub writer_busy_secs: f64,
    /// Shard output directory.
    pub out_dir: PathBuf,
    /// Structural quality against the fit source, filled when the run
    /// was tapped (`[evaluate]` in a scenario spec routes chunks through
    /// a [`crate::metrics::stream::TappedSink`]); `None` otherwise.
    pub quality: Option<crate::metrics::stream::StructuralReport>,
}

impl std::fmt::Display for StreamReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} edges in {} shards, {:.2}s ({:.1} Medges/s), peak buffer {:.1} MB",
            self.edges_written,
            self.shards,
            self.wall_secs,
            self.edges_written as f64 / self.wall_secs.max(1e-9) / 1e6,
            self.peak_buffer_bytes as f64 / 1e6
        )?;
        if !self.worker_busy_secs.is_empty() {
            let busiest = self.worker_busy_secs.iter().cloned().fold(0.0f64, f64::max);
            write!(
                f,
                ", {} workers (busiest {:.2}s sampling)",
                self.worker_busy_secs.len(),
                busiest
            )?;
        }
        if let Some(q) = &self.quality {
            write!(f, ", quality: {q}")?;
        }
        Ok(())
    }
}

impl StreamReport {
    /// Canonical JSON form — the single report format shared by
    /// `sgg run --json` / `sgg stream --json` and every progress line
    /// `sgg serve` emits from `GET /jobs/<id>`. Wide counters use
    /// [`Json::u64_exact`], so the document round-trips losslessly
    /// through [`StreamReport::from_json`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("edges_written", Json::u64_exact(self.edges_written)),
            ("shards", Json::from(self.shards)),
            ("wall_secs", Json::from(self.wall_secs)),
            ("peak_buffer_bytes", Json::u64_exact(self.peak_buffer_bytes)),
            ("worker_busy_secs", Json::from(self.worker_busy_secs.clone())),
            ("sample_secs", Json::from(self.sample_secs)),
            ("encode_secs", Json::from(self.encode_secs)),
            ("write_secs", Json::from(self.write_secs)),
            ("writer_busy_secs", Json::from(self.writer_busy_secs)),
            ("out_dir", Json::from(self.out_dir.display().to_string())),
            (
                "quality",
                match &self.quality {
                    Some(q) => q.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Parse the canonical JSON form back into a report — the client
    /// side of the service's progress stream. The stage-time breakdown
    /// fields default to 0 when absent, so reports written before the
    /// breakdown existed still parse.
    pub fn from_json(doc: &Json) -> Result<StreamReport> {
        let opt_f64 = |key: &str| doc.opt(key).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(StreamReport {
            edges_written: doc.req_u64("edges_written")?,
            shards: doc.req_usize("shards")?,
            wall_secs: doc.req_f64("wall_secs")?,
            peak_buffer_bytes: doc.req_u64("peak_buffer_bytes")?,
            worker_busy_secs: doc.req_f64s("worker_busy_secs")?,
            sample_secs: opt_f64("sample_secs"),
            encode_secs: opt_f64("encode_secs"),
            write_secs: opt_f64("write_secs"),
            writer_busy_secs: opt_f64("writer_busy_secs"),
            out_dir: PathBuf::from(doc.req_str("out_dir")?),
            quality: match doc.opt("quality") {
                Some(q) => Some(crate::metrics::stream::StructuralReport::from_json(q)?),
                None => None,
            },
        })
    }
}

/// Path of the shard holding chunk `index` under `dir` — zero-padded so
/// lexical path order equals chunk-index order.
pub fn shard_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(format!("shard-{index:05}.sgg"))
}

/// Writes each chunk to its own binary shard file under a directory, in
/// the [`io::ShardFormat`] the chunk config selects (`SGGEDGE1` fixed
/// width by default, `SGGEDGE2` varint-delta when asked).
///
/// **Encoded-chunk fast path:** a chunk that arrives with its wire
/// bytes already attached (worker-side encoding, see
/// [`ChunkConfig::encode`]) is written verbatim — the sink never
/// re-encodes it. Raw chunks fall back to an in-sink
/// [`io::encode_chunk`] through one reused staging buffer.
///
/// **Overlapped IO:** shard bytes are handed to a dedicated IO thread
/// (one write in flight, double-buffered), so shard `N`'s write + fsync
/// + rename overlaps the reorder wait for chunk `N + 1`. Writes are
/// still *issued and completed* strictly in index order, so the
/// completed shard files of an interrupted run always form a
/// consecutive `shard-00000..` prefix — the per-chunk completion
/// records [`ShardSink::resume`] restarts from. Once a deferred write
/// fails (after the IO thread's own bounded retry under the sink's
/// [`RetryPolicy`]), the sink goes sticky-failed: the error surfaces on
/// the next call and every later call fails fatally without submitting
/// more writes, preserving the consecutive-prefix invariant.
///
/// Every shard is written atomically and durably (`.tmp` + fsync +
/// rename + directory fsync, see [`io::write_encoded_atomic`]).
pub struct ShardSink {
    out_dir: PathBuf,
    /// Upper bound on simultaneously resident chunks: the parallel
    /// runner's reorder window (full queue + one chunk per worker) + the
    /// one the writer holds.
    max_inflight: usize,
    /// Bounded retry for transient shard-write failures.
    retry: RetryPolicy,
    /// On-disk encoding for every shard this sink writes.
    format: io::ShardFormat,
    /// Reused encode buffer for the fallback (sink-side) encode path.
    spare: Vec<u8>,
    /// Lazily spawned IO stage; `None` until the first shard write.
    io: Option<IoStage>,
    /// Sticky failure (the first deferred write error's message): set
    /// once a submitted write fails, after which every call fails
    /// fatally without submitting new writes.
    failed: Option<String>,
    /// Largest `max_inflight` chunk edge-counts seen, descending.
    top_sizes: Vec<usize>,
    /// Sampling seconds per worker id, aggregated from chunk provenance.
    worker_busy: Vec<f64>,
    /// Stage-time accumulators (see [`StreamReport`]).
    sample_secs: f64,
    encode_secs: f64,
    write_secs: f64,
    writer_busy: f64,
    /// Live progress mirror: when set, the sink publishes a fresh
    /// [`StreamReport`] snapshot here after every shard it writes.
    progress: Option<ProgressHandle>,
    shards: usize,
    written: u64,
    t0: Instant,
}

/// One shard write handed to the IO thread.
struct WriteJob {
    path: PathBuf,
    bytes: Vec<u8>,
}

/// The IO thread's completion record: the drained byte buffer (recycled
/// into the encode arena), the seconds the write took, and its outcome.
struct WriteDone {
    bytes: Vec<u8>,
    secs: f64,
    result: Result<()>,
}

/// The double-buffered shard write stage: a dedicated IO thread fed
/// through a pair of depth-1 bounded channels. The sink submits at most
/// one job before draining the previous completion, so exactly one
/// write is in flight and rename order equals submission order — the
/// resume invariant does not depend on scheduling.
struct IoStage {
    jobs: crate::util::threadpool::Bounded<WriteJob>,
    done: crate::util::threadpool::Bounded<WriteDone>,
    handle: Option<std::thread::JoinHandle<()>>,
    inflight: bool,
}

impl IoStage {
    fn spawn(retry: RetryPolicy) -> IoStage {
        let jobs: crate::util::threadpool::Bounded<WriteJob> =
            crate::util::threadpool::Bounded::new(1);
        let done: crate::util::threadpool::Bounded<WriteDone> =
            crate::util::threadpool::Bounded::new(1);
        let (rx, tx) = (jobs.clone(), done.clone());
        let handle = std::thread::spawn(move || {
            while let Some(job) = rx.recv() {
                let t0 = Instant::now();
                let result =
                    retry_transient(retry, |_| io::write_encoded_atomic(&job.path, &job.bytes));
                let secs = t0.elapsed().as_secs_f64();
                if tx.send(WriteDone { bytes: job.bytes, secs, result }).is_err() {
                    break; // sink dropped mid-write
                }
            }
        });
        IoStage { jobs, done, handle: Some(handle), inflight: false }
    }
}

impl Drop for ShardSink {
    fn drop(&mut self) {
        if let Some(stage) = self.io.take() {
            // let an in-flight write complete (keeping the on-disk
            // prefix consecutive even on an abort path), then stop the
            // thread
            stage.jobs.close();
            if let Some(h) = stage.handle {
                h.join().ok();
            }
            stage.done.close();
        }
    }
}

/// Shared slot a [`ShardSink`] publishes in-flight [`StreamReport`]
/// snapshots into — the mechanism behind `sgg serve`'s
/// `GET /jobs/<id>` progress stream. Readers lock and clone; the sink
/// overwrites the slot once per written shard.
pub type ProgressHandle = std::sync::Arc<std::sync::Mutex<Option<StreamReport>>>;

impl ShardSink {
    /// Create the output directory and an empty sink.
    ///
    /// Leftover `*.tmp` staging files from an interrupted earlier run
    /// are swept on open — they are incomplete by construction, and a
    /// fresh run would otherwise leave them lying around to confuse
    /// directory listings and shard-dir consumers.
    pub fn new(out_dir: &Path, chunks: ChunkConfig) -> Result<ShardSink> {
        std::fs::create_dir_all(out_dir)?;
        for entry in std::fs::read_dir(out_dir)? {
            let p = entry?.path();
            if p.extension().map(|x| x == "tmp").unwrap_or(false) {
                std::fs::remove_file(&p)?;
            }
        }
        Ok(ShardSink {
            out_dir: out_dir.to_path_buf(),
            max_inflight: chunks.queue_capacity.max(1) + chunks.workers.max(1) + 1,
            retry: chunks.retry,
            format: chunks.format,
            spare: Vec::new(),
            io: None,
            failed: None,
            top_sizes: Vec::new(),
            worker_busy: Vec::new(),
            sample_secs: 0.0,
            encode_secs: 0.0,
            write_secs: 0.0,
            writer_busy: 0.0,
            progress: None,
            shards: 0,
            written: 0,
            t0: Instant::now(),
        })
    }

    /// Reopen an interrupted run's output directory and return the sink
    /// plus the number of already-completed leading chunks (the resume
    /// watermark for [`ChunkConfig::resume_from`]).
    ///
    /// Staged `.tmp` files are incomplete by construction and swept
    /// first. Completed shards are scanned as a consecutive prefix from
    /// index 0 — each header validated against its file — and their
    /// counts restored into the sink's report; any shard at or past the
    /// first gap is deleted (its chunk regenerates deterministically, so
    /// deleting is always safe and keeps the final directory byte-
    /// identical to an uninterrupted run).
    pub fn resume(out_dir: &Path, chunks: ChunkConfig) -> Result<(ShardSink, usize)> {
        ShardSink::resume_range(out_dir, chunks, 0)
    }

    /// [`ShardSink::resume`] for a range-restricted (distributed host)
    /// run whose first owned chunk is `start`: the consecutive completed
    /// prefix is scanned from `start` instead of 0, and only shards at
    /// or past the returned watermark are swept. Shards below `start`
    /// belong to other hosts' ranges and are never touched.
    pub fn resume_range(
        out_dir: &Path,
        chunks: ChunkConfig,
        start: usize,
    ) -> Result<(ShardSink, usize)> {
        // `ShardSink::new` sweeps the staged `.tmp` debris
        let mut sink = ShardSink::new(out_dir, chunks)?;
        let mut completed = start;
        loop {
            let p = shard_path(out_dir, completed);
            if !p.exists() {
                break;
            }
            let (_spec, n_edges) = io::read_binary_header(&p)?;
            sink.written += n_edges;
            sink.shards += 1;
            sink.note_size(n_edges as usize);
            completed += 1;
        }
        // a chunk that produced no edges writes no shard, so files can
        // exist past the first gap; everything ≥ the watermark will be
        // regenerated — drop it rather than trust it
        for entry in std::fs::read_dir(out_dir)? {
            let p = entry?.path();
            let index = p
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(|n| n.strip_prefix("shard-"))
                .and_then(|n| n.strip_suffix(".sgg"))
                .and_then(|n| n.parse::<usize>().ok());
            if matches!(index, Some(i) if i >= completed) {
                std::fs::remove_file(&p)?;
            }
        }
        Ok((sink, completed))
    }

    /// Track `n` among the largest `max_inflight` chunk sizes
    /// (descending) for the peak-buffer estimate.
    fn note_size(&mut self, n: usize) {
        let pos = self.top_sizes.binary_search_by(|x| n.cmp(x)).unwrap_or_else(|p| p);
        if pos < self.max_inflight {
            self.top_sizes.insert(pos, n);
            self.top_sizes.truncate(self.max_inflight);
        }
    }

    /// Mirror every subsequent progress snapshot into `slot` (one
    /// [`StreamReport`] per written shard). The current state is
    /// published immediately, so resumed runs surface their restored
    /// prefix before the first new shard lands.
    pub fn publish_to(&mut self, slot: ProgressHandle) {
        *slot.lock().unwrap() = Some(self.report());
        self.progress = Some(slot);
    }

    /// The report built so far (same data [`Sink::finish`] returns).
    pub fn report(&self) -> StreamReport {
        StreamReport {
            edges_written: self.written,
            shards: self.shards,
            wall_secs: self.t0.elapsed().as_secs_f64(),
            peak_buffer_bytes: self.top_sizes.iter().sum::<usize>() as u64 * 16,
            worker_busy_secs: self.worker_busy.clone(),
            sample_secs: self.sample_secs,
            encode_secs: self.encode_secs,
            write_secs: self.write_secs,
            writer_busy_secs: self.writer_busy,
            out_dir: self.out_dir.clone(),
            quality: None,
        }
    }

    /// The fatal sticky error every call after a deferred write failure
    /// returns. Deliberately [`Error::Data`] (never transient): the IO
    /// thread already exhausted the retry budget on the write itself, so
    /// a retrying adapter above must not spin on the sink.
    fn sticky_err(msg: &str) -> Error {
        Error::Data(format!("shard sink disabled after write failure: {msg}"))
    }

    /// Block until the in-flight shard write (if any) completes,
    /// folding its timing into `write_secs` and returning its drained
    /// byte buffer for recycling. A write error trips the sticky flag
    /// and propagates — the caller must not submit more writes.
    fn drain_inflight(&mut self) -> Result<Option<Vec<u8>>> {
        let Some(stage) = self.io.as_mut() else { return Ok(None) };
        if !stage.inflight {
            return Ok(None);
        }
        stage.inflight = false;
        let done = stage.done.recv().ok_or_else(|| {
            Error::Worker("shard IO thread exited with a write outstanding".into())
        })?;
        self.write_secs += done.secs;
        if let Err(e) = done.result {
            self.failed = Some(e.to_string());
            return Err(e);
        }
        Ok(Some(done.bytes))
    }
}

impl Sink for ShardSink {
    fn name(&self) -> &'static str {
        "shards"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        let t0 = Instant::now();
        if let Some(msg) = &self.failed {
            return Err(ShardSink::sticky_err(msg));
        }
        // Fast path: the chunk arrived with its wire bytes already
        // encoded (worker-side). A raw chunk — or one encoded in a
        // different format than this sink writes — is encoded here
        // through the reused fallback buffer.
        let worker_encoded =
            chunk.encoded.as_ref().map(|e| e.format == self.format).unwrap_or(false);
        let bytes = if worker_encoded {
            chunk.encoded.take().expect("checked above").bytes
        } else {
            let mut buf = std::mem::take(&mut self.spare);
            let te = Instant::now();
            io::encode_chunk(&chunk.edges, self.format, &mut buf);
            self.encode_secs += te.elapsed().as_secs_f64();
            buf
        };
        // Overlap: the previous shard's write ran while this chunk was
        // being reordered/encoded; settle it before issuing the next
        // write so exactly one is in flight and rename order is
        // submission order.
        let drained = self.drain_inflight()?;
        if let Some(drained) = drained {
            if worker_encoded {
                // hand the drained buffer back through the chunk slot so
                // the runner recycles it into the worker encode arena
                chunk.encoded = Some(io::EncodedChunk { format: self.format, bytes: drained });
            } else {
                self.spare = drained;
            }
        }
        let stage = self.io.get_or_insert_with(|| IoStage::spawn(self.retry));
        let path = shard_path(&self.out_dir, chunk.index);
        if stage.jobs.send(WriteJob { path, bytes }).is_err() {
            return Err(Error::Worker("shard IO thread is gone".into()));
        }
        stage.inflight = true;
        self.written += chunk.edges.len() as u64;
        self.shards += 1;
        if self.worker_busy.len() <= chunk.worker {
            self.worker_busy.resize(chunk.worker + 1, 0.0);
        }
        self.worker_busy[chunk.worker] += chunk.sample_secs;
        self.sample_secs += chunk.sample_secs;
        self.encode_secs += chunk.encode_secs;
        self.note_size(chunk.edges.len());
        if let Some(slot) = &self.progress {
            *slot.lock().unwrap() = Some(self.report());
        }
        self.writer_busy += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        if let Some(msg) = &self.failed {
            return Err(ShardSink::sticky_err(msg));
        }
        // settle the last in-flight write before declaring the run done
        if let Some(drained) = self.drain_inflight()? {
            self.spare = drained;
        }
        Ok(SinkFinish::Streamed(self.report()))
    }
}

/// Cancel-aware sink adapter: checks a [`CancelToken`] before handing
/// each chunk to the inner sink and turns a tripped token into an
/// error, which aborts the parallel runner through its normal
/// first-error path (workers stop at the next chunk boundary, unsampled
/// chunks never run). Because the runner delivers chunks strictly in
/// index order and shard writes are atomic, a cancelled shard run
/// always leaves a consecutive completed prefix — exactly what
/// [`ShardSink::resume`] restarts from.
pub struct CancelSink<'a> {
    inner: &'a mut dyn Sink,
    token: CancelToken,
}

impl<'a> CancelSink<'a> {
    /// Wrap `inner`, aborting as soon as `token` trips.
    pub fn new(inner: &'a mut dyn Sink, token: CancelToken) -> CancelSink<'a> {
        CancelSink { inner, token }
    }

    fn check(&self) -> Result<()> {
        if self.token.is_cancelled() {
            return Err(Error::Worker("generation cancelled".into()));
        }
        Ok(())
    }
}

impl Sink for CancelSink<'_> {
    fn name(&self) -> &'static str {
        "cancel"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        self.check()?;
        self.inner.edges(chunk)
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        self.check()?;
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;

    fn chunk(index: usize, n: usize) -> Chunk {
        let mut edges = EdgeList::with_capacity(PartiteSpec::square(1 << 10), n);
        for i in 0..n {
            edges.push(i as u64 % 1024, (i as u64 * 7) % 1024);
        }
        Chunk {
            index,
            worker: index % 2,
            sample_secs: 0.25,
            encode_secs: 0.0,
            edges,
            encoded: None,
        }
    }

    #[test]
    fn memory_sink_reassembles_in_chunk_index_order() {
        let mut sink = MemorySink::new();
        // chunks arrive out of order (parallel workers race); output must
        // be deterministic in the index, not the arrival order
        sink.edges(&mut chunk(1, 5)).unwrap();
        sink.edges(&mut chunk(0, 10)).unwrap();
        match sink.finish().unwrap() {
            SinkFinish::Collected(e) => {
                assert_eq!(e.len(), 15);
                // chunk 0's 10 edges come first: its row pattern starts at i=0
                assert_eq!(e.src[0], 0);
                assert_eq!(e.src[9], 9);
            }
            SinkFinish::Streamed(_) => panic!("memory sink streamed"),
        }
    }

    #[test]
    fn shard_sink_writes_and_reports_actual_peak() {
        let dir = std::env::temp_dir().join(format!("sgg_sink_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ChunkConfig {
            prefix_levels: 2,
            workers: 2,
            queue_capacity: 1,
            ..ChunkConfig::default()
        };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        // sizes 100..107; max_inflight = 1 + 2 + 1 = 4 → peak sums the 4
        // largest actual chunks, not a divisor-based estimate
        for (i, n) in (100..108).enumerate() {
            sink.edges(&mut chunk(i, n)).unwrap();
        }
        let report = match sink.finish().unwrap() {
            SinkFinish::Streamed(r) => r,
            SinkFinish::Collected(_) => panic!("shard sink collected"),
        };
        assert_eq!(report.shards, 8);
        assert_eq!(report.edges_written, (100..108).sum::<usize>() as u64);
        assert_eq!(report.peak_buffer_bytes, (104 + 105 + 106 + 107) * 16);
        // chunk provenance aggregates into per-worker busy time
        assert_eq!(report.worker_busy_secs.len(), 2);
        assert!((report.worker_busy_secs[0] - 1.0).abs() < 1e-9);
        assert!((report.worker_busy_secs[1] - 1.0).abs() < 1e-9);
        // ... and into the scalar stage breakdown: 8 chunks × 0.25 s
        // sampling, sink-side fallback encoding and real writes
        assert!((report.sample_secs - 2.0).abs() < 1e-9);
        assert!(report.encode_secs > 0.0);
        assert!(report.write_secs > 0.0);
        assert!(report.writer_busy_secs > 0.0);
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_sink_writes_the_configured_format() {
        let dir = std::env::temp_dir().join(format!("sgg_sink_fmt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ChunkConfig { format: io::ShardFormat::Edge2, ..ChunkConfig::default() };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        let mut c = chunk(0, 500);
        let reference = c.edges.clone();
        sink.edges(&mut c).unwrap();
        // the write is asynchronous — settle it before reading the file
        sink.finish().unwrap();
        let path = shard_path(&dir, 0);
        let header = io::read_shard_header(&path).unwrap();
        assert_eq!(header.format, io::ShardFormat::Edge2);
        assert_eq!(header.n_edges, 500);
        // decoded multiset identical to what was sampled, and the
        // compressed shard beats the 16 B/edge fixed-width footprint
        assert_eq!(
            io::decoded_checksum(&io::read_binary(&path).unwrap()),
            io::decoded_checksum(&reference)
        );
        assert!(std::fs::metadata(&path).unwrap().len() < 500 * 16);
        // resume auto-detects the format from the header
        let (resumed, completed) = ShardSink::resume(&dir, cfg).unwrap();
        assert_eq!(completed, 1);
        assert_eq!(resumed.report().edges_written, 500);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_open_sweeps_stale_tmp_files() {
        // regression: a fresh (non-resume) run over a directory holding
        // `.tmp` debris from an interrupted earlier run must sweep it —
        // previously only the resume path did
        let dir = std::env::temp_dir().join(format!("sgg_sweep_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(shard_path(&dir, 0).with_extension("sgg.tmp"), b"partial").unwrap();
        std::fs::write(shard_path(&dir, 7).with_extension("sgg.tmp"), b"partial").unwrap();
        let mut sink = ShardSink::new(&dir, ChunkConfig::default()).unwrap();
        sink.edges(&mut chunk(0, 10)).unwrap();
        sink.finish().unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().map(|x| x == "tmp").unwrap_or(false))
            .collect();
        assert!(leftovers.is_empty(), "stale .tmp survived fresh open: {leftovers:?}");
        assert!(shard_path(&dir, 0).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_report_json_roundtrips() {
        let report = StreamReport {
            edges_written: (1u64 << 53) + 7, // exercises the wide-u64 encoding
            shards: 3,
            wall_secs: 1.25,
            peak_buffer_bytes: 4096,
            worker_busy_secs: vec![0.5, 0.75],
            sample_secs: 1.25,
            encode_secs: 0.25,
            write_secs: 0.125,
            writer_busy_secs: 0.0625,
            out_dir: PathBuf::from("/tmp/out"),
            quality: Some(crate::metrics::stream::StructuralReport {
                degree_dist: 0.9375,
                dcc: 0.8125,
            }),
        };
        let doc = Json::parse(&report.to_json().to_string()).unwrap();
        let back = StreamReport::from_json(&doc).unwrap();
        assert_eq!(back.edges_written, report.edges_written);
        assert_eq!(back.shards, report.shards);
        assert_eq!(back.wall_secs.to_bits(), report.wall_secs.to_bits());
        assert_eq!(back.worker_busy_secs, report.worker_busy_secs);
        assert_eq!(back.sample_secs.to_bits(), report.sample_secs.to_bits());
        assert_eq!(back.encode_secs.to_bits(), report.encode_secs.to_bits());
        assert_eq!(back.write_secs.to_bits(), report.write_secs.to_bits());
        assert_eq!(back.writer_busy_secs.to_bits(), report.writer_busy_secs.to_bits());
        assert_eq!(back.out_dir, report.out_dir);
        assert_eq!(back.quality, report.quality);
        // absent quality round-trips as None, not an error
        let mut plain = report.clone();
        plain.quality = None;
        let back = StreamReport::from_json(&plain.to_json()).unwrap();
        assert!(back.quality.is_none());
        // reports written before the stage-time breakdown existed still
        // parse, with the stage fields defaulting to zero
        let doc = Json::parse(
            r#"{"edges_written":1,"shards":1,"wall_secs":1.0,"peak_buffer_bytes":16,
                "worker_busy_secs":[1.0],"out_dir":"/tmp/out"}"#,
        )
        .unwrap();
        let old = StreamReport::from_json(&doc).unwrap();
        assert_eq!(old.sample_secs, 0.0);
        assert_eq!(old.write_secs, 0.0);
    }

    #[test]
    fn worker_encoded_chunks_write_verbatim_and_recycle_buffers() {
        let dir = std::env::temp_dir().join(format!("sgg_sink_enc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ChunkConfig { format: io::ShardFormat::Edge2, ..ChunkConfig::default() };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        let mut expected = Vec::new();
        for i in 0..3usize {
            let mut c = chunk(i, 200 + i);
            let mut bytes = Vec::new();
            io::encode_chunk(&c.edges, io::ShardFormat::Edge2, &mut bytes);
            expected.push(bytes.clone());
            c.encoded = Some(io::EncodedChunk { format: io::ShardFormat::Edge2, bytes });
            sink.edges(&mut c).unwrap();
            if i > 0 {
                // the drained previous write's buffer comes back through
                // the chunk slot, feeding the runner's encode arena
                assert!(c.encoded.is_some(), "chunk {i}: no recycled buffer");
            }
        }
        sink.finish().unwrap();
        for (i, bytes) in expected.iter().enumerate() {
            assert_eq!(&std::fs::read(shard_path(&dir, i)).unwrap(), bytes, "shard {i}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deferred_write_failure_is_sticky_and_fatal() {
        let dir = std::env::temp_dir().join(format!("sgg_sink_sticky_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut sink = ShardSink::new(&dir, ChunkConfig::default()).unwrap();
        // sabotage: replace the output directory with a file, so chunk
        // 0's deferred write fails on the IO thread
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::write(&dir, b"not a directory").unwrap();
        sink.edges(&mut chunk(0, 10)).unwrap(); // async submit succeeds
        let err = sink.edges(&mut chunk(1, 10)).unwrap_err(); // drain surfaces it
        assert!(err.to_string().contains("shard"), "{err}");
        // every later call fails fatally (Error::Data — never transient,
        // so a retrying adapter above cannot spin) without submitting
        let err2 = sink.edges(&mut chunk(2, 10)).unwrap_err();
        assert!(matches!(err2, Error::Data(_)), "{err2}");
        assert!(err2.to_string().contains("disabled after write failure"), "{err2}");
        assert!(sink.finish().is_err());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn cancel_sink_aborts_at_chunk_boundary() {
        let token = CancelToken::new();
        let mut inner = MemorySink::new();
        let mut sink = CancelSink::new(&mut inner, token.clone());
        sink.edges(&mut chunk(0, 5)).unwrap();
        token.cancel();
        let err = sink.edges(&mut chunk(1, 5)).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert!(sink.finish().is_err());
    }

    #[test]
    fn resume_restores_prefix_and_sweeps_leftovers() {
        let dir = std::env::temp_dir().join(format!("sgg_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ChunkConfig { workers: 2, ..ChunkConfig::default() };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        for (i, n) in [(0usize, 10usize), (1, 20), (2, 30)] {
            sink.edges(&mut chunk(i, n)).unwrap();
        }
        sink.finish().unwrap();
        // simulate interruption debris: a staged partial write and a
        // shard past the completed prefix (an empty-chunk gap at 3)
        std::fs::write(shard_path(&dir, 3).with_extension("sgg.tmp"), b"partial").unwrap();
        crate::graph::io::write_binary(&shard_path(&dir, 4), &chunk(4, 5).edges).unwrap();
        let (resumed, completed) = ShardSink::resume(&dir, cfg).unwrap();
        assert_eq!(completed, 3);
        let report = resumed.report();
        assert_eq!(report.shards, 3);
        assert_eq!(report.edges_written, 60);
        assert!(!shard_path(&dir, 4).exists(), "stale post-gap shard survived");
        let tmps: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().path().extension().map(|x| x == "tmp").unwrap_or(false)
            })
            .collect();
        assert!(tmps.is_empty(), "stale .tmp survived");
        std::fs::remove_dir_all(&dir).ok();
    }
}
