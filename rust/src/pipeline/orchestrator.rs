//! Streaming, out-of-core generation (paper §4.5 / Table 3 path).
//!
//! Wraps [`crate::structgen::chunked`] with a disk-shard sink: worker
//! threads sample prefix-partitioned chunks; the writer (caller thread)
//! serializes each chunk to its own shard file. The bounded channel
//! between them is the backpressure mechanism — peak memory is
//! `queue_capacity × chunk` edges regardless of total graph size.

use crate::graph::io;
use crate::structgen::chunked::{generate_chunked, ChunkConfig};
use crate::structgen::kronecker::KroneckerGen;
use crate::Result;
use std::path::PathBuf;

/// Streaming run report (rows of paper Table 3).
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub edges_written: u64,
    pub shards: usize,
    pub wall_secs: f64,
    /// Peak resident edge-buffer bytes (chunks in flight × 16 B/edge).
    pub peak_buffer_bytes: u64,
    pub out_dir: PathBuf,
}

impl std::fmt::Display for StreamReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} edges in {} shards, {:.2}s ({:.1} Medges/s), peak buffer {:.1} MB",
            self.edges_written,
            self.shards,
            self.wall_secs,
            self.edges_written as f64 / self.wall_secs.max(1e-9) / 1e6,
            self.peak_buffer_bytes as f64 / 1e6
        )
    }
}

/// Generate `edges` edges at (n_src × n_dst) and stream them to binary
/// shards under `out_dir` (one file per chunk).
pub fn stream_to_shards(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    edges: u64,
    seed: u64,
    cfg: ChunkConfig,
    out_dir: &std::path::Path,
) -> Result<StreamReport> {
    std::fs::create_dir_all(out_dir)?;
    let t0 = std::time::Instant::now();
    let mut shards = 0usize;
    let mut write_err: Option<crate::Error> = None;
    let total = generate_chunked(gen, n_src, n_dst, edges, seed, cfg, |chunk| {
        if write_err.is_some() {
            return;
        }
        let path = out_dir.join(format!("shard-{:05}.sgg", chunk.index));
        if let Err(e) = io::write_binary(&path, &chunk.edges) {
            write_err = Some(e);
            return;
        }
        shards += 1;
    })?;
    if let Some(e) = write_err {
        return Err(e);
    }
    let peak = (cfg.queue_capacity as u64 + cfg.workers as u64)
        * (edges / 4u64.pow(cfg.prefix_levels).max(1)).max(1)
        * 16;
    Ok(StreamReport {
        edges_written: total,
        shards,
        wall_secs: t0.elapsed().as_secs_f64(),
        peak_buffer_bytes: peak,
        out_dir: out_dir.to_path_buf(),
    })
}

/// Read every shard back into one edge list (for validation / tests).
pub fn read_shards(dir: &std::path::Path) -> Result<crate::graph::EdgeList> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "sgg").unwrap_or(false))
        .collect();
    paths.sort();
    let mut out: Option<crate::graph::EdgeList> = None;
    for p in paths {
        let e = io::read_binary(&p)?;
        match &mut out {
            None => out = Some(e),
            Some(acc) => acc.extend_from(&e),
        }
    }
    out.ok_or_else(|| crate::Error::Data(format!("no shards in {}", dir.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::theta::ThetaS;

    fn tmp_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_orch_{}_{}", std::process::id(), name));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn stream_writes_all_edges() {
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 10_000);
        let dir = tmp_dir("all");
        let cfg = ChunkConfig { prefix_levels: 2, workers: 4, queue_capacity: 2 };
        let report = stream_to_shards(&gen, 1 << 10, 1 << 10, 10_000, 3, cfg, &dir).unwrap();
        assert_eq!(report.edges_written, 10_000);
        assert!(report.shards > 1);
        let back = read_shards(&dir).unwrap();
        assert_eq!(back.len(), 10_000);
        assert!(back.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streamed_equals_collected() {
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 5_000);
        let dir = tmp_dir("eq");
        let cfg = ChunkConfig { prefix_levels: 2, workers: 2, queue_capacity: 2 };
        stream_to_shards(&gen, 512, 512, 5_000, 7, cfg, &dir).unwrap();
        let mut streamed = read_shards(&dir).unwrap();
        let mut collected =
            crate::structgen::chunked::generate_chunked_collect(&gen, 512, 512, 5_000, 7, cfg)
                .unwrap();
        streamed.sort_dedup();
        collected.sort_dedup();
        assert_eq!(streamed.src, collected.src);
        assert_eq!(streamed.dst, collected.dst);
        std::fs::remove_dir_all(&dir).ok();
    }
}
