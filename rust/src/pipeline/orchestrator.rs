//! Streaming, out-of-core generation (paper §4.5 / Table 3 path).
//!
//! This module is a thin convenience wrapper over the unified [`Sink`]
//! path — there is no separate streaming engine here anymore.
//! [`StructureGenerator::generate_into`] decomposes the job into chunks,
//! the [`ParallelChunkRunner`](crate::pipeline::parallel::ParallelChunkRunner)
//! samples them (concurrently when `workers > 1`, with bounded-channel
//! backpressure and in-order delivery), and [`ShardSink`] persists each
//! chunk as its own shard file, aborting generation early on the first
//! write error. Prefer [`crate::pipeline::FittedPipeline::run`] with a
//! [`ShardSink`] (or a `[sink]` stanza in a scenario spec) in new code;
//! [`stream_to_shards`] remains for direct generator-level streaming and
//! the Table 3 experiment.

use crate::pipeline::sink::{ShardSink, Sink, SinkFinish};
use crate::structgen::kronecker::KroneckerGen;
use crate::structgen::chunked::ChunkConfig;
use crate::structgen::StructureGenerator;
use crate::Result;

pub use crate::pipeline::sink::StreamReport;

/// Generate `edges` edges at (n_src × n_dst) and stream them to binary
/// shards under `out_dir` (one file per chunk). A shard-write failure
/// aborts generation at the next chunk boundary and returns the error.
pub fn stream_to_shards(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    edges: u64,
    seed: u64,
    cfg: ChunkConfig,
    out_dir: &std::path::Path,
) -> Result<StreamReport> {
    stream_to_shards_opts(gen, n_src, n_dst, edges, seed, cfg, out_dir, false)
}

/// [`stream_to_shards`] with resume support: with `resume`, the intact
/// shard prefix an interrupted run left under `out_dir` is kept (see
/// [`ShardSink::resume`]), the corresponding chunks are skipped, and
/// the rest regenerate deterministically — the final directory is
/// byte-identical to a single uninterrupted run at any worker count.
#[allow(clippy::too_many_arguments)]
pub fn stream_to_shards_opts(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    edges: u64,
    seed: u64,
    mut cfg: ChunkConfig,
    out_dir: &std::path::Path,
    resume: bool,
) -> Result<StreamReport> {
    // Shard runs encode on the workers: the sink then writes the wire
    // bytes verbatim instead of re-encoding on the reorder thread.
    cfg.encode = true;
    let mut sink = if resume {
        let (sink, completed) = ShardSink::resume(out_dir, cfg)?;
        cfg.resume_from = completed;
        sink
    } else {
        ShardSink::new(out_dir, cfg)?
    };
    gen.generate_into(n_src, n_dst, edges, seed, cfg, &mut |chunk| sink.edges(chunk))?;
    match sink.finish()? {
        SinkFinish::Streamed(report) => Ok(report),
        SinkFinish::Collected(_) => unreachable!("shard sink never collects"),
    }
}

/// Read every shard back into one edge list (for validation / tests).
/// Prefer `metrics::stream::evaluate_shards` when only scores are
/// needed — it never materializes the whole graph.
pub fn read_shards(dir: &std::path::Path) -> Result<crate::graph::EdgeList> {
    let reader = crate::graph::io::ShardReader::open(dir)?;
    let mut out =
        crate::graph::EdgeList::with_capacity(reader.spec(), reader.total_edges() as usize);
    for i in 0..reader.len() {
        out.extend_from(&reader.read(i)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::theta::ThetaS;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_orch_{}_{}", std::process::id(), name));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn stream_writes_all_edges() {
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 10_000);
        let dir = tmp_dir("all");
        let cfg = ChunkConfig { prefix_levels: 2, workers: 4, queue_capacity: 2, ..ChunkConfig::default() };
        let report = stream_to_shards(&gen, 1 << 10, 1 << 10, 10_000, 3, cfg, &dir).unwrap();
        assert_eq!(report.edges_written, 10_000);
        assert!(report.shards > 1);
        // peak estimate comes from real chunk sizes: bounded by the whole
        // graph, and at least the largest shard
        assert!(report.peak_buffer_bytes <= 10_000 * 16);
        assert!(report.peak_buffer_bytes > 0);
        let back = read_shards(&dir).unwrap();
        assert_eq!(back.len(), 10_000);
        assert!(back.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streamed_equals_collected() {
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 5_000);
        let dir = tmp_dir("eq");
        let cfg = ChunkConfig { prefix_levels: 2, workers: 2, queue_capacity: 2, ..ChunkConfig::default() };
        stream_to_shards(&gen, 512, 512, 5_000, 7, cfg, &dir).unwrap();
        let mut streamed = read_shards(&dir).unwrap();
        let mut collected =
            crate::structgen::chunked::generate_chunked_collect(&gen, 512, 512, 5_000, 7, cfg)
                .unwrap();
        streamed.sort_dedup();
        collected.sort_dedup();
        assert_eq!(streamed.src, collected.src);
        assert_eq!(streamed.dst, collected.dst);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn interrupted_then_resumed_is_byte_identical() {
        use crate::pipeline::fault::{FaultPlan, FaultSink};
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 8_000);
        for workers in [1usize, 4] {
            let cfg = ChunkConfig {
                prefix_levels: 2,
                workers,
                queue_capacity: 2,
                ..ChunkConfig::default()
            };
            // reference: one uninterrupted run
            let full = tmp_dir(&format!("full{workers}"));
            stream_to_shards(&gen, 512, 512, 8_000, 11, cfg, &full).unwrap();
            // interrupted run: a fatal sink fault kills it at chunk 5 ...
            let broken = tmp_dir(&format!("broken{workers}"));
            let mut sink = ShardSink::new(&broken, cfg).unwrap();
            let mut faulted = FaultSink::new(&mut sink, FaultPlan::fatal_at(5));
            let err =
                gen.generate_into(512, 512, 8_000, 11, cfg, &mut |c| faulted.edges(c));
            assert!(err.is_err(), "fatal fault must abort the run");
            // ... and `--resume` completes it
            let report =
                stream_to_shards_opts(&gen, 512, 512, 8_000, 11, cfg, &broken, true)
                    .unwrap();
            assert_eq!(report.edges_written, 8_000);
            // the resumed directory is byte-identical to the reference
            let mut names: Vec<String> = std::fs::read_dir(&full)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .collect();
            names.sort();
            let mut resumed_names: Vec<String> = std::fs::read_dir(&broken)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .collect();
            resumed_names.sort();
            assert_eq!(names, resumed_names, "workers={workers}");
            for n in &names {
                let a = std::fs::read(full.join(n)).unwrap();
                let b = std::fs::read(broken.join(n)).unwrap();
                assert_eq!(a, b, "shard {n} differs (workers={workers})");
            }
            std::fs::remove_dir_all(&full).ok();
            std::fs::remove_dir_all(&broken).ok();
        }
    }

    #[test]
    fn write_error_aborts_stream() {
        let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 9), 20_000);
        let dir = tmp_dir("abort");
        let cfg = ChunkConfig { prefix_levels: 3, workers: 2, queue_capacity: 1, ..ChunkConfig::default() };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        // sabotage the output directory mid-stream: replace it with a
        // file so the first shard write fails and generation aborts
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::write(&dir, b"not a directory").unwrap();
        let err = gen.generate_into(1 << 9, 1 << 9, 20_000, 5, cfg, &mut |c| sink.edges(c));
        assert!(err.is_err(), "writes into a file path must fail");
        std::fs::remove_file(&dir).ok();
    }
}
