//! Distributed generation: N shared-nothing hosts, one graph.
//!
//! The chunked engine already makes every chunk an independent unit of
//! work — [`chunk_plan`](crate::structgen::StructureGenerator::chunk_plan)
//! fixes the chunk count, per-chunk edge budgets and per-chunk PRNG
//! streams up front, so chunk `i` samples identically no matter which
//! process (or machine) executes it. This module turns that property
//! into a multi-host protocol:
//!
//! 1. **Plan** ([`plan_run`] / `sgg plan`) — load a `.sggm` model
//!    artifact, resolve the target size, count the chunks *with the same
//!    plan execution will use*, and write a versioned [`RunManifest`]
//!    that pins the model (content hash), the job shape (spec hash) and
//!    a contiguous chunk range per host.
//! 2. **Generate** ([`run_host_range`] / `sgg generate --chunks A..B`) —
//!    each host independently runs its half-open chunk range against the
//!    same artifact, writing shards named by *global* chunk index (so
//!    the union of all host directories is already the canonical
//!    single-host layout) plus a [`HostReport`] carrying per-shard
//!    decoded-edge checksums and a serialized degree-profile partial.
//!    Hosts may write either shard format
//!    ([`io::ShardFormat`]) — determinism is pinned on
//!    the *decoded* edge multiset, not file bytes, so mixed-format runs
//!    validate and merge identically.
//! 3. **Merge** ([`merge_run`] / `sgg merge`) — the coordinator
//!    validates completeness (every chunk exactly once, checksums match,
//!    all hashes agree), assembles the shards into one directory
//!    (hard-linking where possible), and folds the per-host profile
//!    partials with the exact integer-count
//!    [`merge`](crate::metrics::MetricAccumulator::merge) the in-process
//!    engine uses — so the folded profile is **bit-identical** to the
//!    profile of a single-process run from the same artifact and seed.
//!
//! The host report doubles as the host's durable completion record: it
//! is written only after the host's whole range succeeded, so a missing
//! report means an incomplete (or never-run) host. Chunks that sampled
//! zero edges write no shard; they are represented by the *absence* of a
//! per-chunk record inside a report whose range covers them, which is
//! why completeness is validated against the reports rather than against
//! file presence.

use super::registry::Registries;
use super::sink::{shard_path, ShardSink, StreamReport};
use super::spec::SizeSpec;
use super::FittedPipeline;
use crate::graph::io::{self, ShardReader};
use crate::graph::PartiteSpec;
use crate::metrics::accum::MetricAccumulator;
use crate::metrics::degree::{self, DegreeAccumulator, DegreeProfile};
use crate::metrics::stream::{profile_reader_with, StructuralReport, DCC_SAMPLES};
use crate::pipeline::fault::RetryPolicy;
use crate::structgen::chunked::ChunkConfig;
use crate::util::checksum::{fnv1a_file, Fnv1a};
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Run-manifest format identifier (the `format` header field).
pub const RUN_FORMAT: &str = "sgg-run";

/// Run-manifest format version this build reads and writes.
pub const RUN_VERSION: u64 = 1;

/// Host-report format identifier.
pub const HOST_REPORT_FORMAT: &str = "sgg-host-report";

/// Host-report format version this build reads and writes. Version 2
/// switched [`ChunkRecord::checksum`] from raw file bytes to the
/// order-invariant decoded-edge checksum
/// ([`io::decoded_checksum`]), so reports from hosts writing different
/// shard formats validate and merge uniformly.
pub const HOST_REPORT_VERSION: u64 = 2;

/// File name of the per-host completion record inside a host's output
/// directory.
pub const HOST_REPORT_FILE: &str = "host-report.json";

/// File name of the merged quality report inside the merge output
/// directory.
pub const MERGE_REPORT_FILE: &str = "merge-report.json";

/// The (only) shard naming scheme this build understands, recorded in
/// the manifest so a future renaming bumps loudly instead of silently
/// misassembling: chunk `i` lives in `shard-{i:05}.sgg` (see
/// [`shard_path`]).
pub const SHARD_SCHEME: &str = "shard-%05d.sgg";

/// One host's contiguous half-open chunk range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostRange {
    /// Host index (0-based, dense).
    pub host: usize,
    /// First chunk this host owns.
    pub start: usize,
    /// One past the last chunk this host owns.
    pub end: usize,
}

impl HostRange {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("host", Json::from(self.host)),
            ("start", Json::from(self.start)),
            ("end", Json::from(self.end)),
        ])
    }

    fn from_json(v: &Json) -> Result<HostRange> {
        Ok(HostRange {
            host: v.req_usize("host")?,
            start: v.req_usize("start")?,
            end: v.req_usize("end")?,
        })
    }
}

/// The versioned run manifest `sgg plan` writes: everything N hosts and
/// one coordinator must agree on. The two hashes are the protocol's
/// identity checks — [`RunManifest::model_hash`] pins the *exact* model
/// artifact bytes and [`RunManifest::spec_hash`] the resolved job shape,
/// so a host generating from a refitted model or a differently-sized job
/// fails loudly at generate or merge time instead of producing a
/// plausible-looking but wrong graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunManifest {
    /// FNV-1a over the raw bytes of the `.sggm` artifact every host must
    /// generate from.
    pub model_hash: u64,
    /// FNV-1a over the resolved job shape (sizes, seed, scale, prefix
    /// levels, chunk count) — see [`RunManifest::compute_spec_hash`].
    pub spec_hash: u64,
    /// Dataset the model was fitted on (from the artifact's provenance);
    /// the merge-time quality reference.
    pub dataset: String,
    /// Integer scale factor the job was planned at.
    pub scale: u64,
    /// Generation seed shared by every host.
    pub seed: u64,
    /// Chunking depth ([`ChunkConfig::prefix_levels`]) shared by every
    /// host — it determines the chunk decomposition itself.
    pub prefix_levels: u32,
    /// Resolved source-node count.
    pub n_src: u64,
    /// Resolved destination-node count.
    pub n_dst: u64,
    /// Resolved total edge budget.
    pub edges: u64,
    /// Total number of chunks in the plan (the ranges below tile
    /// `[0, total_chunks)` exactly).
    pub total_chunks: usize,
    /// Shard file naming scheme; must equal [`SHARD_SCHEME`].
    pub shard_scheme: String,
    /// Per-host chunk ranges, in host order.
    pub hosts: Vec<HostRange>,
}

impl RunManifest {
    /// The job-shape fingerprint: FNV-1a over the resolved sizes, seed,
    /// scale, chunking depth and chunk count (each eaten as 8
    /// little-endian bytes). Two manifests with equal spec hashes
    /// describe byte-identical jobs modulo the model parameters, which
    /// [`RunManifest::model_hash`] covers separately.
    pub fn compute_spec_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        for x in [
            self.n_src,
            self.n_dst,
            self.edges,
            self.seed,
            self.scale,
            self.prefix_levels as u64,
            self.total_chunks as u64,
        ] {
            h.write_u64(x);
        }
        h.finish()
    }

    /// The chunk range of host `k`.
    pub fn host_range(&self, k: usize) -> Result<HostRange> {
        self.hosts.get(k).copied().ok_or_else(|| {
            Error::Config(format!(
                "host {k} is out of range: the manifest plans {} hosts",
                self.hosts.len()
            ))
        })
    }

    /// Serialize into the versioned manifest document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::from(RUN_FORMAT)),
            ("version", Json::from(RUN_VERSION)),
            ("model_hash", Json::u64_exact(self.model_hash)),
            ("spec_hash", Json::u64_exact(self.spec_hash)),
            ("dataset", Json::from(self.dataset.as_str())),
            ("scale", Json::u64_exact(self.scale)),
            ("seed", Json::u64_exact(self.seed)),
            ("prefix_levels", Json::from(self.prefix_levels)),
            ("n_src", Json::u64_exact(self.n_src)),
            ("n_dst", Json::u64_exact(self.n_dst)),
            ("edges", Json::u64_exact(self.edges)),
            ("total_chunks", Json::from(self.total_chunks)),
            ("shard_scheme", Json::from(self.shard_scheme.as_str())),
            ("hosts", Json::Arr(self.hosts.iter().map(|h| h.to_json()).collect())),
        ])
    }

    /// Inverse of [`RunManifest::to_json`]. Rejects wrong/missing format
    /// headers, unsupported versions, unknown shard schemes, a spec hash
    /// that does not match the manifest's own fields, and host ranges
    /// that fail to tile `[0, total_chunks)` exactly — a hand-edited
    /// manifest fails here, before any host burns CPU on it.
    pub fn from_json(doc: &Json) -> Result<RunManifest> {
        let format = doc
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Data("not a sgg-run manifest (no `format` header)".into()))?;
        if format != RUN_FORMAT {
            return Err(Error::Data(format!("not a sgg-run manifest (format `{format}`)")));
        }
        let version = doc.req_u64("version")?;
        if version != RUN_VERSION {
            return Err(Error::Data(format!(
                "unsupported sgg-run manifest version {version} (this build reads version \
                 {RUN_VERSION}); re-plan the run with a matching build"
            )));
        }
        let manifest = RunManifest {
            model_hash: doc.req_u64("model_hash")?,
            spec_hash: doc.req_u64("spec_hash")?,
            dataset: doc.req_str("dataset")?.to_string(),
            scale: doc.req_u64("scale")?,
            seed: doc.req_u64("seed")?,
            prefix_levels: doc.req_u32("prefix_levels")?,
            n_src: doc.req_u64("n_src")?,
            n_dst: doc.req_u64("n_dst")?,
            edges: doc.req_u64("edges")?,
            total_chunks: doc.req_usize("total_chunks")?,
            shard_scheme: doc.req_str("shard_scheme")?.to_string(),
            hosts: doc
                .req_arr("hosts")?
                .iter()
                .map(HostRange::from_json)
                .collect::<Result<Vec<_>>>()?,
        };
        if manifest.shard_scheme != SHARD_SCHEME {
            return Err(Error::Data(format!(
                "unsupported shard naming scheme `{}` (this build writes `{SHARD_SCHEME}`)",
                manifest.shard_scheme
            )));
        }
        if manifest.spec_hash != manifest.compute_spec_hash() {
            return Err(Error::Data(
                "manifest spec_hash does not match its own job fields (manifest edited \
                 by hand?)"
                    .into(),
            ));
        }
        validate_tiling(
            &manifest
                .hosts
                .iter()
                .map(|h| (h.start, h.end))
                .collect::<Vec<_>>(),
            manifest.total_chunks,
        )?;
        Ok(manifest)
    }

    /// Write the manifest to `path` as a JSON document.
    pub fn save(&self, path: &Path) -> Result<()> {
        let doc = self.to_json();
        std::fs::write(path, format!("{doc}\n")).map_err(|e| {
            Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
        })
    }

    /// Read and validate a manifest from `path`.
    pub fn load(path: &Path) -> Result<RunManifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::Data(format!("{}: invalid manifest JSON: {e}", path.display())))?;
        RunManifest::from_json(&doc).map_err(|e| Error::Data(format!("{}: {e}", path.display())))
    }
}

/// Check that sorted-by-start `(start, end)` ranges tile `[0, total)`
/// exactly: no overlap, no gap, nothing out of bounds. `ranges` may
/// arrive unsorted; empty ranges are rejected.
fn validate_tiling(ranges: &[(usize, usize)], total: usize) -> Result<()> {
    let mut sorted = ranges.to_vec();
    sorted.sort_unstable();
    let mut cursor = 0usize;
    for &(start, end) in &sorted {
        if start >= end {
            return Err(Error::Data(format!("empty or inverted chunk range {start}..{end}")));
        }
        match start.cmp(&cursor) {
            std::cmp::Ordering::Less => {
                return Err(Error::Data(format!(
                    "overlapping chunk ranges: {start}..{end} re-covers chunks below {cursor}"
                )));
            }
            std::cmp::Ordering::Greater => {
                return Err(Error::Data(format!(
                    "chunk range gap: nothing covers chunks {cursor}..{start}"
                )));
            }
            std::cmp::Ordering::Equal => cursor = end,
        }
    }
    if cursor != total {
        return Err(Error::Data(format!(
            "chunk ranges cover {cursor} of {total} chunks (missing {cursor}..{total})"
        )));
    }
    Ok(())
}

/// Plan a distributed run: load the `.sggm` artifact at `model`, resolve
/// the job size at integer `scale`, count the chunks with the *same*
/// [`chunk_plan`](crate::structgen::StructureGenerator::chunk_plan) the
/// hosts will execute, and partition them into `hosts` contiguous ranges
/// (the same largest-first-free static split
/// [`fold_indices`](super::parallel::ParallelChunkRunner::fold_indices)
/// uses: host `k` owns `[k·n/H, (k+1)·n/H)`).
pub fn plan_run(
    model: &Path,
    hosts: usize,
    scale: u64,
    seed: u64,
    prefix_levels: u32,
    regs: &Registries,
) -> Result<RunManifest> {
    if hosts == 0 {
        return Err(Error::Config("a distributed plan needs at least one host".into()));
    }
    let model_hash = fnv1a_file(model)?;
    let fitted = FittedPipeline::load(model, regs)?;
    let (n_src, n_dst, edges) = fitted.struct_gen.scaled_size(scale.max(1));
    let total_chunks = fitted
        .struct_gen
        .chunk_plan(n_src, n_dst, edges, seed, prefix_levels)?
        .n_chunks();
    if hosts > total_chunks {
        return Err(Error::Config(format!(
            "{hosts} hosts but the plan has only {total_chunks} chunks — use fewer hosts \
             or a deeper --prefix-levels"
        )));
    }
    let ranges: Vec<HostRange> = (0..hosts)
        .map(|k| HostRange {
            host: k,
            start: k * total_chunks / hosts,
            end: (k + 1) * total_chunks / hosts,
        })
        .collect();
    let mut manifest = RunManifest {
        model_hash,
        spec_hash: 0,
        dataset: fitted.source().dataset.clone(),
        scale: scale.max(1),
        seed,
        prefix_levels,
        n_src,
        n_dst,
        edges,
        total_chunks,
        shard_scheme: SHARD_SCHEME.to_string(),
        hosts: ranges,
    };
    manifest.spec_hash = manifest.compute_spec_hash();
    Ok(manifest)
}

/// One completed chunk's durable record inside a [`HostReport`]: which
/// shard it produced, how many edges it holds, and the decoded-edge
/// checksum of its contents. Chunks that sampled zero edges write no
/// shard and get no record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRecord {
    /// Global chunk index (also the shard's file name via
    /// [`shard_path`]).
    pub chunk: usize,
    /// Edge count of the shard (must match its header at merge time).
    pub edges: u64,
    /// Order-invariant multiset checksum over the shard's *decoded*
    /// edges ([`io::shard_decoded_checksum`]) — identical no matter
    /// which shard format or edge ordering the host wrote, so merge
    /// validation survives format migrations and re-encodes.
    pub checksum: u64,
}

impl ChunkRecord {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("chunk", Json::from(self.chunk)),
            ("edges", Json::u64_exact(self.edges)),
            ("checksum", Json::u64_exact(self.checksum)),
        ])
    }

    fn from_json(v: &Json) -> Result<ChunkRecord> {
        Ok(ChunkRecord {
            chunk: v.req_usize("chunk")?,
            edges: v.req_u64("edges")?,
            checksum: v.req_u64("checksum")?,
        })
    }
}

/// A serialized [`DegreeAccumulator`] partial: the host's per-node
/// degree counts, shipped inside its [`HostReport`] so the coordinator
/// can fold host profiles with the exact integer-count
/// [`merge`](MetricAccumulator::merge) the in-process engine uses —
/// no re-reading of shards, bit-identical result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProfilePartial {
    /// Node space of the generated graph (identical on every host).
    pub spec: PartiteSpec,
    /// Out-degree count per source node contributed by this host's
    /// chunks.
    pub out: Vec<u32>,
    /// In-degree count per destination node.
    pub in_: Vec<u32>,
    /// Edges this host's chunks contributed.
    pub edges: u64,
}

impl ProfilePartial {
    /// Rebuild the accumulator this partial was serialized from.
    pub fn to_accumulator(&self) -> Result<DegreeAccumulator> {
        DegreeAccumulator::from_counts(self.spec, self.out.clone(), self.in_.clone(), self.edges)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spec", self.spec.to_json()),
            ("out", Json::Arr(self.out.iter().map(|&x| Json::from(x)).collect())),
            ("in", Json::Arr(self.in_.iter().map(|&x| Json::from(x)).collect())),
            ("edges", Json::u64_exact(self.edges)),
        ])
    }

    fn from_json(v: &Json) -> Result<ProfilePartial> {
        Ok(ProfilePartial {
            spec: PartiteSpec::from_json(v.req("spec")?)?,
            out: v.req_u32s("out")?,
            in_: v.req_u32s("in")?,
            edges: v.req_u64("edges")?,
        })
    }
}

/// The durable completion record one host writes (as
/// [`HOST_REPORT_FILE`] in its output directory) after its whole chunk
/// range succeeded: identity hashes, the range, per-shard checksums, and
/// the host's degree-profile partial. Written last, so its presence
/// certifies the directory is complete.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostReport {
    /// Copy of the manifest's model hash (merge cross-checks it).
    pub model_hash: u64,
    /// Copy of the manifest's spec hash.
    pub spec_hash: u64,
    /// First chunk this host ran.
    pub start: usize,
    /// One past the last chunk this host ran.
    pub end: usize,
    /// One record per non-empty chunk in `[start, end)`, in chunk order.
    pub chunks: Vec<ChunkRecord>,
    /// Degree-profile partial over this host's shards; `None` when every
    /// chunk in the range sampled zero edges.
    pub profile: Option<ProfilePartial>,
}

impl HostReport {
    /// Serialize into the versioned host-report document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::from(HOST_REPORT_FORMAT)),
            ("version", Json::from(HOST_REPORT_VERSION)),
            ("model_hash", Json::u64_exact(self.model_hash)),
            ("spec_hash", Json::u64_exact(self.spec_hash)),
            ("start", Json::from(self.start)),
            ("end", Json::from(self.end)),
            ("chunks", Json::Arr(self.chunks.iter().map(|c| c.to_json()).collect())),
            (
                "profile",
                match &self.profile {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Inverse of [`HostReport::to_json`], with the same format/version
    /// gating as the manifest.
    pub fn from_json(doc: &Json) -> Result<HostReport> {
        let format = doc
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Data("not a sgg host report (no `format` header)".into()))?;
        if format != HOST_REPORT_FORMAT {
            return Err(Error::Data(format!("not a sgg host report (format `{format}`)")));
        }
        let version = doc.req_u64("version")?;
        if version != HOST_REPORT_VERSION {
            return Err(Error::Data(format!(
                "unsupported host-report version {version} (this build reads version \
                 {HOST_REPORT_VERSION})"
            )));
        }
        Ok(HostReport {
            model_hash: doc.req_u64("model_hash")?,
            spec_hash: doc.req_u64("spec_hash")?,
            start: doc.req_usize("start")?,
            end: doc.req_usize("end")?,
            chunks: doc
                .req_arr("chunks")?
                .iter()
                .map(ChunkRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
            profile: match doc.opt("profile") {
                Some(p) => Some(ProfilePartial::from_json(p)?),
                None => None,
            },
        })
    }

    /// Write the report into `dir` (as [`HOST_REPORT_FILE`]).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(HOST_REPORT_FILE);
        let doc = self.to_json();
        std::fs::write(&path, format!("{doc}\n")).map_err(|e| {
            Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
        })
    }

    /// Read a host report from `dir`.
    pub fn load(dir: &Path) -> Result<HostReport> {
        let path = dir.join(HOST_REPORT_FILE);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Data(format!(
                "{}: {e} — missing host report (did the host run complete?)",
                path.display()
            ))
        })?;
        let doc = Json::parse(&text).map_err(|e| {
            Error::Data(format!("{}: invalid host report JSON: {e}", path.display()))
        })?;
        HostReport::from_json(&doc).map_err(|e| Error::Data(format!("{}: {e}", path.display())))
    }
}

/// Run one host's slice of a planned distributed run: regenerate chunks
/// `[start, end)` of the manifest's job from the artifact at `model`
/// into `out_dir`, then record per-shard checksums and the host's degree
/// partial in a [`HostReport`] (written into `out_dir` last, as the
/// completion certificate).
///
/// Identity is enforced before any sampling: the artifact's content hash
/// must equal the manifest's, and the loaded model must resolve to the
/// manifest's exact job shape and chunk count. With `resume`, an
/// interrupted host run restarts from its intact shard prefix
/// ([`ShardSink::resume_range`]) — the finished directory is
/// byte-identical either way.
///
/// `format` picks the shard encoding this host writes
/// ([`io::ShardFormat`]); hosts of one run may mix formats freely,
/// because every checksum in the protocol is over *decoded* edges, not
/// file bytes.
#[allow(clippy::too_many_arguments)]
pub fn run_host_range(
    model: &Path,
    manifest: &RunManifest,
    start: usize,
    end: usize,
    out_dir: &Path,
    workers: usize,
    resume: bool,
    format: io::ShardFormat,
    regs: &Registries,
) -> Result<(HostReport, StreamReport)> {
    if start >= end || end > manifest.total_chunks {
        return Err(Error::Config(format!(
            "chunk range {start}..{end} is not a non-empty subrange of the plan's \
             0..{}",
            manifest.total_chunks
        )));
    }
    let model_hash = fnv1a_file(model)?;
    if model_hash != manifest.model_hash {
        return Err(Error::Data(format!(
            "{} does not match the manifest's model (artifact hash {model_hash:016x}, \
             manifest {:016x}) — every host must generate from the exact artifact the \
             run was planned with",
            model.display(),
            manifest.model_hash
        )));
    }
    let fitted = FittedPipeline::load(model, regs)?;
    let planned = fitted
        .struct_gen
        .chunk_plan(
            manifest.n_src,
            manifest.n_dst,
            manifest.edges,
            manifest.seed,
            manifest.prefix_levels,
        )?
        .n_chunks();
    if planned != manifest.total_chunks {
        return Err(Error::Data(format!(
            "model decomposes this job into {planned} chunks but the manifest promises \
             {} — the manifest was planned against a different build or model",
            manifest.total_chunks
        )));
    }

    let mut chunks = ChunkConfig {
        prefix_levels: manifest.prefix_levels,
        workers: workers.max(1),
        resume_from: start,
        stop_before: Some(end),
        format,
        encode: true,
        ..ChunkConfig::default()
    };
    let mut sink = if resume {
        let (sink, completed) = ShardSink::resume_range(out_dir, chunks, start)?;
        chunks.resume_from = completed.min(end);
        sink
    } else {
        ShardSink::new(out_dir, chunks)?
    };
    crate::info!(
        "host range {start}..{end} of {} chunks → {}",
        manifest.total_chunks,
        out_dir.display()
    );
    let size = SizeSpec::Sized {
        n_src: manifest.n_src,
        n_dst: manifest.n_dst,
        edges: manifest.edges,
    };
    let stream = match fitted.run(size, chunks, &mut sink, manifest.seed)? {
        super::SinkOutput::Streamed(r) => r,
        super::SinkOutput::Dataset(_) => unreachable!("shard sinks always stream"),
    };

    // Post-run accounting is a separate pass over the finished shards so
    // a resumed run records resumed chunks too: checksum + header edge
    // count per shard, then the host's degree partial. The decode-heavy
    // checksum pass runs on the worker pool (contiguous chunk ranges per
    // worker, partials concatenated in worker order, so the record list
    // stays in chunk order).
    let partials = crate::pipeline::parallel::ParallelChunkRunner::new(workers.max(1), 1)
        .fold_indices(
            end - start,
            |_worker| Vec::new(),
            |records: &mut Vec<ChunkRecord>, i| {
                let chunk = start + i;
                let path = shard_path(out_dir, chunk);
                if !path.exists() {
                    return Ok(()); // zero-edge chunk: no shard by design
                }
                let (_spec, edges) = io::read_binary_header(&path)?;
                records.push(ChunkRecord {
                    chunk,
                    edges,
                    checksum: io::shard_decoded_checksum(&path)?,
                });
                Ok(())
            },
        )?;
    let records: Vec<ChunkRecord> = partials.into_iter().flatten().collect();
    let profile = if records.is_empty() {
        None
    } else {
        let reader = ShardReader::open(out_dir)?;
        let (prof, scan) =
            profile_reader_with(&reader, workers.max(1), None, RetryPolicy::default())?;
        Some(ProfilePartial {
            spec: reader.spec(),
            out: prof.out_degrees().to_vec(),
            in_: prof.in_degrees().to_vec(),
            edges: scan.edges,
        })
    };
    let report = HostReport {
        model_hash,
        spec_hash: manifest.spec_hash,
        start,
        end,
        chunks: records,
        profile,
    };
    report.save(out_dir)?;
    Ok((report, stream))
}

/// What [`merge_run`] validated and assembled.
#[derive(Clone, Debug)]
pub struct MergeReport {
    /// Number of host reports folded.
    pub hosts: usize,
    /// Total chunks the run covered (= the manifest's).
    pub chunks: usize,
    /// Shard files assembled (non-empty chunks).
    pub shards: usize,
    /// Total edges in the merged graph.
    pub edges: u64,
    /// [`degree::profile_hash`] of the folded degree profile — equal to
    /// the hash of a single-process run's profile from the same artifact
    /// and seed.
    pub profile_hash: u64,
    /// Folded structural quality against the fit source's degree
    /// profile, when the caller supplied one.
    pub quality: Option<StructuralReport>,
    /// Merge wall-clock seconds (validation + assembly + fold).
    pub wall_secs: f64,
    /// Seconds spent in the per-shard size/checksum re-verification
    /// pass (wall clock; the pass runs on the merge's worker pool).
    pub verify_secs: f64,
    /// Shard bytes assembled into the merged directory.
    pub bytes: u64,
    /// The merged output directory.
    pub out_dir: PathBuf,
}

impl std::fmt::Display for MergeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "merged {} hosts / {} chunks: {} edges in {} shards → {} \
             ({:.2}s, {:.1} MB, profile {:016x})",
            self.hosts,
            self.chunks,
            self.edges,
            self.shards,
            self.out_dir.display(),
            self.wall_secs,
            self.bytes as f64 / 1e6,
            self.profile_hash
        )?;
        if let Some(q) = &self.quality {
            write!(f, ", quality: {q}")?;
        }
        Ok(())
    }
}

impl MergeReport {
    /// Serialize for [`MERGE_REPORT_FILE`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::from("sgg-merge-report")),
            ("version", Json::from(1u64)),
            ("hosts", Json::from(self.hosts)),
            ("chunks", Json::from(self.chunks)),
            ("shards", Json::from(self.shards)),
            ("edges", Json::u64_exact(self.edges)),
            ("profile_hash", Json::u64_exact(self.profile_hash)),
            (
                "degree_dist",
                self.quality.map(|q| Json::from(q.degree_dist)).unwrap_or(Json::Null),
            ),
            ("dcc", self.quality.map(|q| Json::from(q.dcc)).unwrap_or(Json::Null)),
            ("wall_secs", Json::from(self.wall_secs)),
            ("verify_secs", Json::from(self.verify_secs)),
            ("bytes", Json::u64_exact(self.bytes)),
        ])
    }
}

/// Validate and fold a distributed run: check every host report against
/// the manifest (model/spec hashes), check the ranges tile
/// `[0, total_chunks)` exactly, verify every recorded shard against its
/// checksum and header, assemble the shards into `out_dir` (hard-link
/// with copy fallback — names are already canonical), and fold the
/// per-host degree partials into one profile whose edge total must equal
/// the manifest's.
///
/// With `reference` supplied (the fit source's degree profile), the
/// folded profile is scored into a [`StructuralReport`] — bit-identical
/// to `sgg eval` over the merged directory. The [`MergeReport`] is also
/// written into `out_dir` as [`MERGE_REPORT_FILE`].
pub fn merge_run(
    manifest: &RunManifest,
    host_dirs: &[PathBuf],
    out_dir: &Path,
    reference: Option<&DegreeProfile>,
) -> Result<MergeReport> {
    merge_run_with(manifest, host_dirs, out_dir, reference, 1)
}

/// [`merge_run`] with an explicit worker count for the decode-heavy
/// per-shard re-verification pass (`sgg merge --workers`). Verification
/// order does not affect the result — every shard is checked
/// independently and the first failure aborts the merge — so any worker
/// count produces the same report (modulo timings).
pub fn merge_run_with(
    manifest: &RunManifest,
    host_dirs: &[PathBuf],
    out_dir: &Path,
    reference: Option<&DegreeProfile>,
    workers: usize,
) -> Result<MergeReport> {
    let t0 = Instant::now();
    if host_dirs.is_empty() {
        return Err(Error::Config("merge needs at least one host directory".into()));
    }
    let mut reports = Vec::with_capacity(host_dirs.len());
    for dir in host_dirs {
        let report = HostReport::load(dir)?;
        if report.model_hash != manifest.model_hash {
            return Err(Error::Data(format!(
                "{}: host generated from a different model artifact (hash {:016x}, \
                 manifest {:016x})",
                dir.display(),
                report.model_hash,
                manifest.model_hash
            )));
        }
        if report.spec_hash != manifest.spec_hash {
            return Err(Error::Data(format!(
                "{}: host ran a different job shape (spec hash {:016x}, manifest \
                 {:016x})",
                dir.display(),
                report.spec_hash,
                manifest.spec_hash
            )));
        }
        reports.push((dir.clone(), report));
    }
    validate_tiling(
        &reports.iter().map(|(_, r)| (r.start, r.end)).collect::<Vec<_>>(),
        manifest.total_chunks,
    )?;

    // Cheap structural checks first (no IO): records inside their host's
    // range, and each degree partial covering exactly the edges its
    // shard records sum to.
    for (dir, report) in &reports {
        let mut host_edges = 0u64;
        for rec in &report.chunks {
            if rec.chunk < report.start || rec.chunk >= report.end {
                return Err(Error::Data(format!(
                    "{}: chunk {} recorded outside the host's range {}..{}",
                    dir.display(),
                    rec.chunk,
                    report.start,
                    report.end
                )));
            }
            host_edges += rec.edges;
        }
        let profiled = report.profile.as_ref().map(|p| p.edges).unwrap_or(0);
        if profiled != host_edges {
            return Err(Error::Data(format!(
                "{}: degree partial covers {profiled} edges but the shard records sum \
                 to {host_edges}",
                dir.display()
            )));
        }
    }

    // Verify every recorded shard before moving anything: header edge
    // count vs record, then a full decoded-edge checksum pass — format-
    // and order-invariant, so SGGEDGE1 and SGGEDGE2 hosts validate the
    // same way. Each shard verifies independently, so the pass fans out
    // over the worker pool (contiguous ranges of the flattened record
    // list) and the first failure aborts the merge.
    let to_verify: Vec<(PathBuf, u64, u64)> = reports
        .iter()
        .flat_map(|(dir, report)| {
            report
                .chunks
                .iter()
                .map(|rec| (shard_path(dir, rec.chunk), rec.edges, rec.checksum))
        })
        .collect();
    let tv = Instant::now();
    crate::pipeline::parallel::ParallelChunkRunner::new(workers.max(1), 1).fold_indices(
        to_verify.len(),
        |_worker| (),
        |_acc, i| {
            let (path, rec_edges, rec_checksum) = &to_verify[i];
            let (_spec, edges) = io::read_binary_header(path)?;
            if edges != *rec_edges {
                return Err(Error::Data(format!(
                    "{}: holds {edges} edges but the host report recorded {rec_edges} \
                     — shard rewritten after the run?",
                    path.display()
                )));
            }
            let checksum = io::shard_decoded_checksum(path)?;
            if checksum != *rec_checksum {
                return Err(Error::Data(format!(
                    "{}: decoded-edge checksum mismatch ({checksum:016x}, host report \
                     recorded {rec_checksum:016x}) — shard corrupted in transit?",
                    path.display()
                )));
            }
            Ok(())
        },
    )?;
    let verify_secs = tv.elapsed().as_secs_f64();

    // Assemble: every shard keeps its canonical name, so the merged
    // directory decodes to the same graph as a single-host run's output
    // (and is byte-identical to it when the formats match).
    std::fs::create_dir_all(out_dir)?;
    let mut shards = 0usize;
    let mut bytes = 0u64;
    for (dir, report) in &reports {
        for rec in &report.chunks {
            let src = shard_path(dir, rec.chunk);
            let dst = shard_path(out_dir, rec.chunk);
            if dst.exists() {
                std::fs::remove_file(&dst)?;
            }
            if std::fs::hard_link(&src, &dst).is_err() {
                // cross-device (or FS without hard links): fall back to
                // a plain copy
                std::fs::copy(&src, &dst)?;
            }
            shards += 1;
            bytes += std::fs::metadata(&dst)?.len();
        }
    }

    // Fold the degree partials with the exact in-process merge.
    let mut acc = DegreeAccumulator::new();
    for (_dir, report) in &reports {
        if let Some(partial) = &report.profile {
            acc.merge(partial.to_accumulator()?);
        }
    }
    if acc.edges_observed() != manifest.edges {
        return Err(Error::Data(format!(
            "merged run holds {} edges but the manifest promises {} — a host ran an \
             incomplete or wrong-sized job",
            acc.edges_observed(),
            manifest.edges
        )));
    }
    let folded = acc.finalize();
    let quality = reference.map(|orig| StructuralReport {
        degree_dist: degree::degree_dist_score_profiles(orig, &folded),
        dcc: degree::dcc_profiles(orig, &folded, DCC_SAMPLES),
    });
    let report = MergeReport {
        hosts: reports.len(),
        chunks: manifest.total_chunks,
        shards,
        edges: manifest.edges,
        profile_hash: degree::profile_hash(&folded),
        quality,
        wall_secs: t0.elapsed().as_secs_f64(),
        verify_secs,
        bytes,
        out_dir: out_dir.to_path_buf(),
    };
    let doc = report.to_json();
    let path = out_dir.join(MERGE_REPORT_FILE);
    std::fs::write(&path, format!("{doc}\n")).map_err(|e| {
        Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
    })?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiling_accepts_exact_cover_in_any_order() {
        validate_tiling(&[(4, 9), (0, 4), (9, 16)], 16).unwrap();
        validate_tiling(&[(0, 1)], 1).unwrap();
    }

    #[test]
    fn tiling_rejects_gap_overlap_and_short_cover() {
        let gap = validate_tiling(&[(0, 4), (6, 16)], 16).unwrap_err();
        assert!(gap.to_string().contains("gap"), "{gap}");
        let overlap = validate_tiling(&[(0, 8), (4, 16)], 16).unwrap_err();
        assert!(overlap.to_string().contains("overlap"), "{overlap}");
        let dup = validate_tiling(&[(0, 8), (0, 8), (8, 16)], 16).unwrap_err();
        assert!(dup.to_string().contains("overlap"), "{dup}");
        let short = validate_tiling(&[(0, 8)], 16).unwrap_err();
        assert!(short.to_string().contains("8 of 16"), "{short}");
        let empty = validate_tiling(&[(0, 8), (8, 8), (8, 16)], 16).unwrap_err();
        assert!(empty.to_string().contains("empty"), "{empty}");
    }

    #[test]
    fn manifest_rejects_foreign_and_edited_documents() {
        let not_a_manifest = Json::obj(vec![("hello", Json::from(1u64))]);
        let err = RunManifest::from_json(&not_a_manifest).unwrap_err();
        assert!(err.to_string().contains("no `format` header"), "{err}");

        let wrong = Json::obj(vec![("format", Json::from("sggm"))]);
        let err = RunManifest::from_json(&wrong).unwrap_err();
        assert!(err.to_string().contains("format `sggm`"), "{err}");
    }
}
