//! The `.sggm` model artifact: a serialized [`FittedPipeline`].
//!
//! The paper's central premise is that the framework "learns a series of
//! parametric models from proprietary datasets *that can be released* to
//! researchers" — the fitted models, not the data, are the shareable
//! unit. This module implements that release format: every fitted
//! component serializes its state (`save_state`, the **ModelState**
//! capability on the three component traits) into a single versioned,
//! self-describing JSON document, and [`FittedPipeline::load`]
//! reconstructs the pipeline through the state-loader registries without
//! ever touching the source dataset.
//!
//! Layout (format version 1):
//!
//! ```json
//! {
//!   "format": "sggm", "version": 1,
//!   "name": "ieee-fraud", "seed": 23134,
//!   "source": { "dataset": "...", "spec": {...}, "edges": N,
//!               "edge_feature_cols": [...], "node_feature_cols": [...] },
//!   "structure":     { "backend": "kronecker", "state": {...} },
//!   "edge_features": { "backend": "kde",       "state": {...} },
//!   "edge_aligner":  { "backend": "xgboost",   "state": {...} },
//!   "node_features": { ... } | null,
//!   "node_aligner":  { ... } | null
//! }
//! ```
//!
//! Guarantees:
//!
//! * **Bit-identical generation** — for the same seed (and any worker
//!   count), `load(...).run(...)` produces exactly the output
//!   `fit(...).run(...)` would have.
//! * **Versioned** — a wrong `format` or unsupported `version` is
//!   rejected with a clear error before any component is touched.
//! * **Open** — backend names resolve through the same open registries
//!   as fit-time factories, so custom components can participate by
//!   registering a state loader under their display name.

use super::registry::Registries;
use super::FittedPipeline;
use crate::aligner::Aligner;
use crate::featgen::FeatureGenerator;
use crate::graph::PartiteSpec;
use crate::structgen::StructureGenerator;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// Artifact format identifier (the `format` header field).
pub const SGGM_FORMAT: &str = "sggm";

/// Artifact format version this build reads and writes.
pub const SGGM_VERSION: u64 = 1;

/// Summary of the dataset a pipeline was fitted on, carried in the
/// artifact so a consumer can sanity-check provenance and shape without
/// access to the (possibly proprietary) source data.
#[derive(Clone, Debug, PartialEq)]
pub struct SourceSummary {
    /// Registry name of the source dataset.
    pub dataset: String,
    /// Partite layout of the source graph.
    pub spec: PartiteSpec,
    /// Edge count of the source graph.
    pub edges: u64,
    /// Edge-feature column names, in order.
    pub edge_feature_cols: Vec<String>,
    /// Node-feature column names (None when the source had none).
    pub node_feature_cols: Option<Vec<String>>,
}

impl SourceSummary {
    /// Serialize into the artifact's `source` field.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::from(self.dataset.as_str())),
            ("spec", self.spec.to_json()),
            ("edges", Json::u64_exact(self.edges)),
            (
                "edge_feature_cols",
                Json::Arr(self.edge_feature_cols.iter().map(|n| Json::from(n.as_str())).collect()),
            ),
            (
                "node_feature_cols",
                match &self.node_feature_cols {
                    Some(cols) => {
                        Json::Arr(cols.iter().map(|n| Json::from(n.as_str())).collect())
                    }
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Inverse of [`SourceSummary::to_json`].
    pub fn from_json(v: &Json) -> Result<SourceSummary> {
        Ok(SourceSummary {
            dataset: v.req_str("dataset")?.to_string(),
            spec: PartiteSpec::from_json(v.req("spec")?)?,
            edges: v.req_u64("edges")?,
            edge_feature_cols: v.req_strs("edge_feature_cols")?,
            node_feature_cols: match v.opt("node_feature_cols") {
                Some(_) => Some(v.req_strs("node_feature_cols")?),
                None => None,
            },
        })
    }
}

/// One serialized component: its backend name plus opaque state.
fn component_json(backend: &str, state: Json) -> Json {
    Json::obj(vec![("backend", Json::from(backend)), ("state", state)])
}

impl FittedPipeline {
    /// Serialize the whole fitted pipeline into the `.sggm` JSON
    /// document (see the module docs for the layout).
    pub fn to_artifact_json(&self) -> Result<Json> {
        let node_features = match &self.node_feat_gen {
            Some(gen) => component_json(gen.name(), gen.save_state()?),
            None => Json::Null,
        };
        let node_aligner = match &self.node_aligner {
            Some(a) => component_json(a.name(), a.save_state()?),
            None => Json::Null,
        };
        let doc = Json::obj(vec![
            ("format", Json::from(SGGM_FORMAT)),
            ("version", Json::from(SGGM_VERSION)),
            ("name", Json::from(self.name.as_str())),
            ("seed", Json::u64_exact(self.seed)),
            ("source", self.source.to_json()),
            (
                "structure",
                component_json(self.struct_gen.name(), self.struct_gen.save_state()?),
            ),
            (
                "edge_features",
                component_json(self.edge_feat_gen.name(), self.edge_feat_gen.save_state()?),
            ),
            (
                "edge_aligner",
                component_json(self.edge_aligner.name(), self.edge_aligner.save_state()?),
            ),
            ("node_features", node_features),
            ("node_aligner", node_aligner),
        ]);
        // JSON cannot represent NaN/inf — fail the export now, with the
        // source data still at hand, rather than shipping an artifact
        // that only errors when someone tries to load it elsewhere
        if doc.has_non_finite() {
            return Err(Error::Data(
                "refusing to export artifact: a fitted component contains a non-finite \
                 parameter (NaN or infinity) — refit before saving"
                    .into(),
            ));
        }
        Ok(doc)
    }

    /// Write the pipeline to a `.sggm` model artifact at `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let doc = self.to_artifact_json()?;
        std::fs::write(path, format!("{doc}\n")).map_err(|e| {
            Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
        })?;
        crate::info!("saved model artifact {}", path.display());
        Ok(())
    }

    /// Reconstruct a pipeline from a parsed artifact document,
    /// resolving each component's backend against `regs`' state-loader
    /// registries. Rejects wrong/missing format headers, unsupported
    /// versions, and unknown backends with descriptive errors.
    pub fn from_artifact_json(doc: &Json, regs: &Registries) -> Result<FittedPipeline> {
        let format = doc
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Data("not a .sggm model artifact (no `format` header)".into()))?;
        if format != SGGM_FORMAT {
            return Err(Error::Data(format!(
                "not a .sggm model artifact (format `{format}`)"
            )));
        }
        let version = doc.req_u64("version")?;
        if version != SGGM_VERSION {
            return Err(Error::Data(format!(
                "unsupported .sggm format version {version} (this build reads version \
                 {SGGM_VERSION}); re-export the artifact with a matching build"
            )));
        }

        let structure = doc.req("structure")?;
        let struct_gen =
            regs.structure_states.resolve(structure.req_str("backend")?)?(structure.req("state")?)?;
        let ef = doc.req("edge_features")?;
        let edge_feat_gen = regs.feature_states.resolve(ef.req_str("backend")?)?(ef.req("state")?)?;
        let ea = doc.req("edge_aligner")?;
        let edge_aligner = regs.aligner_states.resolve(ea.req_str("backend")?)?(ea.req("state")?)?;

        let node_feat_gen = match doc.opt("node_features") {
            Some(nf) => {
                Some(regs.feature_states.resolve(nf.req_str("backend")?)?(nf.req("state")?)?)
            }
            None => None,
        };
        let node_aligner = match doc.opt("node_aligner") {
            Some(na) => {
                Some(regs.aligner_states.resolve(na.req_str("backend")?)?(na.req("state")?)?)
            }
            None => None,
        };
        if node_feat_gen.is_some() != node_aligner.is_some() {
            return Err(Error::Data(
                "artifact: `node_features` and `node_aligner` must both be present or both null"
                    .into(),
            ));
        }

        Ok(FittedPipeline {
            name: doc.req_str("name")?.to_string(),
            struct_gen,
            edge_feat_gen,
            edge_aligner,
            node_feat_gen,
            node_aligner,
            seed: doc.req_u64("seed")?,
            source: SourceSummary::from_json(doc.req("source")?)?,
        })
    }

    /// Load a pipeline from a `.sggm` model artifact. The source dataset
    /// is *not* needed — this is the paper's release path: fit once where
    /// the data lives, ship the artifact, generate anywhere. Generation
    /// from the loaded pipeline is bit-identical to generation from the
    /// originally fitted one for the same seed and any worker count.
    pub fn load(path: &Path, regs: &Registries) -> Result<FittedPipeline> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::Data(format!("{}: invalid artifact JSON: {e}", path.display())))?;
        Self::from_artifact_json(&doc, regs)
            .map_err(|e| Error::Data(format!("{}: {e}", path.display())))
    }

    /// Read only the provenance header of a `.sggm` artifact — the
    /// [`SourceSummary`] naming the fit dataset and its shape — without
    /// reconstructing any fitted component (no GBT trees, alias tables
    /// or encoder state are deserialized). Validates the same
    /// format/version headers as [`FittedPipeline::load`]. Used by
    /// `sgg eval --model`, which only needs the reference dataset name.
    pub fn read_provenance(path: &Path) -> Result<SourceSummary> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::Data(format!("{}: invalid artifact JSON: {e}", path.display())))?;
        let provenance = || -> Result<SourceSummary> {
            let format = doc.get("format").and_then(Json::as_str).ok_or_else(|| {
                Error::Data("not a .sggm model artifact (no `format` header)".into())
            })?;
            if format != SGGM_FORMAT {
                return Err(Error::Data(format!(
                    "not a .sggm model artifact (format `{format}`)"
                )));
            }
            let version = doc.req_u64("version")?;
            if version != SGGM_VERSION {
                return Err(Error::Data(format!(
                    "unsupported .sggm format version {version} (this build reads version \
                     {SGGM_VERSION}); re-export the artifact with a matching build"
                )));
            }
            SourceSummary::from_json(doc.req("source")?)
        };
        provenance().map_err(|e| Error::Data(format!("{}: {e}", path.display())))
    }
}
