//! String-keyed component registries: the open counterpart of the old
//! closed `StructKind`/`FeatKind`/`AlignKind` enums. Components register a
//! factory under a canonical name (plus aliases); scenario specs and the
//! pipeline builder resolve them by name, and unknown names fail with the
//! full list of registered backends.

use crate::aligner::{AlignerFactory, AlignerStateLoader};
use crate::featgen::{FeatureGeneratorFactory, FeatureStateLoader};
use crate::structgen::{StructureGeneratorFactory, StructureStateLoader};
use crate::{Error, Result};
use std::collections::BTreeMap;

/// A name → factory table for one component kind.
pub struct Registry<F> {
    kind: &'static str,
    entries: BTreeMap<String, F>,
    aliases: BTreeMap<String, String>,
}

impl<F> Registry<F> {
    /// Empty registry; `kind` labels error messages ("structure", ...).
    pub fn new(kind: &'static str) -> Registry<F> {
        Registry { kind, entries: BTreeMap::new(), aliases: BTreeMap::new() }
    }

    /// Register (or replace) a factory under its canonical name.
    pub fn register(&mut self, name: &str, factory: F) {
        self.entries.insert(name.to_string(), factory);
    }

    /// Register an alias for a canonical name.
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        self.aliases.insert(alias.to_string(), canonical.to_string());
    }

    /// Canonical names, sorted (aliases not listed).
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// True when `name` (or an alias) is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_ok()
    }

    /// Look up a factory by name or alias. Unknown names produce a
    /// [`Error::Config`] listing every registered backend.
    pub fn resolve(&self, name: &str) -> Result<&F> {
        let canonical = self.aliases.get(name).map(String::as_str).unwrap_or(name);
        self.entries.get(canonical).ok_or_else(|| {
            Error::Config(format!(
                "unknown {} backend `{name}`; registered: {}",
                self.kind,
                self.names().join(", ")
            ))
        })
    }
}

/// The component registries a pipeline resolves against: fit-time
/// factories (dataset → fitted component) plus `.sggm` state loaders
/// (artifact JSON → fitted component), both keyed by backend name.
pub struct Registries {
    /// Structure-generator factories, keyed by backend name.
    pub structure: Registry<StructureGeneratorFactory>,
    /// Feature-generator factories (serve both the edge and node legs).
    pub features: Registry<FeatureGeneratorFactory>,
    /// Aligner factories.
    pub aligners: Registry<AlignerFactory>,
    /// Structure state loaders for `.sggm` artifacts.
    pub structure_states: Registry<StructureStateLoader>,
    /// Feature-generator state loaders for `.sggm` artifacts.
    pub feature_states: Registry<FeatureStateLoader>,
    /// Aligner state loaders for `.sggm` artifacts.
    pub aligner_states: Registry<AlignerStateLoader>,
}

impl Registries {
    /// Empty registries (for fully custom component sets).
    pub fn empty() -> Registries {
        Registries {
            structure: Registry::new("structure"),
            features: Registry::new("feature"),
            aligners: Registry::new("aligner"),
            structure_states: Registry::new("structure-state"),
            feature_states: Registry::new("feature-state"),
            aligner_states: Registry::new("aligner-state"),
        }
    }

    /// Registries pre-loaded with every built-in backend.
    pub fn builtin() -> Registries {
        let mut r = Registries::empty();
        crate::structgen::register_builtins(&mut r.structure);
        crate::featgen::register_builtins(&mut r.features);
        crate::aligner::register_builtins(&mut r.aligners);
        crate::structgen::register_state_loaders(&mut r.structure_states);
        crate::featgen::register_state_loaders(&mut r.feature_states);
        crate::aligner::register_state_loaders(&mut r.aligner_states);
        r
    }
}

impl Default for Registries {
    fn default() -> Self {
        Registries::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_structure_names_and_aliases() {
        let r = Registries::builtin();
        for name in ["kronecker", "kronecker-noisy", "erdos-renyi", "sbm", "trilliong"] {
            assert!(r.structure.contains(name), "missing {name}");
        }
        for alias in ["ours", "random", "er", "graphworld"] {
            assert!(r.structure.contains(alias), "missing alias {alias}");
        }
    }

    #[test]
    fn builtin_feature_and_aligner_names() {
        let r = Registries::builtin();
        for name in ["kde", "random", "gaussian", "gan"] {
            assert!(r.features.contains(name), "missing {name}");
        }
        assert!(r.features.contains("mvg"));
        for name in ["learned", "random"] {
            assert!(r.aligners.contains(name), "missing {name}");
        }
        assert!(r.aligners.contains("xgboost"));
    }

    #[test]
    fn state_loaders_cover_every_backend_display_name() {
        // artifacts record `Component::name()` — every display name
        // (including "random"/"graphworld"/"xgboost") must resolve to a
        // state loader
        let r = Registries::builtin();
        for name in ["kronecker", "kronecker-noisy", "random", "graphworld", "trilliong"] {
            assert!(r.structure_states.contains(name), "missing structure loader {name}");
        }
        for name in ["kde", "random", "gaussian", "gan"] {
            assert!(r.feature_states.contains(name), "missing feature loader {name}");
        }
        for name in ["xgboost", "learned", "random"] {
            assert!(r.aligner_states.contains(name), "missing aligner loader {name}");
        }
    }

    #[test]
    fn unknown_name_lists_registered() {
        let r = Registries::builtin();
        let err = r.structure.resolve("warp-drive").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp-drive"), "{msg}");
        assert!(msg.contains("kronecker"), "{msg}");
        assert!(msg.contains("sbm"), "{msg}");
    }
}
