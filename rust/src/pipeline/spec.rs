//! Declarative scenario description: [`ScenarioSpec`] names every choice a
//! generation job makes — dataset, per-component backends + parameters,
//! target size, seed, and output sink — and parses from a minimal
//! TOML-subset config file so `sgg run scenario.toml` works end to end.
//!
//! The supported config surface (a strict subset of TOML — no arrays,
//! tables-in-tables, escapes, or multi-line values):
//!
//! ```toml
//! # top level: job identity + size
//! name = "fraud-demo"
//! dataset = "ieee-fraud"     # registry name (see `sgg datasets`)
//!                            # — or generate from a fitted artifact:
//!                            # model = "fraud.sggm" (makes `dataset` and
//!                            # every component section invalid: the
//!                            # artifact already carries the fitted
//!                            # components)
//! seed = 42
//! scale = 2                  # nodes ×2, edges ×4 — or use [size]
//! workers = 4                # parallel chunk-sampling threads
//!                            # (default 1 = sequential, 0 = all cores)
//!
//! [structure]                # component sections: `backend` + params
//! backend = "kronecker"
//! noise = 0.1
//!
//! [edge_features]
//! backend = "kde"
//!
//! [node_features]            # omit = auto (mirrors edge_features when
//! backend = "gaussian"       # the dataset has node features);
//!                            # backend = "none" disables
//! [aligner]
//! backend = "learned"
//! trees = 30
//!
//! [sink]
//! kind = "shards"            # "memory" (default) or "shards"
//! dir = "/tmp/sgg-shards"
//! retries = 2                # bounded retry budget for transient IO
//! backoff_ms = 0             # deterministic backoff base (doubles per retry)
//!
//! [evaluate]                 # score the output against the fit source:
//! enabled = true             # full Table-2 report for memory runs, an
//!                            # in-flight structural tap for shard runs
//! ```

use crate::structgen::chunked::ChunkConfig;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A scalar parameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Number (every TOML-subset numeric parses as f64).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Num(_) => "number",
            Value::Bool(_) => "bool",
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}

impl From<u64> for Value {
    fn from(x: u64) -> Value {
        Value::Num(x as f64)
    }
}

impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

/// Named scalar parameters of one component (or one spec section).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Params(BTreeMap<String, Value>);

impl Params {
    /// Empty parameter set.
    pub fn new() -> Params {
        Params::default()
    }

    /// Insert (replacing) a parameter.
    pub fn set(&mut self, key: &str, value: Value) {
        self.0.insert(key.to_string(), value);
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.0.get(key)
    }

    /// True when no parameters are set.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Iterate `(key, value)` pairs in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.0.iter().map(|(k, v)| (k.as_str(), v))
    }

    fn type_err(&self, key: &str, want: &str, got: &Value) -> Error {
        Error::Config(format!("param `{key}` must be a {want}, got {}", got.type_name()))
    }

    /// Float param with default; errors on a non-numeric value.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| self.type_err(key, "number", v)),
        }
    }

    /// Unsigned-integer param with default; errors on non-integral values.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let x = v.as_f64().ok_or_else(|| self.type_err(key, "integer", v))?;
                f64_to_u64(key, x)
            }
        }
    }

    /// `usize` param with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    /// Bool param with default; errors on a non-bool value.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| self.type_err(key, "bool", v)),
        }
    }

    /// String param (None when unset); errors on a non-string value.
    pub fn str_opt(&self, key: &str) -> Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.as_str().map(Some).ok_or_else(|| self.type_err(key, "string", v)),
        }
    }
}

/// One pipeline component: a registry name plus its parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentSpec {
    /// Registry name (or alias) of the backend.
    pub name: String,
    /// Backend-specific scalar parameters.
    pub params: Params,
}

impl ComponentSpec {
    /// Component with no parameters.
    pub fn new(name: &str) -> ComponentSpec {
        ComponentSpec { name: name.to_string(), params: Params::new() }
    }

    /// Builder-style parameter attachment.
    pub fn with(mut self, key: &str, value: impl Into<Value>) -> ComponentSpec {
        self.params.set(key, value.into());
        self
    }
}

impl From<&str> for ComponentSpec {
    fn from(name: &str) -> ComponentSpec {
        ComponentSpec::new(name)
    }
}

/// Node-feature handling for a scenario.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum NodeFeatureSpec {
    /// Generate node features iff the source dataset has them, reusing
    /// the edge-feature backend.
    #[default]
    Auto,
    /// Never generate node features.
    Off,
    /// Generate node features with this component (errors at fit time if
    /// the dataset has none to learn from).
    Component(ComponentSpec),
}

/// Target output size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeSpec {
    /// Integer scale: nodes ×s, edges ×s² (density preserved, paper
    /// eq. 22).
    Scale(u64),
    /// Explicit node/edge targets.
    Sized { n_src: u64, n_dst: u64, edges: u64 },
}

impl Default for SizeSpec {
    fn default() -> Self {
        SizeSpec::Scale(1)
    }
}

/// Where generated output goes.
#[derive(Clone, Debug, PartialEq)]
pub enum SinkSpec {
    /// Assemble an in-memory [`crate::datasets::Dataset`].
    Memory,
    /// Stream structure chunks to binary shards under `dir`.
    Shards { dir: PathBuf, chunks: ChunkConfig },
}

impl Default for SinkSpec {
    fn default() -> Self {
        SinkSpec::Memory
    }
}

/// A full declarative generation job.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Job name (for logs/reports).
    pub name: String,
    /// Dataset registry name (see [`crate::datasets::REGISTRY`]).
    /// Empty when [`ScenarioSpec::model`] is set — a loaded artifact
    /// needs no source data.
    pub dataset: String,
    /// Path to a `.sggm` model artifact to generate from instead of
    /// fitting. Mutually exclusive with `dataset` and the component
    /// sections (the artifact already carries the fitted components).
    pub model: Option<PathBuf>,
    /// Seed used when loading/synthesizing the source dataset.
    pub dataset_seed: u64,
    /// Structure backend.
    pub structure: ComponentSpec,
    /// Edge-feature backend.
    pub edge_features: ComponentSpec,
    /// Node-feature handling.
    pub node_features: NodeFeatureSpec,
    /// Aligner backend.
    pub aligner: ComponentSpec,
    /// Output size.
    pub size: SizeSpec,
    /// Generation seed.
    pub seed: u64,
    /// Worker threads for chunked structure generation (the parallel
    /// runner). 1 = sequential, 0 = one per core. Output is identical
    /// for every value — only wall-clock changes.
    pub workers: usize,
    /// Output sink.
    pub sink: SinkSpec,
    /// Score the generated output against the fit source (`[evaluate]`
    /// section). Shard runs are tapped in flight and carry the
    /// structural scores in their [`crate::pipeline::StreamReport`];
    /// memory runs signal the caller to score the returned dataset once
    /// (the `sgg run` CLI prints the full Table-2
    /// [`crate::metrics::QualityReport`]). Requires `dataset` (a `model`
    /// artifact carries no reference graph to score against).
    pub evaluate: bool,
}

impl ScenarioSpec {
    /// A same-size, in-memory scenario with default components.
    pub fn new(dataset: &str) -> ScenarioSpec {
        ScenarioSpec {
            name: format!("{dataset}-scenario"),
            dataset: dataset.to_string(),
            model: None,
            dataset_seed: 1,
            structure: ComponentSpec::new("kronecker"),
            edge_features: ComponentSpec::new("kde"),
            node_features: NodeFeatureSpec::Auto,
            aligner: ComponentSpec::new("learned"),
            size: SizeSpec::default(),
            seed: 0x5a6e,
            workers: 1,
            sink: SinkSpec::Memory,
            evaluate: false,
        }
    }

    /// Parse a spec from config text (the TOML subset in the module docs).
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let raw = RawConfig::parse(text)?;
        raw.into_spec()
    }

    /// Parse a spec from a config file.
    pub fn from_file(path: &Path) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let mut spec = ScenarioSpec::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        if spec.name.is_empty() {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                spec.name = stem.to_string();
            }
        }
        Ok(spec)
    }
}

/// Line-parsed config: top-level pairs + named sections, before
/// interpretation.
struct RawConfig {
    top: Vec<(String, Value)>,
    /// `(section name, pairs)` in file order.
    sections: Vec<(String, Vec<(String, Value)>)>,
}

impl RawConfig {
    fn parse(text: &str) -> Result<RawConfig> {
        let mut top = Vec::new();
        let mut sections: Vec<(String, Vec<(String, Value)>)> = Vec::new();
        for (i, raw_line) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .ok_or_else(|| {
                        Error::Config(format!("line {lineno}: malformed section header `{line}`"))
                    })?;
                if sections.iter().any(|(n, _)| n == name) {
                    return Err(Error::Config(format!(
                        "line {lineno}: duplicate section `[{name}]`"
                    )));
                }
                sections.push((name.to_string(), Vec::new()));
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim();
                if key.is_empty()
                    || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
                {
                    return Err(Error::Config(format!("line {lineno}: bad key `{key}`")));
                }
                let value = parse_value(v.trim(), lineno)?;
                match sections.last_mut() {
                    Some((_, pairs)) => pairs.push((key.to_string(), value)),
                    None => top.push((key.to_string(), value)),
                }
            } else {
                return Err(Error::Config(format!(
                    "line {lineno}: expected `key = value` or `[section]`, got `{line}`"
                )));
            }
        }
        Ok(RawConfig { top, sections })
    }

    fn into_spec(self) -> Result<ScenarioSpec> {
        let mut spec = ScenarioSpec::new("");
        spec.name = String::new();
        let mut scale: Option<u64> = None;
        let mut dataset = None;
        let mut model: Option<PathBuf> = None;
        let mut dataset_seed_given = false;
        for (key, value) in &self.top {
            match key.as_str() {
                "name" => {
                    spec.name = expect_str(key, value)?.to_string();
                }
                "dataset" => {
                    dataset = Some(expect_str(key, value)?.to_string());
                }
                "model" => {
                    model = Some(PathBuf::from(expect_str(key, value)?));
                }
                "dataset_seed" => {
                    dataset_seed_given = true;
                    spec.dataset_seed = expect_u64(key, value)?;
                }
                "seed" => spec.seed = expect_u64(key, value)?,
                "scale" => scale = Some(expect_u64(key, value)?),
                "workers" => spec.workers = expect_u64(key, value)? as usize,
                other => {
                    return Err(Error::Config(format!(
                        "unknown top-level key `{other}`; known: \
                         name, dataset, model, dataset_seed, seed, scale, workers"
                    )));
                }
            }
        }
        spec.dataset = match (&model, dataset) {
            (Some(_), Some(_)) => {
                return Err(Error::Config(
                    "give either `dataset` (fit) or `model` (load artifact), not both".into(),
                ));
            }
            (Some(_), None) => String::new(),
            (None, Some(d)) => d,
            (None, None) => {
                return Err(Error::Config("spec is missing `dataset` (or `model`)".into()));
            }
        };
        spec.model = model;
        if spec.model.is_some() && dataset_seed_given {
            return Err(Error::Config(
                "`dataset_seed` has no effect with a `model` artifact (no dataset is \
                 loaded) — drop it"
                    .into(),
            ));
        }

        let mut sized: Option<SizeSpec> = None;
        for (name, pairs) in self.sections {
            if spec.model.is_some()
                && matches!(
                    name.as_str(),
                    "structure" | "edge_features" | "node_features" | "aligner"
                )
            {
                return Err(Error::Config(format!(
                    "`[{name}]` configures fitting, but a `model` artifact already carries \
                     the fitted components — drop the section or the `model` key"
                )));
            }
            match name.as_str() {
                "structure" => spec.structure = component_section(&pairs, "kronecker")?,
                "edge_features" => spec.edge_features = component_section(&pairs, "kde")?,
                "node_features" => {
                    let c = component_section(&pairs, "none")?;
                    spec.node_features = match c.name.as_str() {
                        "none" | "off" => NodeFeatureSpec::Off,
                        "auto" => NodeFeatureSpec::Auto,
                        _ => NodeFeatureSpec::Component(c),
                    };
                }
                "aligner" => spec.aligner = component_section(&pairs, "learned")?,
                "size" => {
                    let p = params_of(&pairs);
                    let n_src = p.u64_or("n_src", 0)?;
                    let n_dst = p.u64_or("n_dst", n_src)?;
                    let edges = p.u64_or("edges", 0)?;
                    if n_src == 0 || edges == 0 {
                        return Err(Error::Config(
                            "[size] needs positive `n_src` and `edges` (and optional `n_dst`)"
                                .into(),
                        ));
                    }
                    sized = Some(SizeSpec::Sized { n_src, n_dst, edges });
                }
                "sink" => {
                    let p = params_of(&pairs);
                    let kind = p.str_opt("kind")?.unwrap_or("memory");
                    spec.sink = match kind {
                        "memory" => SinkSpec::Memory,
                        "shards" => {
                            let defaults = ChunkConfig::default();
                            let format = match p.str_opt("format")? {
                                None => defaults.format,
                                Some(name) => {
                                    crate::graph::io::ShardFormat::parse(name).ok_or_else(
                                        || {
                                            Error::Config(format!(
                                                "unknown shard format `{name}`; known: \
                                                 sggedge1, sggedge2"
                                            ))
                                        },
                                    )?
                                }
                            };
                            SinkSpec::Shards {
                                dir: PathBuf::from(p.str_opt("dir")?.unwrap_or("sgg-shards")),
                                chunks: ChunkConfig {
                                    prefix_levels: p
                                        .u64_or("prefix_levels", defaults.prefix_levels as u64)?
                                        as u32,
                                    // 0 = inherit the top-level `workers`
                                    // key (resolved below)
                                    workers: p.usize_or("workers", 0)?,
                                    queue_capacity: p
                                        .usize_or("queue_capacity", defaults.queue_capacity)?,
                                    retry: crate::pipeline::fault::RetryPolicy {
                                        max_retries: p.u64_or(
                                            "retries",
                                            defaults.retry.max_retries as u64,
                                        )? as u32,
                                        backoff_ms: p
                                            .u64_or("backoff_ms", defaults.retry.backoff_ms)?,
                                    },
                                    format,
                                    ..defaults
                                },
                            }
                        }
                        other => {
                            return Err(Error::Config(format!(
                                "unknown sink kind `{other}`; known: memory, shards"
                            )));
                        }
                    };
                }
                "evaluate" => {
                    let p = params_of(&pairs);
                    for (key, _) in p.iter() {
                        if key != "enabled" {
                            return Err(Error::Config(format!(
                                "unknown `[evaluate]` key `{key}`; known: enabled"
                            )));
                        }
                    }
                    spec.evaluate = p.bool_or("enabled", true)?;
                    if spec.evaluate && spec.model.is_some() {
                        return Err(Error::Config(
                            "`[evaluate]` needs the fit source as a reference, but a \
                             `model` artifact carries no dataset — drop the section or \
                             fit from `dataset` instead"
                                .into(),
                        ));
                    }
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown section `[{other}]`; known: structure, edge_features, \
                         node_features, aligner, size, sink, evaluate"
                    )));
                }
            }
        }
        spec.size = match (scale, sized) {
            (Some(_), Some(_)) => {
                return Err(Error::Config("give either `scale` or `[size]`, not both".into()));
            }
            (Some(0), None) => {
                return Err(Error::Config("`scale` must be at least 1".into()));
            }
            (Some(s), None) => SizeSpec::Scale(s),
            (None, Some(s)) => s,
            (None, None) => SizeSpec::Scale(1),
        };
        if spec.name.is_empty() {
            spec.name = match &spec.model {
                Some(path) => format!(
                    "{}-generate",
                    path.file_stem().and_then(|s| s.to_str()).unwrap_or("model")
                ),
                None => format!("{}-scenario", spec.dataset),
            };
        }
        // a [sink] section without its own `workers` inherits the
        // top-level worker count
        if let SinkSpec::Shards { chunks, .. } = &mut spec.sink {
            if chunks.workers == 0 {
                chunks.workers = spec.workers;
            }
        }
        Ok(spec)
    }
}

fn params_of(pairs: &[(String, Value)]) -> Params {
    let mut p = Params::new();
    for (k, v) in pairs {
        p.set(k, v.clone());
    }
    p
}

fn component_section(pairs: &[(String, Value)], default_backend: &str) -> Result<ComponentSpec> {
    let mut c = ComponentSpec::new(default_backend);
    for (k, v) in pairs {
        if k == "backend" {
            c.name = expect_str(k, v)?.to_string();
        } else {
            c.params.set(k, v.clone());
        }
    }
    Ok(c)
}

fn expect_str<'v>(key: &str, value: &'v Value) -> Result<&'v str> {
    value
        .as_str()
        .ok_or_else(|| Error::Config(format!("`{key}` must be a string, got {value:?}")))
}

fn expect_u64(key: &str, value: &Value) -> Result<u64> {
    let x = value
        .as_f64()
        .ok_or_else(|| Error::Config(format!("`{key}` must be an integer, got {value:?}")))?;
    f64_to_u64(key, x)
}

/// Exact f64 → u64 conversion. Values are stored as f64, which holds
/// integers exactly only below 2^53 — anything at or above that bound is
/// rejected rather than silently rounded (2^53 itself is refused because
/// it is indistinguishable from a rounded 2^53 + 1).
fn f64_to_u64(key: &str, x: f64) -> Result<u64> {
    const EXACT_BOUND: f64 = 9_007_199_254_740_992.0; // 2^53
    if x < 0.0 || x.fract() != 0.0 {
        return Err(Error::Config(format!(
            "`{key}` must be a non-negative integer, got {x}"
        )));
    }
    if x >= EXACT_BOUND {
        return Err(Error::Config(format!(
            "`{key}` = {x} is at or above 2^53 and cannot be represented exactly \
             in a spec value"
        )));
    }
    Ok(x as u64)
}

/// Cut a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse one scalar: `"string"`, `true`/`false`, or a number
/// (underscore separators allowed).
fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        return body
            .strip_suffix('"')
            .filter(|inner| !inner.contains('"'))
            .map(|inner| Value::Str(inner.to_string()))
            .ok_or_else(|| Error::Config(format!("line {lineno}: malformed string `{s}`")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| Error::Config(format!("line {lineno}: unparseable value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_spec_parses() {
        let text = r#"
            # demo scenario
            name = "demo"
            dataset = "ieee-fraud"   # trailing comment
            seed = 42
            scale = 2

            [structure]
            backend = "kronecker"
            noise = 0.25

            [edge_features]
            backend = "kde"

            [node_features]
            backend = "gaussian"

            [aligner]
            backend = "learned"
            trees = 10

            [sink]
            kind = "shards"
            dir = "/tmp/demo-shards"
            prefix_levels = 3
        "#;
        let spec = ScenarioSpec::parse(text).unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.dataset, "ieee-fraud");
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.size, SizeSpec::Scale(2));
        assert_eq!(spec.structure.name, "kronecker");
        assert_eq!(spec.structure.params.f64_or("noise", 0.0).unwrap(), 0.25);
        assert_eq!(spec.edge_features.name, "kde");
        assert!(matches!(&spec.node_features, NodeFeatureSpec::Component(c) if c.name == "gaussian"));
        assert_eq!(spec.aligner.params.u64_or("trees", 0).unwrap(), 10);
        match &spec.sink {
            SinkSpec::Shards { dir, chunks } => {
                assert_eq!(dir, &PathBuf::from("/tmp/demo-shards"));
                assert_eq!(chunks.prefix_levels, 3);
            }
            other => panic!("wrong sink {other:?}"),
        }
    }

    #[test]
    fn minimal_spec_uses_defaults() {
        let spec = ScenarioSpec::parse("dataset = \"cora\"").unwrap();
        assert_eq!(spec.dataset, "cora");
        assert_eq!(spec.name, "cora-scenario");
        assert_eq!(spec.size, SizeSpec::Scale(1));
        assert_eq!(spec.structure.name, "kronecker");
        assert_eq!(spec.edge_features.name, "kde");
        assert_eq!(spec.aligner.name, "learned");
        assert_eq!(spec.node_features, NodeFeatureSpec::Auto);
        assert_eq!(spec.sink, SinkSpec::Memory);
    }

    #[test]
    fn missing_dataset_is_config_error() {
        let err = ScenarioSpec::parse("seed = 1").unwrap_err();
        assert!(err.to_string().contains("dataset"), "{err}");
    }

    #[test]
    fn unknown_section_and_key_error() {
        assert!(ScenarioSpec::parse("dataset = \"cora\"\n[bogus]\n").is_err());
        assert!(ScenarioSpec::parse("dataset = \"cora\"\nbogus = 1\n").is_err());
    }

    #[test]
    fn scale_and_size_conflict() {
        let text = "dataset = \"cora\"\nscale = 2\n[size]\nn_src = 10\nedges = 40\n";
        assert!(ScenarioSpec::parse(text).is_err());
    }

    #[test]
    fn explicit_size_parses() {
        let text = "dataset = \"cora\"\n[size]\nn_src = 1_000\nn_dst = 500\nedges = 9000\n";
        let spec = ScenarioSpec::parse(text).unwrap();
        assert_eq!(spec.size, SizeSpec::Sized { n_src: 1000, n_dst: 500, edges: 9000 });
    }

    #[test]
    fn node_features_off() {
        let text = "dataset = \"cora\"\n[node_features]\nbackend = \"none\"\n";
        let spec = ScenarioSpec::parse(text).unwrap();
        assert_eq!(spec.node_features, NodeFeatureSpec::Off);
    }

    #[test]
    fn value_types() {
        let text = "dataset = \"d\"\n[structure]\nnoise = 0.5\n[edge_features]\nbackend = \"gan\"\nuse_pjrt = false\n";
        let spec = ScenarioSpec::parse(text).unwrap();
        assert_eq!(spec.structure.params.f64_or("noise", 0.0).unwrap(), 0.5);
        assert!(!spec.edge_features.params.bool_or("use_pjrt", true).unwrap());
        assert!(spec.edge_features.params.u64_or("use_pjrt", 1).is_err());
    }

    #[test]
    fn zero_scale_is_rejected() {
        let err = ScenarioSpec::parse("dataset = \"cora\"\nscale = 0\n").unwrap_err();
        assert!(err.to_string().contains("scale"), "{err}");
    }

    #[test]
    fn integers_beyond_2_pow_53_are_rejected_not_rounded() {
        // 2^53 + 1 rounds to 2^53 in f64; both must be refused
        for v in ["9007199254740993", "9007199254740992"] {
            let err = ScenarioSpec::parse(&format!("dataset = \"cora\"\nseed = {v}\n"))
                .unwrap_err();
            assert!(err.to_string().contains("2^53"), "{v}: {err}");
        }
        // the largest exactly-representable integer is accepted
        let spec =
            ScenarioSpec::parse("dataset = \"cora\"\nseed = 9007199254740991\n").unwrap();
        assert_eq!(spec.seed, (1u64 << 53) - 1);
    }

    #[test]
    fn workers_key_parses_and_flows_into_shard_chunks() {
        // default: sequential
        let spec = ScenarioSpec::parse("dataset = \"cora\"").unwrap();
        assert_eq!(spec.workers, 1);
        // top-level key
        let spec = ScenarioSpec::parse("dataset = \"cora\"\nworkers = 6\n").unwrap();
        assert_eq!(spec.workers, 6);
        // a [sink] without its own workers inherits the top-level count
        let text = "dataset = \"cora\"\nworkers = 6\n[sink]\nkind = \"shards\"\n";
        match ScenarioSpec::parse(text).unwrap().sink {
            SinkSpec::Shards { chunks, .. } => assert_eq!(chunks.workers, 6),
            other => panic!("wrong sink {other:?}"),
        }
        // an explicit [sink] workers wins over the top-level key
        let text =
            "dataset = \"cora\"\nworkers = 6\n[sink]\nkind = \"shards\"\nworkers = 2\n";
        match ScenarioSpec::parse(text).unwrap().sink {
            SinkSpec::Shards { chunks, .. } => assert_eq!(chunks.workers, 2),
            other => panic!("wrong sink {other:?}"),
        }
    }

    #[test]
    fn sink_format_key_parses_and_rejects_unknown() {
        use crate::graph::io::ShardFormat;
        // default: SGGEDGE1 (byte-stable, resume/CI-smoke compatible)
        let text = "dataset = \"cora\"\n[sink]\nkind = \"shards\"\n";
        match ScenarioSpec::parse(text).unwrap().sink {
            SinkSpec::Shards { chunks, .. } => assert_eq!(chunks.format, ShardFormat::Edge1),
            other => panic!("wrong sink {other:?}"),
        }
        let text = "dataset = \"cora\"\n[sink]\nkind = \"shards\"\nformat = \"sggedge2\"\n";
        match ScenarioSpec::parse(text).unwrap().sink {
            SinkSpec::Shards { chunks, .. } => assert_eq!(chunks.format, ShardFormat::Edge2),
            other => panic!("wrong sink {other:?}"),
        }
        let text = "dataset = \"cora\"\n[sink]\nkind = \"shards\"\nformat = \"parquet\"\n";
        let err = ScenarioSpec::parse(text).unwrap_err();
        assert!(err.to_string().contains("unknown shard format"), "{err}");
    }

    #[test]
    fn evaluate_section_parses() {
        // absent: off
        assert!(!ScenarioSpec::parse("dataset = \"cora\"").unwrap().evaluate);
        // bare section: on
        let spec = ScenarioSpec::parse("dataset = \"cora\"\n[evaluate]\n").unwrap();
        assert!(spec.evaluate);
        // explicit enabled flag
        let spec =
            ScenarioSpec::parse("dataset = \"cora\"\n[evaluate]\nenabled = false\n").unwrap();
        assert!(!spec.evaluate);
        // unknown keys are hard errors
        let err = ScenarioSpec::parse("dataset = \"cora\"\n[evaluate]\nbogus = 1\n")
            .unwrap_err();
        assert!(err.to_string().contains("bogus"), "{err}");
    }

    #[test]
    fn evaluate_conflicts_with_model() {
        let err = ScenarioSpec::parse("model = \"m.sggm\"\n[evaluate]\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("evaluate") && msg.contains("model"), "{msg}");
        // explicitly disabled evaluation is fine with a model
        let spec =
            ScenarioSpec::parse("model = \"m.sggm\"\n[evaluate]\nenabled = false\n").unwrap();
        assert!(!spec.evaluate);
    }

    #[test]
    fn model_key_makes_dataset_optional() {
        let spec = ScenarioSpec::parse("model = \"fraud.sggm\"\nscale = 2\n").unwrap();
        assert_eq!(spec.model, Some(PathBuf::from("fraud.sggm")));
        assert!(spec.dataset.is_empty());
        assert_eq!(spec.size, SizeSpec::Scale(2));
        assert_eq!(spec.name, "fraud-generate");
    }

    #[test]
    fn model_and_dataset_conflict() {
        let err =
            ScenarioSpec::parse("model = \"m.sggm\"\ndataset = \"cora\"\n").unwrap_err();
        assert!(err.to_string().contains("not both"), "{err}");
    }

    #[test]
    fn model_rejects_dataset_seed() {
        let err =
            ScenarioSpec::parse("model = \"m.sggm\"\ndataset_seed = 9\n").unwrap_err();
        assert!(err.to_string().contains("dataset_seed"), "{err}");
    }

    #[test]
    fn model_forbids_component_sections() {
        let err = ScenarioSpec::parse("model = \"m.sggm\"\n[structure]\nbackend = \"sbm\"\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("structure") && msg.contains("model"), "{msg}");
        // size/sink sections stay allowed with a model
        let spec = ScenarioSpec::parse(
            "model = \"m.sggm\"\n[sink]\nkind = \"shards\"\ndir = \"/tmp/x\"\n",
        )
        .unwrap();
        assert!(matches!(spec.sink, SinkSpec::Shards { .. }));
    }

    #[test]
    fn missing_dataset_mentions_model_alternative() {
        let err = ScenarioSpec::parse("seed = 1").unwrap_err();
        assert!(err.to_string().contains("model"), "{err}");
    }

    #[test]
    fn malformed_lines_report_line_numbers() {
        let err = ScenarioSpec::parse("dataset = \"d\"\nnot a pair\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }
}
