//! The end-to-end SynGen pipeline (paper Figure 1): fit the structure
//! generator, the feature generator, and the aligner on an input
//! [`Dataset`]; generate at any scale; align; return a synthetic
//! [`Dataset`]. [`orchestrator`] adds the streaming/out-of-core path.

pub mod orchestrator;

use crate::aligner::gbt::GbtConfig;
use crate::aligner::ranking::{LearnedAligner, Target};
use crate::aligner::{random_alignment, AlignKind, StructFeatConfig};
use crate::datasets::Dataset;
use crate::featgen::gan::GanFeatureGen;
use crate::featgen::gaussian::GaussianFeatureGen;
use crate::featgen::kde::KdeFeatureGen;
use crate::featgen::random::RandomFeatureGen;
use crate::featgen::{FeatKind, FeatureGenerator};
use crate::structgen::erdos_renyi::ErdosRenyi;
use crate::structgen::sbm::DcSbm;
use crate::structgen::trilliong::TrillionG;
use crate::structgen::{fit::fit_kronecker, StructKind, StructureGenerator};
use crate::Result;

/// Pipeline configuration: the three swappable components (the ablation
/// axes of paper Table 6) plus fitting hyper-parameters.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub struct_kind: StructKind,
    pub feat_kind: FeatKind,
    pub align_kind: AlignKind,
    /// GBT settings for the learned aligner.
    pub gbt: GbtConfig,
    /// Structural features used by the aligner.
    pub struct_feats: StructFeatConfig,
    /// Kronecker noise amplitude (0 disables; paper §9).
    pub noise: f64,
    /// DC-SBM blocks for the graphworld baseline.
    pub sbm_blocks: usize,
    /// Use the PJRT GAN backend when artifacts are present (otherwise the
    /// in-process resample backend keeps the pipeline runnable).
    pub use_pjrt_gan: bool,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            struct_kind: StructKind::Kronecker,
            feat_kind: FeatKind::Kde,
            align_kind: AlignKind::Learned,
            gbt: GbtConfig::fast(),
            struct_feats: StructFeatConfig::default(),
            noise: 0.0,
            sbm_blocks: 16,
            use_pjrt_gan: true,
            seed: 0x5a6e,
        }
    }
}

/// A fitted pipeline ready to generate synthetic datasets.
pub struct FittedPipeline {
    pub name: String,
    struct_gen: Box<dyn StructureGenerator>,
    feat_gen: Box<dyn FeatureGenerator>,
    aligner: Option<LearnedAligner>,
    cfg: PipelineConfig,
}

/// Entry point matching the paper's fit→generate workflow.
pub struct Pipeline;

impl Pipeline {
    /// Fit all three components on a dataset.
    pub fn fit(ds: &Dataset, cfg: &PipelineConfig) -> Result<FittedPipeline> {
        crate::info!("fit[{}]: structure={:?}", ds.name, cfg.struct_kind);
        let struct_gen: Box<dyn StructureGenerator> = match cfg.struct_kind {
            StructKind::Kronecker => Box::new(fit_kronecker(&ds.edges)),
            StructKind::KroneckerNoisy => {
                Box::new(fit_kronecker(&ds.edges).with_noise(cfg.noise.max(0.3)))
            }
            StructKind::Random => Box::new(ErdosRenyi::fit(&ds.edges)),
            StructKind::Sbm => Box::new(DcSbm::fit(&ds.edges, cfg.sbm_blocks)),
            StructKind::TrillionG => Box::new(TrillionG::fit(&ds.edges)),
        };
        crate::info!("fit[{}]: features={:?}", ds.name, cfg.feat_kind);
        let feat_gen: Box<dyn FeatureGenerator> = match cfg.feat_kind {
            FeatKind::Random => Box::new(RandomFeatureGen::fit(&ds.edge_features)),
            FeatKind::Kde => Box::new(KdeFeatureGen::fit(&ds.edge_features)),
            FeatKind::Gaussian => Box::new(GaussianFeatureGen::fit(&ds.edge_features)?),
            FeatKind::Gan => {
                if cfg.use_pjrt_gan && crate::runtime::artifacts_available() {
                    let rt = crate::runtime::global()?;
                    let backend = crate::runtime::gan_exec::PjrtGanBackend::new(
                        rt,
                        crate::runtime::gan_exec::GanTrainConfig::default(),
                    )?;
                    Box::new(GanFeatureGen::fit_with_backend(
                        &ds.edge_features,
                        Box::new(backend),
                        cfg.seed,
                    )?)
                } else {
                    crate::warn_log!("artifacts missing: GAN falls back to resample backend");
                    Box::new(GanFeatureGen::fit_resample(&ds.edge_features, cfg.seed)?)
                }
            }
        };
        let aligner = match cfg.align_kind {
            AlignKind::Learned => Some(LearnedAligner::fit(
                &ds.edges,
                &ds.edge_features,
                Target::Edges,
                cfg.struct_feats.clone(),
                &cfg.gbt,
            )?),
            AlignKind::Random => None,
        };
        Ok(FittedPipeline {
            name: ds.name.clone(),
            struct_gen,
            feat_gen,
            aligner,
            cfg: cfg.clone(),
        })
    }
}

impl FittedPipeline {
    /// Component names (for experiment tables).
    pub fn component_names(&self) -> (String, String, String) {
        (
            self.struct_gen.name().to_string(),
            self.feat_gen.name().to_string(),
            if self.aligner.is_some() { "xgboost".into() } else { "random".into() },
        )
    }

    /// Generate a synthetic dataset at integer `scale` (1 = same size).
    pub fn generate(&self, scale: u64, seed: u64) -> Result<Dataset> {
        let structure = self.struct_gen.generate(scale, seed)?;
        self.finish(structure, seed)
    }

    /// Generate with explicit sizes.
    pub fn generate_sized(
        &self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
    ) -> Result<Dataset> {
        let structure = self.struct_gen.generate_sized(n_src, n_dst, edges, seed)?;
        self.finish(structure, seed)
    }

    fn finish(&self, structure: crate::graph::EdgeList, seed: u64) -> Result<Dataset> {
        let n_edges = structure.len();
        // sample a feature pool the size of the edge set (paper: the
        // generated feature set is then ranked onto the structure)
        let pool = self.feat_gen.sample(n_edges, seed ^ 0xf00d)?;
        let aligned = match &self.aligner {
            Some(a) => a.align(&structure, &pool, seed ^ 0xa11)?,
            None => random_alignment(&pool, n_edges, seed ^ 0xa11)?,
        };
        Ok(Dataset {
            name: format!("{}-synth", self.name),
            edges: structure,
            edge_features: aligned,
            node_features: None,
            node_labels: None,
            edge_labels: None,
        })
    }

    /// The active configuration.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    fn cfg_fast() -> PipelineConfig {
        PipelineConfig { use_pjrt_gan: false, ..Default::default() }
    }

    #[test]
    fn fit_generate_same_size() {
        let ds = crate::datasets::load("ieee-fraud", 1).unwrap();
        let p = Pipeline::fit(&ds, &cfg_fast()).unwrap();
        let synth = p.generate(1, 9).unwrap();
        assert_eq!(synth.edges.len(), ds.edges.len());
        assert_eq!(synth.edge_features.n_rows(), ds.edges.len());
        assert_eq!(synth.edge_features.n_cols(), ds.edge_features.n_cols());
    }

    #[test]
    fn fitted_beats_random_on_degree_metric() {
        let ds = crate::datasets::load("tabformer", 2).unwrap();
        let ours = Pipeline::fit(&ds, &cfg_fast()).unwrap().generate(1, 5).unwrap();
        let random_cfg = PipelineConfig {
            struct_kind: StructKind::Random,
            feat_kind: FeatKind::Random,
            align_kind: AlignKind::Random,
            ..cfg_fast()
        };
        let rand = Pipeline::fit(&ds, &random_cfg).unwrap().generate(1, 5).unwrap();
        let ours_score = metrics::degree::degree_dist_score(&ds.edges, &ours.edges);
        let rand_score = metrics::degree::degree_dist_score(&ds.edges, &rand.edges);
        assert!(
            ours_score > rand_score,
            "ours={ours_score} random={rand_score}"
        );
    }

    #[test]
    fn scale_two_quadruples_edges() {
        let ds = crate::datasets::load("travel-insurance", 3).unwrap();
        let p = Pipeline::fit(&ds, &cfg_fast()).unwrap();
        let synth = p.generate(2, 4).unwrap();
        assert_eq!(synth.edges.len(), 4 * ds.edges.len());
        assert_eq!(synth.edges.spec.n_src, 2 * ds.edges.spec.n_src);
    }

    #[test]
    fn all_component_combos_run() {
        // subsample to keep the 24-combo sweep fast
        let mut ds = crate::datasets::load("travel-insurance", 4).unwrap();
        let keep: Vec<usize> = (0..ds.edges.len()).step_by(10).collect();
        ds.edge_features = ds.edge_features.gather(&keep);
        let mut edges = crate::graph::EdgeList::new(ds.edges.spec);
        for &i in &keep {
            edges.push(ds.edges.src[i], ds.edges.dst[i]);
        }
        ds.edges = edges;
        for sk in [StructKind::Kronecker, StructKind::Random, StructKind::Sbm, StructKind::TrillionG] {
            for fk in [FeatKind::Kde, FeatKind::Random, FeatKind::Gaussian] {
                for ak in [AlignKind::Learned, AlignKind::Random] {
                    let cfg = PipelineConfig {
                        struct_kind: sk,
                        feat_kind: fk,
                        align_kind: ak,
                        gbt: crate::aligner::gbt::GbtConfig { n_trees: 5, ..GbtConfig::fast() },
                        ..cfg_fast()
                    };
                    let p = Pipeline::fit(&ds, &cfg).unwrap();
                    let s = p.generate(1, 1).unwrap();
                    assert_eq!(s.edges.len(), ds.edges.len(), "{sk:?}/{fk:?}/{ak:?}");
                }
            }
        }
    }
}
