//! The end-to-end SynGen pipeline (paper Figure 1), built around a
//! three-phase **fit → artifact → generate** lifecycle, a declarative
//! [`ScenarioSpec`], and string-keyed component [`Registry`]s.
//!
//! **Fit** resolves each component (structure / edge features / node
//! features / aligner) by name against [`Registries`], producing a
//! [`FittedPipeline`]. **Artifact**: the fitted pipeline serializes to a
//! versioned `.sggm` document ([`FittedPipeline::save`] /
//! [`FittedPipeline::load`], module [`artifact`]) so the *models* — not
//! the possibly proprietary data — are the shareable unit; fit once
//! where the data lives, generate anywhere. **Generate** routes
//! structure chunks through a [`Sink`] — [`MemorySink`] assembles an
//! in-memory [`Dataset`] (features generated and aligned, node features
//! included when the source dataset has them), [`ShardSink`] streams
//! shards to disk (paper §4.5) — so the in-memory and out-of-core paths
//! share one code path. Chunk sampling itself runs on the [`parallel`]
//! engine: with `workers > 1` the [`parallel::ParallelChunkRunner`]
//! samples chunks concurrently and feeds the sink in chunk-index order,
//! bit-identical to the sequential path (see `docs/ARCHITECTURE.md` for
//! the full dataflow). Generation from a loaded artifact is
//! bit-identical to generation from the originally fitted pipeline for
//! the same seed and any worker count.
//!
//! Entry points:
//!
//! * [`run_scenario`] — execute a parsed [`ScenarioSpec`] end to end
//!   (fitting from its `dataset`, or loading its `model` artifact).
//! * [`Pipeline::builder`] — fluent programmatic configuration.
//! * [`FittedPipeline::load`] — reconstruct a pipeline from a `.sggm`
//!   artifact without the source dataset.
//! * [`distrib`] — distributed generation: versioned run manifests
//!   (`sgg plan`), per-host chunk-range execution (`sgg generate
//!   --chunks`), and merge-time validation + metric folding
//!   (`sgg merge`).

pub mod artifact;
pub mod distrib;
pub mod fault;
pub mod orchestrator;
pub mod parallel;
pub mod registry;
pub mod sink;
pub mod spec;

pub use artifact::{SourceSummary, SGGM_FORMAT, SGGM_VERSION};
pub use distrib::{HostReport, MergeReport, RunManifest};
pub use fault::{FaultPlan, FaultReader, FaultSink, RetryPolicy, RetryingSink};
pub use parallel::{CancelToken, ChunkPlan, ParallelChunkRunner, SplitPlan};
pub use registry::{Registries, Registry};
pub use sink::{
    CancelSink, MemorySink, ProgressHandle, ShardSink, Sink, SinkFinish, SinkOutput,
    StreamReport,
};
pub use spec::{
    ComponentSpec, NodeFeatureSpec, Params, ScenarioSpec, SinkSpec, SizeSpec, Value,
};

use crate::aligner::gbt::GbtConfig;
use crate::aligner::{Aligner, AlignerFitContext, StructFeatConfig, Target};
use crate::datasets::Dataset;
use crate::featgen::{FeatureFitContext, FeatureGenerator};
use crate::graph::EdgeList;
use crate::structgen::chunked::ChunkConfig;
use crate::structgen::{StructureFitContext, StructureGenerator};
use crate::{Error, Result};

/// Fluent, registry-backed pipeline configuration. Obtain via
/// [`Pipeline::builder`]; component arguments accept a plain name
/// (`"kde"`) or a parameterized [`ComponentSpec`].
#[derive(Clone, Debug)]
pub struct PipelineBuilder {
    structure: ComponentSpec,
    edge_features: ComponentSpec,
    node_features: NodeFeatureSpec,
    aligner: ComponentSpec,
    gbt: Option<GbtConfig>,
    struct_feats: Option<StructFeatConfig>,
    seed: u64,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        PipelineBuilder {
            structure: ComponentSpec::new("kronecker"),
            edge_features: ComponentSpec::new("kde"),
            node_features: NodeFeatureSpec::Auto,
            aligner: ComponentSpec::new("learned"),
            gbt: None,
            struct_feats: None,
            seed: 0x5a6e,
        }
    }
}

impl PipelineBuilder {
    /// Structure backend (registry name or parameterized spec).
    pub fn structure(mut self, c: impl Into<ComponentSpec>) -> Self {
        self.structure = c.into();
        self
    }

    /// Edge-feature backend.
    pub fn edge_features(mut self, c: impl Into<ComponentSpec>) -> Self {
        self.edge_features = c.into();
        self
    }

    /// Node-feature backend (errors at fit time if the dataset has no
    /// node features to learn from).
    pub fn node_features(mut self, c: impl Into<ComponentSpec>) -> Self {
        self.node_features = NodeFeatureSpec::Component(c.into());
        self
    }

    /// Disable the node-feature leg (default is auto: generate node
    /// features iff the source dataset has them).
    pub fn no_node_features(mut self) -> Self {
        self.node_features = NodeFeatureSpec::Off;
        self
    }

    /// Explicit node-feature mode.
    pub fn node_feature_spec(mut self, spec: NodeFeatureSpec) -> Self {
        self.node_features = spec;
        self
    }

    /// Aligner backend.
    pub fn aligner(mut self, c: impl Into<ComponentSpec>) -> Self {
        self.aligner = c.into();
        self
    }

    /// Typed GBT override for the learned aligner.
    pub fn gbt(mut self, cfg: GbtConfig) -> Self {
        self.gbt = Some(cfg);
        self
    }

    /// Typed structural-feature override for the learned aligner.
    pub fn struct_feats(mut self, cfg: StructFeatConfig) -> Self {
        self.struct_feats = Some(cfg);
        self
    }

    /// Fitting seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Fit against the built-in registries.
    pub fn fit(&self, ds: &Dataset) -> Result<FittedPipeline> {
        self.fit_with(ds, &Registries::builtin())
    }

    /// Fit against caller-supplied registries (custom backends).
    pub fn fit_with(&self, ds: &Dataset, regs: &Registries) -> Result<FittedPipeline> {
        crate::info!("fit[{}]: structure=`{}`", ds.name, self.structure.name);
        let struct_gen = regs.structure.resolve(&self.structure.name)?(&StructureFitContext {
            edges: &ds.edges,
            params: &self.structure.params,
            seed: self.seed,
        })?;

        crate::info!("fit[{}]: edge features=`{}`", ds.name, self.edge_features.name);
        let edge_feat_gen = regs.features.resolve(&self.edge_features.name)?(
            &FeatureFitContext {
                table: &ds.edge_features,
                params: &self.edge_features.params,
                seed: self.seed,
            },
        )?;

        let align_factory = regs.aligners.resolve(&self.aligner.name)?;
        let edge_aligner = align_factory(&AlignerFitContext {
            edges: &ds.edges,
            features: &ds.edge_features,
            target: Target::Edges,
            params: &self.aligner.params,
            gbt: self.gbt.as_ref(),
            struct_feats: self.struct_feats.as_ref(),
        })?;

        let node_component = match &self.node_features {
            NodeFeatureSpec::Off => None,
            NodeFeatureSpec::Auto => {
                ds.node_features.as_ref().map(|_| self.edge_features.clone())
            }
            NodeFeatureSpec::Component(c) => Some(c.clone()),
        };
        let (node_feat_gen, node_aligner) = match node_component {
            None => (None, None),
            Some(c) => {
                let nf = ds.node_features.as_ref().ok_or_else(|| {
                    Error::Config(format!(
                        "node-feature backend `{}` requested but dataset `{}` has no \
                         node features to fit on",
                        c.name, ds.name
                    ))
                })?;
                crate::info!("fit[{}]: node features=`{}`", ds.name, c.name);
                let gen = regs.features.resolve(&c.name)?(&FeatureFitContext {
                    table: nf,
                    params: &c.params,
                    seed: self.seed ^ 0x6e0de,
                })?;
                let aligner = align_factory(&AlignerFitContext {
                    edges: &ds.edges,
                    features: nf,
                    target: Target::Nodes,
                    params: &self.aligner.params,
                    gbt: self.gbt.as_ref(),
                    struct_feats: self.struct_feats.as_ref(),
                })?;
                (Some(gen), Some(aligner))
            }
        };

        Ok(FittedPipeline {
            name: ds.name.clone(),
            struct_gen,
            edge_feat_gen,
            edge_aligner,
            node_feat_gen,
            node_aligner,
            seed: self.seed,
            source: SourceSummary {
                dataset: ds.name.clone(),
                spec: ds.edges.spec,
                edges: ds.edges.len() as u64,
                edge_feature_cols: ds.edge_features.column_names(),
                node_feature_cols: ds.node_features.as_ref().map(|t| t.column_names()),
            },
        })
    }
}

/// A fitted pipeline ready to generate synthetic datasets — obtained by
/// fitting ([`PipelineBuilder::fit`]) or by loading a `.sggm` model
/// artifact ([`FittedPipeline::load`]); the two are interchangeable.
pub struct FittedPipeline {
    /// Scenario/pipeline label (used in logs and experiment tables).
    pub name: String,
    struct_gen: Box<dyn StructureGenerator>,
    edge_feat_gen: Box<dyn FeatureGenerator>,
    edge_aligner: Box<dyn Aligner>,
    node_feat_gen: Option<Box<dyn FeatureGenerator>>,
    node_aligner: Option<Box<dyn Aligner>>,
    seed: u64,
    source: SourceSummary,
}

/// Entry point matching the paper's fit→generate workflow.
pub struct Pipeline;

impl Pipeline {
    /// Fluent registry-backed configuration.
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::default()
    }
}

impl FittedPipeline {
    /// Component names (for experiment tables): structure, edge features,
    /// aligner.
    pub fn component_names(&self) -> (String, String, String) {
        (
            self.struct_gen.name().to_string(),
            self.edge_feat_gen.name().to_string(),
            self.edge_aligner.name().to_string(),
        )
    }

    /// True when the pipeline fitted a node-feature leg.
    pub fn has_node_features(&self) -> bool {
        self.node_feat_gen.is_some()
    }

    /// The fitting seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Summary of the dataset this pipeline was fitted on (carried into
    /// `.sggm` artifacts as provenance).
    pub fn source(&self) -> &SourceSummary {
        &self.source
    }

    /// Generate a synthetic dataset at integer `scale` (1 = same size).
    pub fn generate(&self, scale: u64, seed: u64) -> Result<Dataset> {
        let structure = self.struct_gen.generate(scale, seed)?;
        self.assemble(structure, seed)
    }

    /// Generate with explicit sizes.
    pub fn generate_sized(
        &self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
    ) -> Result<Dataset> {
        let structure = self.struct_gen.generate_sized(n_src, n_dst, edges, seed)?;
        self.assemble(structure, seed)
    }

    /// One code path for in-memory and streamed generation: resolve
    /// `size`, stream structure chunks into `sink` (out-of-core backends
    /// chunk with bounded memory; `chunks.workers > 1` samples chunks on
    /// the [`parallel::ParallelChunkRunner`] pool with output identical
    /// to the sequential path), then let the sink finish — a
    /// [`MemorySink`] hands the structure back for feature assembly, a
    /// [`ShardSink`] reports what it persisted.
    pub fn run(
        &self,
        size: SizeSpec,
        chunks: ChunkConfig,
        sink: &mut dyn Sink,
        seed: u64,
    ) -> Result<SinkOutput> {
        let (n_src, n_dst, edges) = match size {
            SizeSpec::Scale(s) => self.struct_gen.scaled_size(s.max(1)),
            SizeSpec::Sized { n_src, n_dst, edges } => (n_src, n_dst, edges),
        };
        crate::info!(
            "run[{}]: {} edges over {}×{} → sink `{}`",
            self.name,
            edges,
            n_src,
            n_dst,
            sink.name()
        );
        self.struct_gen
            .generate_into(n_src, n_dst, edges, seed, chunks, &mut |c| sink.edges(c))?;
        match sink.finish()? {
            SinkFinish::Collected(structure) => {
                Ok(SinkOutput::Dataset(self.assemble(structure, seed)?))
            }
            SinkFinish::Streamed(report) => Ok(SinkOutput::Streamed(report)),
        }
    }

    /// Feature generation + alignment over a generated structure: sample
    /// an edge-feature pool the size of the edge set, rank it onto the
    /// structure (paper: the generated feature set is then ranked onto
    /// the structure), and — when the pipeline fitted a node leg — do the
    /// same per source node.
    fn assemble(&self, structure: EdgeList, seed: u64) -> Result<Dataset> {
        let n_edges = structure.len();
        let pool = self.edge_feat_gen.sample(n_edges, seed ^ 0xf00d)?;
        let edge_features = self.edge_aligner.align(&structure, &pool, seed ^ 0xa11)?;
        let node_features = match (&self.node_feat_gen, &self.node_aligner) {
            (Some(gen), Some(aligner)) => {
                let n_nodes = structure.spec.n_src as usize;
                let pool = gen.sample(n_nodes, seed ^ 0x6e0de)?;
                Some(aligner.align(&structure, &pool, seed ^ 0x6e0a1)?)
            }
            _ => None,
        };
        Ok(Dataset {
            name: format!("{}-synth", self.name),
            edges: structure,
            edge_features,
            node_features,
            node_labels: None,
            edge_labels: None,
        })
    }
}

/// Execute a scenario end to end against the built-in registries:
/// obtain a fitted pipeline (loading the spec's `model` artifact, or
/// loading the dataset and fitting every component), generate at the
/// requested size, and route output through the configured sink.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<SinkOutput> {
    run_scenario_with(spec, &Registries::builtin())
}

/// [`run_scenario`] with caller-supplied registries.
///
/// With `spec.evaluate` set, shard runs route chunks through a
/// [`crate::metrics::stream::TappedSink`], so the structural quality
/// lands in the returned [`StreamReport`] at near-zero extra memory.
/// Memory runs return the assembled dataset untouched — score it once
/// with [`crate::metrics::Evaluator`] against the source (as `sgg run`
/// does), rather than paying a second pass inside the library.
pub fn run_scenario_with(spec: &ScenarioSpec, regs: &Registries) -> Result<SinkOutput> {
    run_scenario_opts(spec, regs, RunOptions::default())
}

/// Robustness knobs for [`run_scenario_opts`] — the levers behind `sgg
/// run --resume` / `--fault-seed`, the harness's fault re-runs, and
/// `sgg serve`'s job supervision (cancellation + live progress).
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Resume an interrupted shard run from its per-chunk completion
    /// records (the intact shard prefix): already-completed chunks are
    /// skipped, the rest regenerate deterministically, and the final
    /// directory is byte-identical to an uninterrupted run. Shard sinks
    /// only — memory runs have nothing durable to resume from.
    pub resume: bool,
    /// Deterministic fault schedule injected into chunk sampling
    /// (transient errors + worker panics via the runner) and shard
    /// writes (via a [`FaultSink`] in front of the real sink). The
    /// sink's [`RetryPolicy`] absorbs every transient fault, so output
    /// is bit-identical to a fault-free run.
    pub faults: Option<FaultPlan>,
    /// Cooperative cancellation: when set, a [`sink::CancelSink`] wraps
    /// the sink chain and aborts the run through the parallel runner's
    /// first-error path as soon as the token trips. A cancelled shard
    /// run keeps its consecutive completed prefix and can be finished
    /// later with [`RunOptions::resume`].
    pub cancel: Option<CancelToken>,
    /// Live progress mirror for shard runs: the [`ShardSink`] publishes
    /// a [`StreamReport`] snapshot into this slot after every written
    /// shard (`sgg serve` streams these from `GET /jobs/<id>`). Ignored
    /// by memory runs.
    pub progress: Option<sink::ProgressHandle>,
}

/// [`run_scenario_with`] plus [`RunOptions`]: resume support and fault
/// injection for shard runs.
pub fn run_scenario_opts(
    spec: &ScenarioSpec,
    regs: &Registries,
    opts: RunOptions,
) -> Result<SinkOutput> {
    let source = match &spec.model {
        Some(_) => None,
        None => Some(crate::datasets::load(&spec.dataset, spec.dataset_seed)?),
    };
    let fitted = match (&spec.model, &source) {
        (Some(path), _) => FittedPipeline::load(path, regs)?,
        (None, Some(ds)) => spec.to_builder().fit_with(ds, regs)?,
        (None, None) => unreachable!("spec parsing enforces dataset xor model"),
    };
    if spec.evaluate && source.is_none() {
        return Err(Error::Config(
            "`[evaluate]` needs the fit source as a reference, but the scenario \
             generates from a `model` artifact"
                .into(),
        ));
    }
    if opts.resume && !matches!(spec.sink, SinkSpec::Shards { .. }) {
        return Err(Error::Config(
            "`--resume` needs a shard sink: memory runs leave no completion \
             records to resume from"
                .into(),
        ));
    }
    if opts.resume && spec.evaluate {
        return Err(Error::Config(
            "`--resume` cannot be combined with `[evaluate]`: the in-flight \
             tap would miss the chunks the resumed run skips — re-score the \
             finished shards with `sgg eval --shards` instead"
                .into(),
        ));
    }
    // `workers = 0` means "one per core" at run time
    let workers = match spec.workers {
        0 => crate::util::threadpool::default_threads(),
        w => w,
    };
    let out = match &spec.sink {
        SinkSpec::Memory => {
            let chunks =
                ChunkConfig { workers, faults: opts.faults, ..ChunkConfig::default() };
            let mut sink = MemorySink::new();
            let mut faulted;
            let mut retrying;
            let inner: &mut dyn Sink = if let Some(plan) = opts.faults {
                faulted = FaultSink::new(&mut sink, plan);
                retrying = RetryingSink::new(&mut faulted, chunks.retry);
                &mut retrying
            } else {
                &mut sink
            };
            if let Some(token) = &opts.cancel {
                let mut cancel = sink::CancelSink::new(inner, token.clone());
                fitted.run(spec.size, chunks, &mut cancel, spec.seed)?
            } else {
                fitted.run(spec.size, chunks, inner, spec.seed)?
            }
        }
        SinkSpec::Shards { dir, chunks } => {
            let mut chunks = *chunks;
            if chunks.workers == 0 {
                chunks.workers = workers;
            }
            chunks.faults = opts.faults;
            // Shard runs encode on the workers (cache-hot, fully
            // parallel); the sink's fast path writes the bytes verbatim.
            chunks.encode = true;
            let mut sink = if opts.resume {
                let (sink, completed) = ShardSink::resume(dir, chunks)?;
                chunks.resume_from = completed;
                sink
            } else {
                ShardSink::new(dir, chunks)?
            };
            if let Some(slot) = &opts.progress {
                sink.publish_to(slot.clone());
            }
            // Adapter order matters: the tap sits innermost so it
            // observes each chunk exactly once — injected faults fire
            // (and retries replay) above it; the cancel check sits
            // outermost so a tripped token stops the run before any
            // further work.
            let mut tapped;
            let inner: &mut dyn Sink = if spec.evaluate {
                let tap = crate::metrics::stream::GenerationTap::new(
                    &source.as_ref().expect("checked above").edges,
                );
                tapped = crate::metrics::stream::TappedSink::new(&mut sink, tap);
                &mut tapped
            } else {
                &mut sink
            };
            let mut faulted;
            let mut retrying;
            let inner: &mut dyn Sink = if let Some(plan) = opts.faults {
                faulted = FaultSink::new(inner, plan);
                retrying = RetryingSink::new(&mut faulted, chunks.retry);
                &mut retrying
            } else {
                inner
            };
            if let Some(token) = &opts.cancel {
                let mut cancel = sink::CancelSink::new(inner, token.clone());
                fitted.run(spec.size, chunks, &mut cancel, spec.seed)?
            } else {
                fitted.run(spec.size, chunks, inner, spec.seed)?
            }
        }
    };
    Ok(out)
}

impl ScenarioSpec {
    /// Lower the declarative spec onto a [`PipelineBuilder`].
    pub fn to_builder(&self) -> PipelineBuilder {
        Pipeline::builder()
            .structure(self.structure.clone())
            .edge_features(self.edge_features.clone())
            .node_feature_spec(self.node_features.clone())
            .aligner(self.aligner.clone())
            .seed(self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    #[test]
    fn fit_generate_same_size() {
        let ds = crate::datasets::load("ieee-fraud", 1).unwrap();
        let p = Pipeline::builder().fit(&ds).unwrap();
        let synth = p.generate(1, 9).unwrap();
        assert_eq!(synth.edges.len(), ds.edges.len());
        assert_eq!(synth.edge_features.n_rows(), ds.edges.len());
        assert_eq!(synth.edge_features.n_cols(), ds.edge_features.n_cols());
    }

    #[test]
    fn fitted_beats_random_on_degree_metric() {
        let ds = crate::datasets::load("tabformer", 2).unwrap();
        let ours = Pipeline::builder().fit(&ds).unwrap().generate(1, 5).unwrap();
        let rand = Pipeline::builder()
            .structure("erdos-renyi")
            .edge_features("random")
            .aligner("random")
            .fit(&ds)
            .unwrap()
            .generate(1, 5)
            .unwrap();
        let ours_score = metrics::degree::degree_dist_score(&ds.edges, &ours.edges);
        let rand_score = metrics::degree::degree_dist_score(&ds.edges, &rand.edges);
        assert!(
            ours_score > rand_score,
            "ours={ours_score} random={rand_score}"
        );
    }

    #[test]
    fn scale_two_quadruples_edges() {
        let ds = crate::datasets::load("travel-insurance", 3).unwrap();
        let p = Pipeline::builder().fit(&ds).unwrap();
        let synth = p.generate(2, 4).unwrap();
        assert_eq!(synth.edges.len(), 4 * ds.edges.len());
        assert_eq!(synth.edges.spec.n_src, 2 * ds.edges.spec.n_src);
    }

    #[test]
    fn all_component_combos_run() {
        // subsample to keep the 24-combo sweep fast
        let mut ds = crate::datasets::load("travel-insurance", 4).unwrap();
        let keep: Vec<usize> = (0..ds.edges.len()).step_by(10).collect();
        ds.edge_features = ds.edge_features.gather(&keep);
        let mut edges = crate::graph::EdgeList::new(ds.edges.spec);
        for &i in &keep {
            edges.push(ds.edges.src[i], ds.edges.dst[i]);
        }
        ds.edges = edges;
        let fast_gbt = GbtConfig { n_trees: 5, ..GbtConfig::fast() };
        for sk in ["kronecker", "erdos-renyi", "sbm", "trilliong"] {
            for fk in ["kde", "random", "gaussian"] {
                for ak in ["learned", "random"] {
                    let p = Pipeline::builder()
                        .structure(sk)
                        .edge_features(fk)
                        .aligner(ak)
                        .gbt(fast_gbt.clone())
                        .fit(&ds)
                        .unwrap();
                    let s = p.generate(1, 1).unwrap();
                    assert_eq!(s.edges.len(), ds.edges.len(), "{sk}/{fk}/{ak}");
                }
            }
        }
    }

    #[test]
    fn default_builder_matches_paper_components() {
        // the default component set the removed enum shim used to pin:
        // kronecker structure, kde features, learned (xgboost) aligner
        let ds = crate::datasets::load("travel-insurance", 5).unwrap();
        let p = Pipeline::builder().no_node_features().fit(&ds).unwrap();
        let (s, f, a) = p.component_names();
        assert_eq!(s, "kronecker");
        assert_eq!(f, "kde");
        assert_eq!(a, "xgboost");
        assert_eq!(p.source().dataset, "travel-insurance");
        assert_eq!(p.source().edges, ds.edges.len() as u64);
        let synth = p.generate(1, 2).unwrap();
        assert_eq!(synth.edges.len(), ds.edges.len());
    }

    #[test]
    fn node_features_generated_when_source_has_them() {
        let ds = crate::datasets::load("cora", 1).unwrap();
        let nf_cols = ds.node_features.as_ref().unwrap().n_cols();
        let p = Pipeline::builder()
            .node_features("kde")
            .gbt(GbtConfig { n_trees: 4, ..GbtConfig::fast() })
            .fit(&ds)
            .unwrap();
        assert!(p.has_node_features());
        let synth = p.generate(1, 3).unwrap();
        let nf = synth.node_features.expect("node features missing");
        assert_eq!(nf.n_rows(), synth.edges.spec.n_src as usize);
        assert_eq!(nf.n_cols(), nf_cols);
    }

    #[test]
    fn unknown_backend_lists_registered_names() {
        let ds = crate::datasets::load("travel-insurance", 6).unwrap();
        let err = Pipeline::builder().structure("warp").fit(&ds).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp") && msg.contains("kronecker"), "{msg}");
    }

    #[test]
    fn memory_sink_run_matches_generate() {
        let ds = crate::datasets::load("travel-insurance", 7).unwrap();
        // prefix_levels = 0 gives the generic split plan a single chunk
        // on the raw seed, so the sink path samples the exact same
        // sequence as `generate` and the outputs match edge-for-edge
        let p = Pipeline::builder()
            .structure("erdos-renyi")
            .aligner("random")
            .edge_features("random")
            .fit(&ds)
            .unwrap();
        let direct = p.generate(1, 11).unwrap();
        let cfg = ChunkConfig {
            prefix_levels: 0,
            workers: 1,
            queue_capacity: 4,
            ..ChunkConfig::default()
        };
        let mut sink = MemorySink::new();
        let via_sink = p
            .run(SizeSpec::Scale(1), cfg, &mut sink, 11)
            .unwrap()
            .into_dataset()
            .unwrap();
        assert_eq!(direct.edges.src, via_sink.edges.src);
        assert_eq!(direct.edges.dst, via_sink.edges.dst);
    }

    #[test]
    fn run_output_is_worker_count_invariant() {
        let ds = crate::datasets::load("travel-insurance", 8).unwrap();
        let p = Pipeline::builder()
            .structure("erdos-renyi")
            .aligner("random")
            .edge_features("random")
            .fit(&ds)
            .unwrap();
        let run_with = |workers: usize| {
            let cfg = ChunkConfig {
                prefix_levels: 2,
                workers,
                queue_capacity: 2,
                ..ChunkConfig::default()
            };
            let mut sink = MemorySink::new();
            p.run(SizeSpec::Scale(1), cfg, &mut sink, 13)
                .unwrap()
                .into_dataset()
                .unwrap()
        };
        let seq = run_with(1);
        for workers in [2, 4] {
            let par = run_with(workers);
            assert_eq!(seq.edges.src, par.edges.src, "workers={workers}");
            assert_eq!(seq.edges.dst, par.edges.dst, "workers={workers}");
            // features + alignment are derived from the same structure
            // and seed, so the whole dataset matches
            assert_eq!(seq.edge_features.n_rows(), par.edge_features.n_rows());
        }
    }
}
