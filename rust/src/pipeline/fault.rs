//! Deterministic fault injection and bounded recovery — the robustness
//! layer under the conformance harness (`sgg test`) and the
//! `fault_paths` test suite.
//!
//! At shard scale, writes fail, readers hit truncated files, and pool
//! workers die mid-run. This module makes those failures *reproducible*
//! and the recovery machinery testable:
//!
//! * [`FaultPlan`] — a seed-driven schedule of injected faults. Every
//!   decision is a pure hash of `(seed, operation kind, index, attempt)`,
//!   so a plan replays identically across runs, worker counts, and
//!   machines. Transient faults fire only on attempts below
//!   [`FaultPlan::max_faulty_attempts`], so bounded retry provably
//!   converges; the injected worker panic fires on the first attempt
//!   only, so a retried chunk recovers bit-identically (chunk sampling is
//!   deterministic per index).
//! * [`RetryPolicy`] + [`retry_transient`] / [`run_attempts`] — bounded
//!   retry with a deterministic exponential backoff schedule
//!   (`backoff_ms << attempt`; the default backoff is 0 ms so tests never
//!   touch the wall clock). [`run_attempts`] additionally catches worker
//!   panics and converts them into [`Error::Worker`], consuming one
//!   attempt each — a persistent panic exhausts the budget and surfaces
//!   as a single clean error instead of unwinding through the pool.
//! * [`FaultSink`] / [`RetryingSink`] — sink adapters: the first injects
//!   the plan's sink faults in front of any [`Sink`], the second retries
//!   transient sink errors per chunk.
//! * [`FaultReader`] — the read-side adapter over
//!   [`ShardReader`](crate::graph::io::ShardReader), injecting transient
//!   read faults and retrying them.
//!
//! Classification lives on the error type itself
//! ([`Error::is_transient`]): interrupted/timed-out I/O is worth a
//! retry, everything else — truncation, bad magic, config errors,
//! exhausted panics — aborts the run.

use crate::graph::io::ShardReader;
use crate::graph::EdgeList;
use crate::pipeline::sink::{Sink, SinkFinish};
use crate::structgen::chunked::Chunk;
use crate::{Error, Result};
use std::collections::HashMap;

/// Bounded retry with deterministic exponential backoff.
///
/// `max_retries` is the number of *re*-attempts after the first try, so
/// an operation runs at most `max_retries + 1` times. The backoff before
/// re-attempt `a` (0-based) is `backoff_ms << a` milliseconds — a fixed,
/// wall-clock-independent schedule. The default keeps `backoff_ms = 0`
/// so the test suite never sleeps; production callers opt into a real
/// delay (e.g. 25 ms) via a scenario's `[sink]` stanza.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (0 disables retry).
    pub max_retries: u32,
    /// Base backoff in milliseconds, doubled each re-attempt.
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff_ms: 0 }
    }
}

impl RetryPolicy {
    /// No retries at all: every error is final on first occurrence.
    pub const fn none() -> RetryPolicy {
        RetryPolicy { max_retries: 0, backoff_ms: 0 }
    }

    /// Backoff in milliseconds before re-attempt `attempt` (0-based):
    /// `backoff_ms << attempt`, shift-capped so it cannot overflow.
    pub fn backoff_for(&self, attempt: u32) -> u64 {
        self.backoff_ms << attempt.min(16)
    }

    fn sleep_before(&self, attempt: u32) {
        let ms = self.backoff_for(attempt);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Run `op(attempt)` under `policy`: transient errors
/// ([`Error::is_transient`]) consume one attempt each and are retried
/// after the deterministic backoff; the first fatal error — or a
/// transient one past the budget — propagates.
pub fn retry_transient<T>(policy: RetryPolicy, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
    let mut attempt = 0u32;
    loop {
        match op(attempt) {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient() && attempt < policy.max_retries => {
                policy.sleep_before(attempt);
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// [`retry_transient`] that additionally catches panics in `op` and
/// converts them to [`Error::Worker`], treating each caught panic as a
/// retryable attempt. Chunk sampling is deterministic per index, so a
/// retried chunk reproduces the exact same edges; a panic that fires on
/// every attempt exhausts the budget and surfaces as one clean error.
pub fn run_attempts<T>(policy: RetryPolicy, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
    let mut attempt = 0u32;
    loop {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| op(attempt)));
        let err = match outcome {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(e)) => e,
            Err(payload) => Error::Worker(panic_message(payload)),
        };
        let retryable = err.is_transient() || matches!(err, Error::Worker(_));
        if !retryable || attempt >= policy.max_retries {
            return Err(err);
        }
        policy.sleep_before(attempt);
        attempt += 1;
    }
}

/// Best-effort extraction of a panic payload's message (the `&str` /
/// `String` payloads `panic!` produces).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// A deterministic, seed-driven fault schedule. Every decision is a pure
/// function of the plan and `(kind, index, attempt)` — no RNG state, no
/// wall clock — so the same plan injects the same faults on every run,
/// at any worker count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed of the fault schedule (independent of the generation seed).
    pub seed: u64,
    /// Per-1024 probability that sampling a chunk fails transiently.
    pub sample_rate: u16,
    /// Per-1024 probability that a sink write fails transiently.
    pub sink_rate: u16,
    /// Per-1024 probability that a shard read fails transiently.
    pub read_rate: u16,
    /// Inject a worker panic while sampling this chunk (first attempt
    /// only, so a retry recovers).
    pub panic_at_chunk: Option<usize>,
    /// Inject a *fatal* (non-transient) sink error at this chunk index —
    /// the interruption lever of the `--resume` tests.
    pub fatal_at_chunk: Option<usize>,
    /// Transient faults fire only on attempts below this bound, so a
    /// retry budget of `max_faulty_attempts` re-attempts always
    /// converges. 0 disables all rate-based faults.
    pub max_faulty_attempts: u8,
}

/// Operation kinds hashed into fault decisions (distinct streams per op).
const KIND_SAMPLE: u64 = 1;
const KIND_SINK: u64 = 2;
const KIND_READ: u64 = 3;

impl FaultPlan {
    /// The harness's standard adversarial schedule: transient faults on
    /// roughly one in five samples/writes/reads (first attempt only) plus
    /// one injected worker panic, all recoverable under the default
    /// [`RetryPolicy`].
    pub fn transient(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            sample_rate: 200,
            sink_rate: 200,
            read_rate: 200,
            panic_at_chunk: Some(1),
            fatal_at_chunk: None,
            max_faulty_attempts: 1,
        }
    }

    /// A plan that only interrupts: one fatal sink error at `chunk`,
    /// nothing else. Used to simulate a crash for `--resume` tests.
    pub fn fatal_at(chunk: usize) -> FaultPlan {
        FaultPlan { fatal_at_chunk: Some(chunk), ..FaultPlan::default() }
    }

    /// splitmix64-style decision hash over `(seed, kind, index, attempt)`.
    fn hash(&self, kind: u64, index: usize, attempt: u32) -> u64 {
        let mut z = self
            .seed
            .wrapping_add(kind.wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .wrapping_add((index as u64 + 1).wrapping_mul(0xbf58_476d_1ce4_e5b9))
            .wrapping_add((attempt as u64 + 1).wrapping_mul(0x94d0_49bb_1331_11eb));
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn fires(&self, kind: u64, rate: u16, index: usize, attempt: u32) -> bool {
        rate > 0
            && attempt < self.max_faulty_attempts as u32
            && self.hash(kind, index, attempt) % 1024 < rate as u64
    }

    fn transient_err(op: &str, index: usize, attempt: u32) -> Error {
        Error::Io(std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            format!("injected transient {op} fault at index {index}, attempt {attempt}"),
        ))
    }

    /// Transient fault (if any) for sampling chunk `index` on `attempt`.
    pub fn sample_fault(&self, index: usize, attempt: u32) -> Option<Error> {
        self.fires(KIND_SAMPLE, self.sample_rate, index, attempt)
            .then(|| Self::transient_err("sample", index, attempt))
    }

    /// Transient fault (if any) for writing chunk `index` on `attempt`.
    pub fn sink_fault(&self, index: usize, attempt: u32) -> Option<Error> {
        self.fires(KIND_SINK, self.sink_rate, index, attempt)
            .then(|| Self::transient_err("sink", index, attempt))
    }

    /// Transient fault (if any) for reading shard `index` on `attempt`.
    pub fn read_fault(&self, index: usize, attempt: u32) -> Option<Error> {
        self.fires(KIND_READ, self.read_rate, index, attempt)
            .then(|| Self::transient_err("read", index, attempt))
    }

    /// True when a worker panic is injected for this chunk attempt
    /// (first attempt only — the retry recovers deterministically).
    pub fn should_panic(&self, index: usize, attempt: u32) -> bool {
        attempt == 0 && self.panic_at_chunk == Some(index)
    }

    /// Fatal sink error (if any) for chunk `index` — fires on every
    /// attempt, so no retry budget can absorb it.
    pub fn fatal_fault(&self, index: usize) -> Option<Error> {
        (self.fatal_at_chunk == Some(index)).then(|| {
            Error::Data(format!("injected fatal sink fault at chunk {index}"))
        })
    }
}

/// Sink adapter that injects a [`FaultPlan`]'s sink faults in front of
/// the wrapped sink. Per-chunk attempt counts are tracked here, so a
/// retrying caller sees the fault sequence the plan dictates and then a
/// clean pass-through once `max_faulty_attempts` is exhausted.
pub struct FaultSink<'a> {
    inner: &'a mut dyn Sink,
    plan: FaultPlan,
    attempts: HashMap<usize, u32>,
}

impl<'a> FaultSink<'a> {
    /// Wrap `inner`, injecting faults per `plan`.
    pub fn new(inner: &'a mut dyn Sink, plan: FaultPlan) -> FaultSink<'a> {
        FaultSink { inner, plan, attempts: HashMap::new() }
    }
}

impl Sink for FaultSink<'_> {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        let attempt = self.attempts.entry(chunk.index).or_insert(0);
        let a = *attempt;
        *attempt += 1;
        if let Some(e) = self.plan.fatal_fault(chunk.index) {
            return Err(e);
        }
        if let Some(e) = self.plan.sink_fault(chunk.index, a) {
            return Err(e);
        }
        self.inner.edges(chunk)
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        self.inner.finish()
    }
}

/// Sink adapter that retries transient `edges` errors of the wrapped
/// sink under a [`RetryPolicy`] (re-sending a clone of the chunk), and
/// passes fatal errors straight through.
pub struct RetryingSink<'a> {
    inner: &'a mut dyn Sink,
    retry: RetryPolicy,
}

impl<'a> RetryingSink<'a> {
    /// Wrap `inner` with bounded retry.
    pub fn new(inner: &'a mut dyn Sink, retry: RetryPolicy) -> RetryingSink<'a> {
        RetryingSink { inner, retry }
    }
}

impl Sink for RetryingSink<'_> {
    fn name(&self) -> &'static str {
        "retrying"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        // `&mut` delivery means retries re-offer the same buffer — no
        // defensive clone per attempt (a transient-faulted attempt must
        // not consume the chunk, and ownership-taking inner sinks only
        // take on success by contract)
        retry_transient(self.retry, |_attempt| self.inner.edges(&mut *chunk))
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        self.inner.finish()
    }
}

/// Read-side adapter over a [`ShardReader`]: injects the plan's read
/// faults and retries transient failures (injected or real) under the
/// policy. With `plan = None` it is a plain retrying reader.
pub struct FaultReader<'a> {
    inner: &'a ShardReader,
    plan: Option<FaultPlan>,
    retry: RetryPolicy,
}

impl<'a> FaultReader<'a> {
    /// Wrap `reader`, injecting faults per `plan` and retrying under
    /// `retry`.
    pub fn new(
        inner: &'a ShardReader,
        plan: Option<FaultPlan>,
        retry: RetryPolicy,
    ) -> FaultReader<'a> {
        FaultReader { inner, plan, retry }
    }

    /// Number of shards (delegates to the wrapped reader).
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    /// True when the wrapped reader holds no shards.
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Read shard `i`, retrying transient faults.
    pub fn read(&self, i: usize) -> Result<EdgeList> {
        retry_transient(self.retry, |attempt| {
            if let Some(plan) = &self.plan {
                if let Some(e) = plan.read_fault(i, attempt) {
                    return Err(e);
                }
            }
            self.inner.read(i)
        })
    }

    /// [`FaultReader::read`] into caller-owned buffers: decode shard `i`
    /// into `out` reusing `scratch` for the raw payload, retrying
    /// transient faults. Hot decode loops hold one `(scratch, out)` pair
    /// per worker so no per-shard allocation survives warm-up.
    pub fn read_into(
        &self,
        i: usize,
        scratch: &mut Vec<u8>,
        out: &mut crate::graph::EdgeList,
    ) -> Result<()> {
        retry_transient(self.retry, |attempt| {
            if let Some(plan) = &self.plan {
                if let Some(e) = plan.read_fault(i, attempt) {
                    return Err(e);
                }
            }
            self.inner.read_into(i, scratch, out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_recovers_from_transient_within_budget() {
        let policy = RetryPolicy { max_retries: 2, backoff_ms: 0 };
        let mut calls = 0u32;
        let out = retry_transient(policy, |attempt| {
            calls += 1;
            if attempt < 2 {
                Err(Error::Io(std::io::Error::new(std::io::ErrorKind::Interrupted, "x")))
            } else {
                Ok(attempt)
            }
        })
        .unwrap();
        assert_eq!(out, 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_does_not_touch_fatal_errors() {
        let policy = RetryPolicy { max_retries: 5, backoff_ms: 0 };
        let mut calls = 0u32;
        let err = retry_transient(policy, |_| -> Result<()> {
            calls += 1;
            Err(Error::Data("corrupt".into()))
        })
        .unwrap_err();
        assert_eq!(calls, 1, "fatal errors must not be retried");
        assert!(err.to_string().contains("corrupt"));
    }

    #[test]
    fn retry_budget_is_bounded() {
        let policy = RetryPolicy { max_retries: 3, backoff_ms: 0 };
        let mut calls = 0u32;
        let err = retry_transient(policy, |_| -> Result<()> {
            calls += 1;
            Err(Error::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, "x")))
        })
        .unwrap_err();
        assert_eq!(calls, 4, "first try + 3 retries");
        assert!(err.is_transient());
    }

    #[test]
    fn backoff_schedule_is_deterministic_exponential() {
        let policy = RetryPolicy { max_retries: 4, backoff_ms: 25 };
        assert_eq!(policy.backoff_for(0), 25);
        assert_eq!(policy.backoff_for(1), 50);
        assert_eq!(policy.backoff_for(2), 100);
        // shift cap: no overflow even for absurd attempts
        assert_eq!(policy.backoff_for(500), 25 << 16);
    }

    #[test]
    fn run_attempts_converts_and_retries_panics() {
        let policy = RetryPolicy { max_retries: 2, backoff_ms: 0 };
        let mut calls = 0u32;
        let out = run_attempts(policy, |attempt| {
            calls += 1;
            if attempt == 0 {
                panic!("injected worker panic");
            }
            Ok(attempt)
        })
        .unwrap();
        assert_eq!(out, 1);
        assert_eq!(calls, 2);
        // a persistent panic exhausts the budget and surfaces cleanly
        let err = run_attempts(RetryPolicy::none(), |_| -> Result<()> {
            panic!("it always dies")
        })
        .unwrap_err();
        match &err {
            Error::Worker(m) => assert!(m.contains("always dies"), "{m}"),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn fault_plan_is_deterministic_and_attempt_bounded() {
        let plan = FaultPlan::transient(42);
        for index in 0..256 {
            // same decision on replay
            assert_eq!(
                plan.sample_fault(index, 0).is_some(),
                plan.sample_fault(index, 0).is_some()
            );
            // faults never fire past the faulty-attempt bound, so retry
            // always converges
            assert!(plan.sample_fault(index, plan.max_faulty_attempts as u32).is_none());
            assert!(plan.sink_fault(index, plan.max_faulty_attempts as u32).is_none());
            assert!(plan.read_fault(index, plan.max_faulty_attempts as u32).is_none());
        }
        // the rates actually fire somewhere in a 256-chunk run
        let fired = (0..256).filter(|&i| plan.sink_fault(i, 0).is_some()).count();
        assert!(fired > 0, "sink faults never fired");
        assert!(fired < 256, "sink faults fired everywhere");
        // injected faults are transient by construction
        let e = plan.sink_fault((0..256).find(|&i| plan.sink_fault(i, 0).is_some()).unwrap(), 0);
        assert!(e.unwrap().is_transient());
    }

    #[test]
    fn fault_plan_panic_and_fatal_schedules() {
        let plan = FaultPlan::transient(7);
        assert!(plan.should_panic(1, 0));
        assert!(!plan.should_panic(1, 1), "panic must not recur on retry");
        assert!(!plan.should_panic(2, 0));
        let fatal = FaultPlan::fatal_at(5);
        assert!(fatal.fatal_fault(5).is_some());
        assert!(fatal.fatal_fault(4).is_none());
        assert!(!fatal.fatal_fault(5).unwrap().is_transient());
    }
}
