//! Multi-threaded chunked generation engine (the "fast as the hardware
//! allows" path of the ROADMAP, paper §10 / SANGEA-style shared-nothing
//! scaling).
//!
//! A generation job is first *decomposed* into a deterministic
//! [`ChunkPlan`]: a fixed list of chunks, each sampleable independently of
//! every other chunk. The decomposition depends only on the job (sizes,
//! seed, `prefix_levels`) — never on the worker count — and every chunk
//! derives its PRNG stream from `hash(seed, chunk_index)` (see
//! [`chunk_seed`]) or an equivalent per-chunk stream. Together these two
//! rules make the output **bit-identical for any worker count and any
//! scheduling interleaving**.
//!
//! [`ParallelChunkRunner`] then executes the plan:
//!
//! ```text
//!                 ┌─ worker 0 ─ sample(chunk i) ─┐
//!   chunk index   ├─ worker 1 ─ sample(chunk j) ─┤   bounded      writer
//!   (atomic) ────▶│        ...                   │──▶ channel ──▶ (caller
//!                 └─ worker W ─ sample(chunk k) ─┘  (capacity Q)  thread)
//!                                                                   │
//!                                      reorder buffer, emits chunks │
//!                                      in index order ──▶ Sink ◀────┘
//! ```
//!
//! * Workers claim chunk indices from an atomic counter and block while
//!   their index is further than `workers + queue_capacity` chunks ahead
//!   of the last index the writer emitted — this caps the reorder buffer
//!   and bounds peak memory at `(workers + queue_capacity + 1)` chunks.
//! * The bounded channel provides backpressure: a slow sink (e.g. a disk
//!   writer) stalls the pool instead of buffering unboundedly.
//! * The writer (running on the caller's thread) re-orders arriving
//!   chunks and feeds the sink strictly in chunk-index order, so sinks
//!   never need their own ordering pass.
//! * The first worker or sink error cancels the pool: in-flight workers
//!   stop at their next chunk boundary, remaining chunks are never
//!   sampled, and the error propagates to the caller.
//! * Chunk edge buffers are recycled through a bounded arena: the writer
//!   returns each emitted chunk's `EdgeList` (or whatever the sink left
//!   after `std::mem::take`) to a spare pool that workers draw from, so
//!   steady-state generation reuses at most `window` warm buffers
//!   instead of allocating one per chunk.

use crate::graph::io::{self, ShardFormat};
use crate::graph::EdgeList;
use crate::pipeline::fault::{self, FaultPlan, RetryPolicy};
use crate::structgen::chunked::{Chunk, ChunkConfig};
use crate::util::threadpool::Bounded;
use crate::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// A shared, cloneable cancellation flag for in-flight generation.
///
/// Cancellation rides the runner's existing first-error path: a
/// cancel-aware sink adapter (see
/// [`CancelSink`](crate::pipeline::sink::CancelSink)) turns a tripped
/// token into a sink error at the next chunk boundary, which aborts the
/// worker pool exactly like any other sink failure — in-flight workers
/// stop, unsampled chunks are never sampled, and the already-written
/// shard prefix stays intact (and resumable). `sgg serve`'s
/// `DELETE /jobs/<id>` trips this token.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// Fresh, untripped token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the token: every clone observes the cancellation.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// True once any clone has called [`CancelToken::cancel`].
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Deterministic per-chunk seed: a splitmix64-style hash of the job seed
/// and the chunk index. Chunk streams are independent of each other and
/// of the worker that happens to sample them.
pub fn chunk_seed(seed: u64, index: usize) -> u64 {
    let mut z = seed.wrapping_add((index as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Largest-remainder apportionment of `total` units over relative
/// `weights`: every chunk gets `floor(total · wᵢ / Σw)`, and the leftover
/// units go to the chunks with the largest fractional parts (stable on
/// ties). The budgets always sum to exactly `total`.
pub fn apportion(weights: &[f64], total: u64) -> Vec<u64> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let sum: f64 = weights.iter().sum();
    if sum <= 0.0 || !sum.is_finite() {
        let mut budgets = vec![0u64; n];
        budgets[0] = total;
        return budgets;
    }
    let targets: Vec<f64> = weights.iter().map(|w| w / sum * total as f64).collect();
    let mut budgets: Vec<u64> = targets.iter().map(|t| t.floor() as u64).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        let fi = targets[i] - budgets[i] as f64;
        let fj = targets[j] - budgets[j] as f64;
        fj.partial_cmp(&fi).unwrap()
    });
    // `total as f64` is inexact above 2^53, so the floored budgets can
    // land on either side of `total` (and their u64 sum can even
    // overflow, e.g. two targets of exactly 2^63); account in u128 and
    // correct in whichever direction the rounding landed
    let assigned: u128 = budgets.iter().map(|&b| b as u128).sum();
    let remainder = u128::from(total).saturating_sub(assigned);
    // remainder can exceed n when f64 ulp error dwarfs the fractional
    // parts (totals near 2^63+), so distribute it evenly rather than one
    // unit per chunk: base share everywhere, one extra for the largest
    // fractional parts. Per-chunk additions total `remainder`, so sums
    // stay exact and no individual budget can overflow past `total`.
    if remainder > 0 {
        let base = (remainder / n as u128) as u64;
        let extra = remainder % n as u128;
        for (rank, &i) in order.iter().enumerate() {
            budgets[i] += base + u64::from((rank as u128) < extra);
        }
    }
    let mut excess = assigned.saturating_sub(u128::from(total));
    for &i in order.iter().rev() {
        if excess == 0 {
            break;
        }
        let take = excess.min(u128::from(budgets[i]));
        budgets[i] -= take as u64;
        excess -= take;
    }
    budgets
}

/// A deterministic decomposition of one generation job into independently
/// sampleable chunks.
///
/// Implementations must satisfy the runner's determinism contract:
/// `sample(i)` depends only on the plan and `i` (its own PRNG stream,
/// typically seeded with [`chunk_seed`]), never on which worker runs it
/// or in what order.
pub trait ChunkPlan: Sync {
    /// Number of chunks in the decomposition (fixed at plan build time).
    fn n_chunks(&self) -> usize;

    /// Sample chunk `index`. May return an empty edge list for chunks
    /// with a zero edge budget; empty chunks are counted for ordering but
    /// never forwarded to the sink.
    fn sample(&self, index: usize) -> Result<EdgeList>;

    /// Sample chunk `index` into a caller-owned buffer, replacing its
    /// contents (spec included). The runner recycles chunk buffers
    /// through this entry point, so plans that override it to
    /// `reset`+`push` (rather than allocate a fresh list) sample every
    /// chunk after the warm-up with zero heap allocation. The default
    /// simply delegates to [`ChunkPlan::sample`] — behaviourally
    /// identical, one allocation per chunk.
    fn sample_into(&self, index: usize, out: &mut EdgeList) -> Result<()> {
        *out = self.sample(index)?;
        Ok(())
    }
}

/// Generic even-split decomposition for edge-i.i.d. generators: the total
/// edge budget is split into `4^prefix_levels` near-equal chunks (the
/// same chunk count the Kronecker prefix scheme uses), each sampled by a
/// caller-supplied function with its own [`chunk_seed`] stream.
///
/// A single-chunk plan (`prefix_levels = 0`) degenerates to one sample
/// with the *raw* job seed, i.e. exactly the pre-chunking sequential
/// behaviour of `generate_sized`.
pub struct SplitPlan<F> {
    budgets: Vec<u64>,
    seed: u64,
    sample: F,
}

impl<F> SplitPlan<F>
where
    F: Fn(usize, u64, u64) -> Result<EdgeList> + Sync,
{
    /// Build an even split of `total_edges` into `4^prefix_levels` chunks
    /// (trailing zero-budget chunks are trimmed). `sample` receives
    /// `(chunk_index, edge_budget, chunk_seed)`.
    pub fn even(total_edges: u64, prefix_levels: u32, seed: u64, sample: F) -> SplitPlan<F> {
        let n = 4usize.saturating_pow(prefix_levels.min(10)).max(1);
        let per = total_edges / n as u64;
        let rem = (total_edges % n as u64) as usize;
        let n_eff = if per == 0 { rem.max(1) } else { n };
        let budgets = (0..n_eff)
            .map(|i| per + u64::from(i < rem))
            .collect();
        SplitPlan { budgets, seed, sample }
    }
}

impl<F> ChunkPlan for SplitPlan<F>
where
    F: Fn(usize, u64, u64) -> Result<EdgeList> + Sync,
{
    fn n_chunks(&self) -> usize {
        self.budgets.len()
    }

    fn sample(&self, index: usize) -> Result<EdgeList> {
        let seed = if self.budgets.len() == 1 {
            self.seed
        } else {
            chunk_seed(self.seed, index)
        };
        (self.sample)(index, self.budgets[index], seed)
    }
}

/// The multi-threaded chunked generation engine: samples a [`ChunkPlan`]
/// on a worker pool and feeds a sink in chunk-index order. See the
/// module docs for the full dataflow and the determinism contract.
///
/// Robustness knobs (all default-off; see [`crate::pipeline::fault`]):
/// transient sampling errors and caught worker panics are retried under
/// `retry` (chunk streams are deterministic per index, so a retried
/// chunk reproduces the exact same edges); chunks below `resume_from`
/// are skipped (counted for ordering, never sampled or forwarded); an
/// optional [`FaultPlan`] injects deterministic sampling faults and
/// worker panics for tests and the conformance harness.
pub struct ParallelChunkRunner {
    workers: usize,
    queue_capacity: usize,
    retry: RetryPolicy,
    resume_from: usize,
    stop_before: Option<usize>,
    faults: Option<FaultPlan>,
    /// Encode each sampled chunk into its final shard wire bytes on the
    /// worker (see [`ChunkConfig::encode`]); `format` picks the wire
    /// encoding.
    encode: bool,
    format: ShardFormat,
}

impl ParallelChunkRunner {
    /// Runner with an explicit worker count and channel capacity (both
    /// clamped to ≥ 1). `workers == 1` runs the plan sequentially on the
    /// caller thread — same output, no threads spawned.
    pub fn new(workers: usize, queue_capacity: usize) -> ParallelChunkRunner {
        ParallelChunkRunner {
            workers: workers.max(1),
            queue_capacity: queue_capacity.max(1),
            retry: RetryPolicy::default(),
            resume_from: 0,
            stop_before: None,
            faults: None,
            encode: false,
            format: ShardFormat::Edge1,
        }
    }

    /// Runner configured from a [`ChunkConfig`]: worker count, channel
    /// capacity, retry policy, resume watermark, chunk-range stop bound,
    /// and fault plan.
    pub fn from_config(cfg: ChunkConfig) -> ParallelChunkRunner {
        ParallelChunkRunner {
            retry: cfg.retry,
            resume_from: cfg.resume_from,
            stop_before: cfg.stop_before,
            faults: cfg.faults,
            encode: cfg.encode,
            format: cfg.format,
            ..ParallelChunkRunner::new(cfg.workers, cfg.queue_capacity)
        }
    }

    /// Sample one chunk into `out` under the runner's robustness policy:
    /// skip it entirely when below the resume watermark (leaving `out`
    /// empty), otherwise run the plan's `sample_into` under bounded
    /// retry ([`fault::run_attempts`] converts caught panics to
    /// [`crate::Error::Worker`] and retries transient failures),
    /// injecting the fault plan's scheduled sampling faults and panics
    /// first. `out` is cleared at the start of every attempt, so a
    /// failed or panicked attempt can never leak partial edges into a
    /// retry.
    fn sample_chunk_into(
        &self,
        plan: &dyn ChunkPlan,
        index: usize,
        out: &mut EdgeList,
    ) -> Result<()> {
        out.clear();
        if index < self.resume_from || self.stop_before.map_or(false, |stop| index >= stop) {
            // outside this process's chunk range (already persisted by an
            // interrupted run, or owned by another host); empty chunks
            // are counted for ordering but never forwarded to the sink
            return Ok(());
        }
        fault::run_attempts(self.retry, |attempt| {
            out.clear();
            if let Some(fp) = &self.faults {
                if fp.should_panic(index, attempt) {
                    panic!("injected worker panic at chunk {index}");
                }
                if let Some(e) = fp.sample_fault(index, attempt) {
                    return Err(e);
                }
            }
            plan.sample_into(index, out)
        })
    }

    /// Parallel fold over the index range `0..n`: the range is split
    /// into one **contiguous, statically-assigned** slice per worker,
    /// each worker folds its slice into a private accumulator
    /// (`init(worker)` then `step(&mut acc, index)` in index order), and
    /// the partial accumulators are returned **in worker order**.
    ///
    /// This is the map/reduce counterpart of [`ParallelChunkRunner::run`]
    /// — used by the streaming evaluation path to accumulate per-shard
    /// metric partials in parallel. Determinism contract: for a fixed
    /// worker count the partition (and therefore each partial) is fully
    /// deterministic; results are additionally *invariant across worker
    /// counts* whenever the caller's merge of the partials is exactly
    /// associative and commutative (true for the count-based metric
    /// accumulators — see `metrics::accum`).
    ///
    /// The first `step` error (scanning workers in order) propagates;
    /// a worker panic surfaces as a single [`crate::Error::Worker`]
    /// rather than unwinding through the caller.
    pub fn fold_indices<A, I, S>(&self, n: usize, init: I, step: S) -> Result<Vec<A>>
    where
        A: Send,
        I: Fn(usize) -> A + Sync,
        S: Fn(&mut A, usize) -> Result<()> + Sync,
    {
        let workers = self.workers.min(n).max(1);
        if workers == 1 {
            let mut acc = init(0);
            for i in 0..n {
                step(&mut acc, i)?;
            }
            return Ok(vec![acc]);
        }
        let results: Vec<Result<A>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let (init, step) = (&init, &step);
                    let (lo, hi) = (w * n / workers, (w + 1) * n / workers);
                    s.spawn(move || -> Result<A> {
                        let mut acc = init(w);
                        for i in lo..hi {
                            step(&mut acc, i)?;
                        }
                        Ok(acc)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    // a panicking fold worker surfaces as one clean
                    // error instead of unwinding through the pool
                    Err(panic) => Err(crate::Error::Worker(fault::panic_message(panic))),
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Execute `plan`, streaming non-empty chunks into `sink` in
    /// chunk-index order. Returns the total number of edges produced.
    ///
    /// The sink receives each chunk by `&mut` and may take ownership of
    /// its edges with `std::mem::take`; whatever buffer it leaves behind
    /// is recycled into a bounded arena (at most `window` spare lists)
    /// that workers draw their next chunk buffer from, so a streaming
    /// sink drives the whole run on a fixed set of edge buffers instead
    /// of one fresh allocation per chunk.
    ///
    /// The first error — from a worker's `sample` or from the sink —
    /// cancels the pool and propagates; the sink never sees another chunk
    /// after returning an error.
    pub fn run(
        &self,
        plan: &dyn ChunkPlan,
        sink: &mut dyn FnMut(&mut Chunk) -> Result<()>,
    ) -> Result<u64> {
        let n = plan.n_chunks();
        if n == 0 {
            return Ok(0);
        }
        if self.workers == 1 {
            return self.run_sequential(plan, sink);
        }

        // Reorder window: a worker may run at most this many chunks ahead
        // of the writer, which caps chunks alive at once (in workers'
        // hands + queued + reorder-buffered) at `window`, plus the one
        // the writer holds.
        let window = self.workers + self.queue_capacity;
        let chan: Bounded<Chunk> = Bounded::new(self.queue_capacity);
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let emitted = Mutex::new(0usize);
        let advanced = Condvar::new();
        let worker_err: Mutex<Option<crate::Error>> = Mutex::new(None);
        // Recycled chunk buffers: the writer returns emitted chunks'
        // edge lists here and workers pop them for their next chunk, so
        // steady-state sampling reuses at most `window` warm buffers.
        let pool: Mutex<Vec<EdgeList>> = Mutex::new(Vec::new());
        // Companion arena for the worker-encode stage: encoded shard
        // byte buffers flow back from the writer the same way.
        let byte_pool: Mutex<Vec<Vec<u8>>> = Mutex::new(Vec::new());
        let mut sink_err: Option<crate::Error> = None;
        let mut total = 0u64;

        std::thread::scope(|s| {
            for w in 0..self.workers {
                let tx = chan.clone();
                let this = &*self;
                let (next, abort, pool, byte_pool) = (&next, &abort, &pool, &byte_pool);
                let (emitted, advanced, worker_err) = (&emitted, &advanced, &worker_err);
                s.spawn(move || loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    if ci >= n {
                        break;
                    }
                    {
                        // stay inside the reorder window
                        let mut done = emitted.lock().unwrap();
                        while ci >= *done + window && !abort.load(Ordering::Relaxed) {
                            done = advanced.wait(done).unwrap();
                        }
                    }
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let mut edges = pool.lock().unwrap().pop().unwrap_or_default();
                    let t0 = Instant::now();
                    match this.sample_chunk_into(plan, ci, &mut edges) {
                        Ok(()) => {
                            let sample_secs = t0.elapsed().as_secs_f64();
                            // encode right here, while the chunk is
                            // cache-hot: per-chunk encoding is
                            // deterministic, so doing it on the worker
                            // changes nothing but where the CPU time
                            // lands
                            let (encoded, encode_secs) = if this.encode && !edges.is_empty()
                            {
                                let mut bytes =
                                    byte_pool.lock().unwrap().pop().unwrap_or_default();
                                let te = Instant::now();
                                io::encode_chunk(&edges, this.format, &mut bytes);
                                (
                                    Some(io::EncodedChunk { format: this.format, bytes }),
                                    te.elapsed().as_secs_f64(),
                                )
                            } else {
                                (None, 0.0)
                            };
                            let chunk = Chunk {
                                index: ci,
                                worker: w,
                                sample_secs,
                                encode_secs,
                                edges,
                                encoded,
                            };
                            if tx.send(chunk).is_err() {
                                break; // channel closed: run is over
                            }
                        }
                        Err(e) => {
                            let mut slot = worker_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            drop(slot);
                            abort.store(true, Ordering::Relaxed);
                            tx.close(); // wake the writer and fail other senders
                            advanced.notify_all();
                            break;
                        }
                    }
                });
            }

            // Writer, on the caller thread: reorder arriving chunks and
            // emit strictly in index order, recycling each chunk's
            // buffer after the sink has seen it.
            let recycle = |edges: EdgeList| {
                let mut spare = pool.lock().unwrap();
                if spare.len() < window {
                    spare.push(edges);
                }
            };
            let rx = chan.clone();
            let mut pending: BTreeMap<usize, Chunk> = BTreeMap::new();
            let mut expect = 0usize;
            'writer: while expect < n {
                let chunk = match rx.recv() {
                    Some(c) => c,
                    None => break, // a worker failed and closed the channel
                };
                pending.insert(chunk.index, chunk);
                while let Some(mut c) = pending.remove(&expect) {
                    expect += 1;
                    *emitted.lock().unwrap() = expect;
                    advanced.notify_all();
                    if c.edges.is_empty() {
                        recycle(c.edges);
                        continue; // ordered, but nothing for the sink
                    }
                    total += c.edges.len() as u64;
                    let res = sink(&mut c);
                    // an ownership-taking sink leaves an empty (taken)
                    // list behind; a borrowing sink leaves the full
                    // buffer — either way the allocation goes back to
                    // the workers
                    recycle(std::mem::take(&mut c.edges));
                    // same for the encoded byte buffer: a shard sink
                    // takes it (and may leave a drained one in its
                    // place); whatever remains feeds the encode arena
                    if let Some(enc) = c.encoded.take() {
                        let mut spare = byte_pool.lock().unwrap();
                        if spare.len() < window {
                            spare.push(enc.bytes);
                        }
                    }
                    if let Err(e) = res {
                        sink_err = Some(e);
                        abort.store(true, Ordering::Relaxed);
                        rx.close();
                        advanced.notify_all();
                        break 'writer;
                    }
                }
            }
            chan.close();
            advanced.notify_all();
        });

        if let Some(e) = sink_err {
            return Err(e);
        }
        if let Some(e) = worker_err.into_inner().unwrap() {
            return Err(e);
        }
        Ok(total)
    }

    /// Sequential execution of a plan on the caller thread: identical
    /// chunk decomposition, seeds, and robustness policy, so the output
    /// matches any parallel run byte for byte. The degenerate arena: one
    /// buffer, sampled into and handed to the sink chunk after chunk.
    fn run_sequential(
        &self,
        plan: &dyn ChunkPlan,
        sink: &mut dyn FnMut(&mut Chunk) -> Result<()>,
    ) -> Result<u64> {
        let mut total = 0u64;
        let mut buf = EdgeList::default();
        let mut bytes = Vec::new();
        for index in 0..plan.n_chunks() {
            let t0 = Instant::now();
            self.sample_chunk_into(plan, index, &mut buf)?;
            if buf.is_empty() {
                continue;
            }
            total += buf.len() as u64;
            let sample_secs = t0.elapsed().as_secs_f64();
            let (encoded, encode_secs) = if self.encode {
                let te = Instant::now();
                io::encode_chunk(&buf, self.format, &mut bytes);
                (
                    Some(io::EncodedChunk {
                        format: self.format,
                        bytes: std::mem::take(&mut bytes),
                    }),
                    te.elapsed().as_secs_f64(),
                )
            } else {
                (None, 0.0)
            };
            let mut chunk = Chunk {
                index,
                worker: 0,
                sample_secs,
                encode_secs,
                edges: std::mem::take(&mut buf),
                encoded,
            };
            let res = sink(&mut chunk);
            buf = std::mem::take(&mut chunk.edges);
            if let Some(enc) = chunk.encoded.take() {
                bytes = enc.bytes;
            }
            res?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::util::rng::Pcg64;
    use crate::Error;

    /// Plan whose chunks are small seeded random edge lists.
    struct TestPlan {
        n: usize,
        per: usize,
        seed: u64,
        fail_at: Option<usize>,
    }

    impl ChunkPlan for TestPlan {
        fn n_chunks(&self) -> usize {
            self.n
        }

        fn sample(&self, index: usize) -> Result<EdgeList> {
            if self.fail_at == Some(index) {
                return Err(Error::Data(format!("chunk {index} exploded")));
            }
            let mut rng = Pcg64::new(chunk_seed(self.seed, index));
            let mut e = EdgeList::with_capacity(PartiteSpec::square(1 << 10), self.per);
            for _ in 0..self.per {
                e.push(rng.below(1 << 10), rng.below(1 << 10));
            }
            Ok(e)
        }
    }

    fn collect(workers: usize, plan: &TestPlan) -> Result<(Vec<usize>, EdgeList)> {
        let runner = ParallelChunkRunner::new(workers, 2);
        let mut order = Vec::new();
        let mut all = EdgeList::new(PartiteSpec::square(1 << 10));
        runner.run(plan, &mut |c| {
            order.push(c.index);
            all.extend_from(&c.edges);
            Ok(())
        })?;
        Ok((order, all))
    }

    #[test]
    fn chunks_arrive_in_index_order_for_any_worker_count() {
        let plan = TestPlan { n: 37, per: 100, seed: 5, fail_at: None };
        for workers in [1, 2, 4, 8] {
            let (order, _) = collect(workers, &plan).unwrap();
            assert_eq!(order, (0..37).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn output_bit_identical_across_worker_counts() {
        let plan = TestPlan { n: 23, per: 250, seed: 9, fail_at: None };
        let (_, base) = collect(1, &plan).unwrap();
        for workers in [2, 3, 4, 8] {
            let (_, out) = collect(workers, &plan).unwrap();
            assert_eq!(base.src, out.src, "workers={workers}");
            assert_eq!(base.dst, out.dst, "workers={workers}");
        }
    }

    #[test]
    fn worker_error_cancels_pool_and_propagates() {
        let plan = TestPlan { n: 64, per: 50, seed: 1, fail_at: Some(10) };
        for workers in [1, 4] {
            let err = collect(workers, &plan).unwrap_err();
            assert!(err.to_string().contains("chunk 10 exploded"), "{err}");
        }
    }

    #[test]
    fn sink_error_cancels_pool_and_propagates() {
        let plan = TestPlan { n: 64, per: 50, seed: 2, fail_at: None };
        let runner = ParallelChunkRunner::new(4, 1);
        let mut seen = 0usize;
        let err = runner
            .run(&plan, &mut |_c| {
                seen += 1;
                if seen == 3 {
                    Err(Error::Data("sink full".into()))
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        // in-order delivery: the sink saw exactly the chunks before the
        // failure, then nothing
        assert_eq!(seen, 3);
    }

    #[test]
    fn fold_indices_partials_cover_range_exactly() {
        for workers in [1usize, 2, 3, 8, 40] {
            let runner = ParallelChunkRunner::new(workers, 1);
            let partials = runner
                .fold_indices(
                    25,
                    |_w| Vec::<usize>::new(),
                    |acc, i| {
                        acc.push(i);
                        Ok(())
                    },
                )
                .unwrap();
            assert!(partials.len() <= workers.max(1));
            // partials are contiguous, in worker order, and cover 0..25
            let flat: Vec<usize> = partials.into_iter().flatten().collect();
            assert_eq!(flat, (0..25).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn fold_indices_propagates_errors() {
        let runner = ParallelChunkRunner::new(4, 1);
        let err = runner
            .fold_indices(
                16,
                |_w| 0u64,
                |acc, i| {
                    if i == 11 {
                        return Err(Error::Data("index 11 exploded".into()));
                    }
                    *acc += i as u64;
                    Ok(())
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("index 11 exploded"), "{err}");
    }

    /// Plan that panics while sampling one chunk — on every attempt.
    struct PanicPlan {
        n: usize,
        panic_at: usize,
    }

    impl ChunkPlan for PanicPlan {
        fn n_chunks(&self) -> usize {
            self.n
        }

        fn sample(&self, index: usize) -> Result<EdgeList> {
            if index == self.panic_at {
                panic!("chunk {index} always panics");
            }
            let mut e = EdgeList::new(PartiteSpec::square(8));
            e.push(index as u64 % 8, 0);
            Ok(e)
        }
    }

    #[test]
    fn injected_faults_recover_bit_identically() {
        use crate::pipeline::fault::FaultPlan;
        let plan = TestPlan { n: 16, per: 80, seed: 21, fail_at: None };
        let (_, clean) = collect(4, &plan).unwrap();
        for workers in [1, 4] {
            let cfg = ChunkConfig {
                workers,
                queue_capacity: 2,
                faults: Some(FaultPlan::transient(77)),
                ..ChunkConfig::default()
            };
            let runner = ParallelChunkRunner::from_config(cfg);
            let mut all = EdgeList::new(PartiteSpec::square(1 << 10));
            runner
                .run(&plan, &mut |c| {
                    all.extend_from(&c.edges);
                    Ok(())
                })
                .unwrap();
            assert_eq!(clean.src, all.src, "workers={workers}");
            assert_eq!(clean.dst, all.dst, "workers={workers}");
        }
    }

    #[test]
    fn persistent_panic_surfaces_as_single_worker_error() {
        let plan = PanicPlan { n: 12, panic_at: 5 };
        for workers in [1, 4] {
            let cfg = ChunkConfig {
                workers,
                queue_capacity: 2,
                retry: crate::pipeline::fault::RetryPolicy::none(),
                ..ChunkConfig::default()
            };
            let err = ParallelChunkRunner::from_config(cfg)
                .run(&plan, &mut |_c| Ok(()))
                .unwrap_err();
            match &err {
                Error::Worker(m) => assert!(m.contains("always panics"), "{m}"),
                other => panic!("wrong error {other:?} (workers={workers})"),
            }
        }
    }

    #[test]
    fn resume_from_skips_completed_prefix() {
        let plan = TestPlan { n: 10, per: 20, seed: 4, fail_at: None };
        for workers in [1, 3] {
            let cfg = ChunkConfig {
                workers,
                queue_capacity: 2,
                resume_from: 4,
                ..ChunkConfig::default()
            };
            let runner = ParallelChunkRunner::from_config(cfg);
            let mut order = Vec::new();
            runner
                .run(&plan, &mut |c| {
                    order.push(c.index);
                    Ok(())
                })
                .unwrap();
            assert_eq!(order, (4..10).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn chunk_range_restriction_samples_only_its_slice() {
        let plan = TestPlan { n: 12, per: 20, seed: 4, fail_at: None };
        for workers in [1, 3] {
            let cfg = ChunkConfig {
                workers,
                queue_capacity: 2,
                resume_from: 3,
                stop_before: Some(8),
                ..ChunkConfig::default()
            };
            let runner = ParallelChunkRunner::from_config(cfg);
            let mut order = Vec::new();
            runner
                .run(&plan, &mut |c| {
                    order.push(c.index);
                    Ok(())
                })
                .unwrap();
            assert_eq!(order, (3..8).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn fold_indices_converts_panics_to_worker_error() {
        let runner = ParallelChunkRunner::new(4, 1);
        let err = runner
            .fold_indices(
                16,
                |_w| (),
                |_acc, i| {
                    if i == 9 {
                        panic!("fold worker died at {i}");
                    }
                    Ok(())
                },
            )
            .unwrap_err();
        match &err {
            Error::Worker(m) => assert!(m.contains("fold worker died"), "{m}"),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn apportion_sums_exactly_and_follows_weights() {
        let w = [0.5, 0.25, 0.125, 0.125];
        let b = apportion(&w, 1_001);
        assert_eq!(b.iter().sum::<u64>(), 1_001);
        assert!(b[0] > b[1] && b[1] > b[2]);
        // degenerate weights: everything lands on the first chunk
        assert_eq!(apportion(&[0.0, 0.0], 7), vec![7, 0]);
        assert_eq!(apportion(&[], 7), Vec::<u64>::new());
    }

    #[test]
    fn apportion_exact_above_f64_integer_precision() {
        // above 2^53 `total as f64` is inexact and the floored budgets
        // can land on either side of the target (off by far more than
        // one unit per chunk near 2^63); the sum must stay exact
        let totals = [
            (1u64 << 54) - 1,
            (1u64 << 54) + 1,
            (1u64 << 63) + 1023,
            u64::MAX - 3,
        ];
        for total in totals {
            let b = apportion(&[1.0], total);
            assert_eq!(b.iter().sum::<u64>(), total);
            let b = apportion(&[0.4, 0.3, 0.3], total);
            assert_eq!(b.iter().sum::<u64>(), total);
        }
        // floored budgets of exactly 2^63 each: their u64 sum would
        // overflow if the accounting were not u128
        let b = apportion(&[0.5, 0.5], u64::MAX);
        assert_eq!(b.iter().sum::<u64>(), u64::MAX);
    }

    #[test]
    fn split_plan_even_budgets_and_single_chunk_seed() {
        let plan = SplitPlan::even(10, 1, 42, |_i, budget, seed| {
            let mut e = EdgeList::new(PartiteSpec::square(4));
            e.push(budget, seed);
            Ok(e)
        });
        assert_eq!(plan.n_chunks(), 4);
        let budgets: Vec<u64> = (0..4).map(|i| plan.sample(i).unwrap().src[0]).collect();
        assert_eq!(budgets.iter().sum::<u64>(), 10);
        // single-chunk plans degenerate to the raw seed
        let one = SplitPlan::even(10, 0, 42, |_i, _b, seed| {
            let mut e = EdgeList::new(PartiteSpec::square(4));
            e.push(seed, seed);
            Ok(e)
        });
        assert_eq!(one.n_chunks(), 1);
        assert_eq!(one.sample(0).unwrap().src[0], 42);
    }

    #[test]
    fn ownership_taking_sink_sees_identical_output() {
        // a sink that `mem::take`s each chunk's edges (MemorySink-style)
        // must observe the same stream as a borrowing sink, and buffer
        // recycling must never leak edges between chunks
        let plan = TestPlan { n: 23, per: 250, seed: 9, fail_at: None };
        let (_, base) = collect(1, &plan).unwrap();
        for workers in [1, 4] {
            let runner = ParallelChunkRunner::new(workers, 2);
            let mut all = EdgeList::new(PartiteSpec::square(1 << 10));
            let mut lens = Vec::new();
            runner
                .run(&plan, &mut |c| {
                    let owned = std::mem::take(&mut c.edges);
                    lens.push(owned.len());
                    all.extend_from(&owned);
                    Ok(())
                })
                .unwrap();
            assert_eq!(base.src, all.src, "workers={workers}");
            assert_eq!(base.dst, all.dst, "workers={workers}");
            assert!(lens.iter().all(|&l| l == 250), "{lens:?}");
        }
    }

    #[test]
    fn sample_into_default_matches_sample() {
        let plan = TestPlan { n: 4, per: 64, seed: 3, fail_at: None };
        for i in 0..4 {
            // a dirty pre-used buffer must be fully replaced
            let mut out = EdgeList::from_pairs(PartiteSpec::square(2), &[(1, 1)]);
            plan.sample_into(i, &mut out).unwrap();
            let fresh = plan.sample(i).unwrap();
            assert_eq!(out.spec, fresh.spec);
            assert_eq!(out.src, fresh.src);
            assert_eq!(out.dst, fresh.dst);
        }
    }

    #[test]
    fn empty_and_tiny_budgets() {
        // fewer edges than chunks: trailing zero chunks are trimmed
        let plan = SplitPlan::even(3, 2, 7, |_i, budget, _s| {
            let mut e = EdgeList::new(PartiteSpec::square(4));
            for _ in 0..budget {
                e.push(0, 0);
            }
            Ok(e)
        });
        assert_eq!(plan.n_chunks(), 3);
        let runner = ParallelChunkRunner::new(4, 2);
        let mut total = 0usize;
        let got = runner
            .run(&plan, &mut |c| {
                total += c.edges.len();
                Ok(())
            })
            .unwrap();
        assert_eq!(got, 3);
        assert_eq!(total, 3);
    }
}
