//! Bridging datasets into the GNN executors (paper §8.1/§8.4/§8.5).
//!
//! Converts a [`Dataset`] into the padded dense tensors the
//! `gcn_full_*` / `gat_full_*` / `edge_clf_*` artifacts expect, and picks
//! the right node bucket.

use crate::datasets::Dataset;
use crate::error::{Error, Result};
use crate::runtime::gnn_exec::{prepare_dense, DenseGraph};

/// Node buckets compiled into the artifacts (aot.py NODE_NS).
pub const NODE_BUCKETS: &[usize] = &[1024, 4096];

/// Smallest artifact bucket that fits `n` nodes.
pub fn pick_bucket(n: usize) -> Result<usize> {
    NODE_BUCKETS
        .iter()
        .copied()
        .find(|&b| b >= n)
        .ok_or_else(|| Error::Config(format!("{n} nodes exceed largest GNN bucket")))
}

/// Prepare a node-classification task from a dataset with node features
/// and labels (e.g. the Cora stand-in or a Figure 4 synthetic).
pub fn node_task(ds: &Dataset, seed: u64) -> Result<DenseGraph> {
    let nf = ds
        .node_features
        .as_ref()
        .ok_or_else(|| Error::Data(format!("{} has no node features", ds.name)))?;
    let labels = ds
        .node_labels
        .as_ref()
        .ok_or_else(|| Error::Data(format!("{} has no node labels", ds.name)))?;
    let n = ds.edges.n_nodes() as usize;
    let bucket = pick_bucket(n)?;
    // row-major node feature vectors
    let rows: Vec<Vec<f64>> = (0..nf.n_rows()).map(|i| nf.row(i).0).collect();
    prepare_dense(&ds.edges, &rows, labels, bucket, seed)
}

/// Transplant labels/features from an original dataset onto a generated
/// structure of the same node count (pretraining graphs keep the task
/// semantics of the original — paper §8.4).
pub fn node_task_on_structure(
    original: &Dataset,
    structure: &crate::graph::EdgeList,
    seed: u64,
) -> Result<DenseGraph> {
    let mut ds = original.clone();
    ds.edges = structure.clone();
    node_task(&ds, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(pick_bucket(500).unwrap(), 1024);
        assert_eq!(pick_bucket(1024).unwrap(), 1024);
        assert_eq!(pick_bucket(2708).unwrap(), 4096);
        assert!(pick_bucket(100_000).is_err());
    }

    #[test]
    fn cora_task_shapes() {
        let ds = crate::datasets::load("cora", 1).unwrap();
        let g = node_task(&ds, 2).unwrap();
        assert_eq!(g.n, 4096);
        assert_eq!(g.n_real, 2708);
        assert_eq!(g.x.len(), 4096 * crate::runtime::gnn_exec::FEAT);
        // masks only over real nodes
        let t: f32 = g.train_mask.iter().sum();
        let v: f32 = g.val_mask.iter().sum();
        assert_eq!((t + v) as usize, 2708);
        // adjacency symmetric + self loops
        assert_eq!(g.a_mask[0], 1.0);
    }
}
