//! Crate-wide error type.

/// Unified error type for the SGG framework.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// I/O failure (dataset files, artifact files, output shards).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// XLA / PJRT runtime failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// An artifact referenced by the runtime is missing on disk.
    #[error("missing artifact `{0}` — run `make artifacts` first")]
    MissingArtifact(String),

    /// Configuration / CLI argument problem.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed input data (dataset schema mismatch, parse failure, ...).
    #[error("data error: {0}")]
    Data(String),

    /// A model was used before it was fitted.
    #[error("model not fitted: {0}")]
    NotFitted(String),

    /// Numerical failure (non-convergence, singular matrix, ...).
    #[error("numeric error: {0}")]
    Numeric(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
