//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline build has no `thiserror`).

use crate::xla;

/// Unified error type for the SGG framework.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (dataset files, artifact files, output shards).
    Io(std::io::Error),

    /// XLA / PJRT runtime failure.
    Xla(String),

    /// An artifact referenced by the runtime is missing on disk.
    MissingArtifact(String),

    /// Configuration / CLI argument / scenario-spec problem.
    Config(String),

    /// Malformed input data (dataset schema mismatch, parse failure, ...).
    Data(String),

    /// A model was used before it was fitted.
    NotFitted(String),

    /// Numerical failure (non-convergence, singular matrix, ...).
    Numeric(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::MissingArtifact(m) => {
                write!(f, "missing artifact `{m}` — run `make artifacts` first")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::NotFitted(m) => write!(f, "model not fitted: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_format() {
        assert_eq!(Error::Config("bad".into()).to_string(), "config error: bad");
        assert_eq!(Error::Data("x".into()).to_string(), "data error: x");
        assert_eq!(
            Error::MissingArtifact("gan".into()).to_string(),
            "missing artifact `gan` — run `make artifacts` first"
        );
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
