//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline build has no `thiserror`), plus the transient/fatal
//! classification the retry layer (`pipeline::fault`) is built on.

use crate::xla;
use std::path::PathBuf;

/// Unified error type for the SGG framework.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (dataset files, artifact files, output shards).
    Io(std::io::Error),

    /// Shard-level I/O failure with file and byte-offset context, so a
    /// failed shard in a thousand-shard run is identifiable from the
    /// message alone.
    ShardIo {
        /// The shard file being read or written.
        path: PathBuf,
        /// Byte offset within the file where the operation failed.
        offset: u64,
        /// The underlying I/O error.
        source: std::io::Error,
    },

    /// A pool worker died (panic, or an injected fault that exhausted its
    /// retry budget). Always fatal: the pool drains and the run aborts.
    Worker(String),

    /// XLA / PJRT runtime failure.
    Xla(String),

    /// An artifact referenced by the runtime is missing on disk.
    MissingArtifact(String),

    /// Configuration / CLI argument / scenario-spec problem.
    Config(String),

    /// Malformed input data (dataset schema mismatch, parse failure, ...).
    Data(String),

    /// A model was used before it was fitted.
    NotFitted(String),

    /// Numerical failure (non-convergence, singular matrix, ...).
    Numeric(String),
}

impl Error {
    /// Transient errors are worth a bounded retry (the operation may
    /// succeed unchanged on a later attempt); everything else is fatal
    /// and aborts the run. Only interrupted/timed-out style I/O kinds
    /// qualify — an `UnexpectedEof` is data corruption (a truncated
    /// shard), not a blip, and must surface immediately.
    pub fn is_transient(&self) -> bool {
        let kind = match self {
            Error::Io(e) => e.kind(),
            Error::ShardIo { source, .. } => source.kind(),
            _ => return false,
        };
        matches!(
            kind,
            std::io::ErrorKind::Interrupted
                | std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
        )
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::ShardIo { path, offset, source } => {
                write!(f, "shard io error: {} at byte {offset}: {source}", path.display())
            }
            Error::Worker(m) => write!(f, "worker failure: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::MissingArtifact(m) => {
                write!(f, "missing artifact `{m}` — run `make artifacts` first")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::NotFitted(m) => write!(f, "model not fitted: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::ShardIo { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_format() {
        assert_eq!(Error::Config("bad".into()).to_string(), "config error: bad");
        assert_eq!(Error::Data("x".into()).to_string(), "data error: x");
        assert_eq!(
            Error::MissingArtifact("gan".into()).to_string(),
            "missing artifact `gan` — run `make artifacts` first"
        );
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn shard_io_carries_path_and_offset() {
        let e = Error::ShardIo {
            path: PathBuf::from("/tmp/out/shard-00042.sgg"),
            offset: 1057,
            source: std::io::Error::new(std::io::ErrorKind::Other, "disk gone"),
        };
        let msg = e.to_string();
        assert!(msg.contains("shard-00042.sgg"), "{msg}");
        assert!(msg.contains("byte 1057"), "{msg}");
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn transient_classification() {
        let transient = Error::Io(std::io::Error::new(std::io::ErrorKind::Interrupted, "x"));
        assert!(transient.is_transient());
        let transient = Error::ShardIo {
            path: PathBuf::from("s"),
            offset: 0,
            source: std::io::Error::new(std::io::ErrorKind::TimedOut, "x"),
        };
        assert!(transient.is_transient());
        // truncation is corruption, not a blip
        let eof = Error::Io(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "x"));
        assert!(!eof.is_transient());
        assert!(!Error::Data("x".into()).is_transient());
        assert!(!Error::Worker("x".into()).is_transient());
    }
}
