//! Paper Figure 7 (§8.12): DCC coefficient (eq. 20) across scale factors
//! −3…+3 (N scaled by 2^k, E by 4^k) — ours vs ER, on Tabformer and
//! IEEE-Fraud stand-ins.

use super::{print_table, save};
use crate::metrics::degree::dcc_profiles;
use crate::metrics::DegreeProfile;
use crate::structgen::erdos_renyi::ErdosRenyi;
use crate::structgen::fit::fit_kronecker;
use crate::structgen::StructureGenerator;
use crate::util::json::Json;
use crate::Result;

/// Regenerate Figure 7 (DCC coefficient across scales); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let datasets = if quick { vec!["ieee-fraud"] } else { vec!["tabformer", "ieee-fraud"] };
    let factors: Vec<i32> = if quick { vec![-2, 0, 2] } else { vec![-3, -2, -1, 0, 1, 2, 3] };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for name in &datasets {
        let ds = crate::datasets::load(name, 1)?;
        // one original profile shared by every (factor, generator) DCC
        let orig = DegreeProfile::of(&ds.edges);
        let ours = fit_kronecker(&ds.edges);
        let er = ErdosRenyi::fit(&ds.edges);
        for &k in &factors {
            let shift = |x: u64, k: i32| -> u64 {
                if k >= 0 {
                    (x << k).max(1)
                } else {
                    (x >> (-k)).max(1)
                }
            };
            let n_src = shift(ds.edges.spec.n_src, k);
            let n_dst = shift(ds.edges.spec.n_dst, k);
            let e = shift(shift(ds.edges.len() as u64, k), k);
            let g_ours = ours.generate_sized(n_src, n_dst, e, 31)?;
            let g_er = er.generate_sized(n_src, n_dst, e, 31)?;
            let d_ours = dcc_profiles(&orig, &DegreeProfile::of(&g_ours), 16);
            let d_er = dcc_profiles(&orig, &DegreeProfile::of(&g_er), 16);
            rows.push(vec![
                name.to_string(),
                format!("{k:+}"),
                format!("{d_ours:.4}"),
                format!("{d_er:.4}"),
            ]);
            records.push(Json::obj(vec![
                ("dataset", Json::from(*name)),
                ("factor", Json::from(k as i64)),
                ("dcc_ours", Json::Num(d_ours)),
                ("dcc_er", Json::Num(d_er)),
            ]));
        }
    }
    print_table(
        "Figure 7: DCC vs scale factor (paper: ours ('propper') above ER at every factor)",
        &["dataset", "2^k", "DCC ours^", "DCC ER^"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("figure7")), ("rows", Json::Arr(records))]);
    save("figure7", &record)?;
    Ok(record)
}
