//! Paper Table 5: the three quality metrics across generation scales
//! 1/2/4/8 (nodes linear, edges quadratic to preserve density).

use super::{print_table, save};
use crate::metrics;
use crate::pipeline::Pipeline;
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 5 (scale sweep); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let datasets: Vec<&str> = if quick {
        vec!["ieee-fraud", "travel-insurance"]
    } else {
        vec!["tabformer", "ieee-fraud", "paysim", "home-credit", "travel-insurance", "ogbn-mag-mini"]
    };
    let scales: Vec<u64> = if quick { vec![1, 2] } else { vec![1, 2, 4, 8] };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for name in &datasets {
        let ds = crate::datasets::load(name, 1)?;
        // one original profile shared by every scale's score
        let evaluator = metrics::Evaluator::new(&ds.edges, &ds.edge_features);
        let fitted = Pipeline::builder().no_node_features().fit(&ds)?;
        for &s in &scales {
            let synth = fitted.generate(s, 11 + s)?;
            let r = evaluator.score(&synth.edges, &synth.edge_features);
            rows.push(vec![
                name.to_string(),
                format!("{s}"),
                format!("{:.4}", r.degree_dist),
                format!("{:.4}", r.feature_corr),
                format!("{:.4}", r.degree_feat_dist),
            ]);
            records.push(Json::obj(vec![
                ("dataset", Json::from(*name)),
                ("scale", Json::from(s)),
                ("degree_dist", Json::Num(r.degree_dist)),
                ("feature_corr", Json::Num(r.feature_corr)),
                ("degree_feat_dist", Json::Num(r.degree_feat_dist)),
            ]));
        }
    }
    print_table(
        "Table 5: metrics across scales (paper: metrics mostly stable as scale grows)",
        &["dataset", "scale", "DegreeDist^", "FeatCorr^", "DegFeatDist_v"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table5")), ("rows", Json::Arr(records))]);
    save("table5", &record)?;
    Ok(record)
}
