//! Paper Figure 8 (§11): generator throughput comparison. We measure our
//! R-MAT implementation single-threaded and chunk-parallel, plus the
//! TrillionG-style and ER generators; the paper's FastSGG/TrillionG/
//! FastKronecker curves were themselves quoted from [41]'s machine, so
//! their published edges/sec constants are reprinted alongside for the
//! shape comparison (who is fastest, rough factors).

use super::{print_table, save};
use crate::graph::PartiteSpec;
use crate::structgen::chunked::{generate_chunked, ChunkConfig};
use crate::structgen::erdos_renyi::ErdosRenyi;
use crate::structgen::fit::fit_kronecker;
use crate::structgen::kronecker::KroneckerGen;
use crate::structgen::theta::ThetaS;
use crate::structgen::trilliong::TrillionG;
use crate::structgen::StructureGenerator;
use crate::util::json::Json;
use crate::Result;

/// Published throughput constants (edges/sec) from the paper's Fig. 8
/// sources (Wang et al. [41], Xeon E5-2630): order-of-magnitude anchors.
pub const PUBLISHED: &[(&str, f64)] = &[
    ("FastSGG (quoted)", 7.0e6),
    ("TrillionG (quoted)", 4.0e6),
    ("FastKronecker (quoted)", 1.5e6),
];

/// Regenerate Figure 8 (generation throughput); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let n: u64 = 1 << 20;
    let sweep: Vec<u64> = if quick {
        vec![1_000_000, 4_000_000]
    } else {
        vec![1_000_000, 4_000_000, 16_000_000, 64_000_000]
    };
    let spec = PartiteSpec::square(n);
    let kron = KroneckerGen::new(ThetaS::rmat_default(), spec, 0);
    let fitted = {
        let sample = kron.generate_sized(n, n, 1_000_000, 1)?;
        fit_kronecker(&sample)
    };
    let _ = fitted;
    let tg = TrillionG::with_default_seed(spec, 0);
    let er = ErdosRenyi { spec, edges: 0 };

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for &e in &sweep {
        // 1 thread RMAT
        let t0 = std::time::Instant::now();
        kron.generate_sized(n, n, e, 3)?;
        let rmat1 = e as f64 / t0.elapsed().as_secs_f64();
        // parallel chunked RMAT
        let cfg = ChunkConfig::default();
        let t0 = std::time::Instant::now();
        generate_chunked(&kron, n, n, e, 3, cfg, |_c| Ok(()))?;
        let rmat_par = e as f64 / t0.elapsed().as_secs_f64();
        // TrillionG-style
        let t0 = std::time::Instant::now();
        tg.generate_sized(n, n, e, 3)?;
        let tg_rate = e as f64 / t0.elapsed().as_secs_f64();
        // ER
        let t0 = std::time::Instant::now();
        er.generate_sized(n, n, e, 3)?;
        let er_rate = e as f64 / t0.elapsed().as_secs_f64();
        rows.push(vec![
            format!("{e}"),
            format!("{:.1}", rmat1 / 1e6),
            format!("{:.1}", rmat_par / 1e6),
            format!("{:.1}", tg_rate / 1e6),
            format!("{:.1}", er_rate / 1e6),
        ]);
        records.push(Json::obj(vec![
            ("edges", Json::from(e)),
            ("rmat_1thread_eps", Json::Num(rmat1)),
            ("rmat_parallel_eps", Json::Num(rmat_par)),
            ("trilliong_eps", Json::Num(tg_rate)),
            ("er_eps", Json::Num(er_rate)),
        ]));
    }
    print_table(
        "Figure 8: generator throughput in Medges/s (paper: our RMAT tops every competitor)",
        &["edges", "RMAT-1t", "RMAT-par", "TrillionG-style", "ER"],
        &rows,
    );
    println!("published anchors (from [41]'s machine):");
    for (name, eps) in PUBLISHED {
        println!("  {name:<24} {:.1} Medges/s", eps / 1e6);
    }
    let record = Json::obj(vec![
        ("experiment", Json::from("figure8")),
        ("rows", Json::Arr(records)),
        (
            "published",
            Json::Arr(
                PUBLISHED
                    .iter()
                    .map(|(n, e)| Json::obj(vec![("name", Json::from(*n)), ("eps", Json::Num(*e))]))
                    .collect(),
            ),
        ),
    ]);
    save("figure8", &record)?;
    Ok(record)
}
