//! Experiment harnesses — one module per table/figure of the paper's
//! evaluation (see DESIGN.md's experiment index). Each `run(quick)`
//! prints the same rows/series the paper reports and returns a Json
//! record that EXPERIMENTS.md summarizes. `quick=true` shrinks sweep
//! sizes for CI-class machines; shapes (who wins, rough factors) are
//! preserved.

pub mod figure2;
pub mod figure4;
pub mod figure5;
pub mod figure6;
pub mod figure7;
pub mod figure8;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod table9;
pub mod table10;

use crate::util::json::Json;
use crate::Result;

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table2", "figure2", "table3", "table4", "table5", "table6", "table7",
    "figure4", "table8", "table9", "table10", "figure5", "figure6",
    "figure7", "figure8",
];

/// Run one experiment by id.
pub fn run(id: &str, quick: bool) -> Result<Json> {
    match id {
        "table2" => table2::run(quick),
        "figure2" => figure2::run(quick),
        "table3" => table3::run(quick),
        "table4" => table4::run(quick),
        "table5" => table5::run(quick),
        "table6" => table6::run(quick),
        "table7" => table7::run(quick),
        "figure4" => figure4::run(quick),
        "table8" => table8::run(quick),
        "table9" => table9::run(quick),
        "table10" => table10::run(quick),
        "figure5" => figure5::run(quick),
        "figure6" => figure6::run(quick),
        "figure7" => figure7::run(quick),
        "figure8" => figure8::run(quick),
        other => Err(crate::Error::Config(format!(
            "unknown experiment `{other}`; known: {ALL:?}"
        ))),
    }
}

/// Save an experiment record under results/.
pub fn save(id: &str, record: &Json) -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{id}.json"));
    std::fs::write(&path, record.to_string())?;
    Ok(path)
}

/// Fixed-width table printer shared by the harnesses.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// Shrink an edge count in quick mode.
pub fn scaled_edges(e: usize, quick: bool) -> usize {
    if quick {
        (e / 4).max(2_000)
    } else {
        e
    }
}
