//! Paper Table 7: pretraining on synthetic + fine-tuning on the original
//! vs training from scratch — node classification (Cora stand-in) and
//! edge classification (IEEE-Fraud stand-in). Requires artifacts.

use super::{print_table, save};
use crate::gnn::node_task_on_structure;
use crate::pipeline::Pipeline;
use crate::runtime::gnn_exec::{EdgeClfRunner, GnnKind, NodeClfRunner};
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 7 (synthetic pretraining + fine-tuning); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    if !crate::runtime::artifacts_available() {
        println!("table7: artifacts missing — run `make artifacts` first (skipped)");
        return Ok(Json::obj(vec![("experiment", Json::from("table7")), ("skipped", Json::from(true))]));
    }
    let rt = crate::runtime::global()?;
    let pre_epochs = if quick { 10 } else { 60 };
    let fine_epochs = if quick { 20 } else { 140 };
    let mut rows = Vec::new();
    let mut records = Vec::new();

    // --- node classification on Cora stand-in ---
    let cora = crate::datasets::load("cora", 1)?;
    let real_task = node_task_on_structure(&cora, &cora.edges, 5)?;
    let synth_structs: Vec<(&str, Option<crate::graph::EdgeList>)> = vec![
        ("no-pretraining", None),
        (
            "random",
            Some(
                Pipeline::builder()
                    .structure("erdos-renyi")
                    .no_node_features()
                    .fit(&cora)?
                    .generate(1, 3)?
                    .edges,
            ),
        ),
        (
            "ours",
            Some(
                Pipeline::builder()
                    .no_node_features()
                    .fit(&cora)?
                    .generate(1, 3)?
                    .edges,
            ),
        ),
    ];
    for kind in [GnnKind::Gcn, GnnKind::Gat] {
        for (gen_name, structure) in &synth_structs {
            let mut runner = NodeClfRunner::new(rt.clone(), kind, real_task.n)?;
            if let Some(edges) = structure {
                // pretrain on the synthetic structure with transplanted
                // labels/features (paper §8.4), then fine-tune on real
                let pre = node_task_on_structure(&cora, edges, 7)?;
                runner.train(&pre, pre_epochs, 0.01, 0)?;
            }
            let res = runner.train(&real_task, fine_epochs, 0.01, 10)?;
            rows.push(vec![
                "cora".into(),
                gen_name.to_string(),
                kind.name().to_uppercase(),
                format!("{:.4}", res.val_acc),
            ]);
            records.push(Json::obj(vec![
                ("dataset", Json::from("cora")),
                ("generator", Json::from(*gen_name)),
                ("model", Json::from(kind.name())),
                ("accuracy", Json::Num(res.val_acc as f64)),
            ]));
        }
    }

    // --- edge classification on IEEE-Fraud stand-in ---
    let ieee = crate::datasets::load("ieee-fraud", 1)?;
    let mut edge_runner = EdgeClfRunner::new(rt.clone())?;
    let labels = ieee.edge_labels.clone().unwrap();
    let real_edge_task = edge_runner.prepare(&ieee.edges, &ieee.edge_features, &labels, 5)?;
    for (gen_name, pretrain) in [("no-pretraining", false), ("random", true), ("ours", true)] {
        edge_runner.reset()?;
        if pretrain {
            let backend = if gen_name == "ours" { "kronecker" } else { "erdos-renyi" };
            let synth = Pipeline::builder()
                .structure(backend)
                .no_node_features()
                .fit(&ieee)?
                .generate(1, 9)?;
            // transplanted labels onto the synthetic structure
            let task = edge_runner.prepare(&synth.edges, &synth.edge_features, &labels, 7)?;
            edge_runner.train(&task, pre_epochs, 0.01)?;
        }
        let res = edge_runner.train(&real_edge_task, fine_epochs.min(60), 0.01)?;
        rows.push(vec![
            "ieee-fraud".into(),
            gen_name.to_string(),
            "GCN-edge".into(),
            format!("{:.4}", res.val_acc),
        ]);
        records.push(Json::obj(vec![
            ("dataset", Json::from("ieee-fraud")),
            ("generator", Json::from(gen_name)),
            ("model", Json::from("gcn-edge")),
            ("accuracy", Json::Num(res.val_acc as f64)),
        ]));
    }
    print_table(
        "Table 7: pretrain on synthetic → finetune (paper: ours ≥ no-pretraining ≥ random)",
        &["dataset", "generator", "model", "accuracy^"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table7")), ("rows", Json::Arr(records))]);
    save("table7", &record)?;
    Ok(record)
}
