//! Paper Table 8 (§8.6): Erdős–Rényi generation timings — nodes fixed,
//! edges swept upward (paper: 100e6 nodes, up to 1e12 edges on 8×V100;
//! here CPU-scaled). The claim: generation time is linear in E.

use super::{print_table, save};
use crate::graph::PartiteSpec;
use crate::structgen::erdos_renyi::ErdosRenyi;
use crate::structgen::StructureGenerator;
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 8 (Erdos-Renyi generation timings); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let nodes: u64 = 1_000_000;
    let edge_sweep: Vec<u64> = if quick {
        vec![1_000_000, 2_500_000, 5_000_000]
    } else {
        vec![5_000_000, 12_500_000, 25_000_000, 37_500_000, 50_000_000]
    };
    let gen = ErdosRenyi { spec: PartiteSpec::square(nodes), edges: 0 };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for &e in &edge_sweep {
        let t0 = std::time::Instant::now();
        let g = gen.generate_sized(nodes, nodes, e, 3)?;
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(g.len() as u64, e);
        rows.push(vec![
            format!("{nodes}"),
            format!("{e}"),
            format!("{secs:.2}s"),
            format!("{:.1}", e as f64 / secs / 1e6),
        ]);
        records.push(Json::obj(vec![
            ("nodes", Json::from(nodes)),
            ("edges", Json::from(e)),
            ("secs", Json::Num(secs)),
            ("medges_per_sec", Json::Num(e as f64 / secs / 1e6)),
        ]));
    }
    print_table(
        "Table 8: ER generation timings, fixed nodes (paper: time linear in edges)",
        &["nodes", "edges", "time", "Medges/s"],
        &rows,
    );
    if records.len() >= 2 {
        let t0 = records[0].get("secs").unwrap().as_f64().unwrap();
        let tn = records.last().unwrap().get("secs").unwrap().as_f64().unwrap();
        let e0 = records[0].get("edges").unwrap().as_f64().unwrap();
        let en = records.last().unwrap().get("edges").unwrap().as_f64().unwrap();
        println!(
            "scaling exponent: {:.2} (1.0 = linear)",
            (tn / t0.max(1e-9)).ln() / (en / e0).ln()
        );
    }
    let record = Json::obj(vec![("experiment", Json::from("table8")), ("rows", Json::Arr(records))]);
    save("table8", &record)?;
    Ok(record)
}
