//! Paper Table 9 (§8.7): aligner structural-feature ablation —
//! node2vec only vs degrees+pagerank+katz vs all-of-the-above, scored by
//! Degree-Feat Dist-Dist over 5 trials.

use super::{print_table, save};
use crate::aligner::node2vec::Node2VecConfig;
use crate::aligner::ranking::{LearnedAligner, Target};
use crate::aligner::StructFeatConfig;
use crate::metrics::Evaluator;
use crate::pipeline::Pipeline;
use crate::util::json::Json;
use crate::util::stats;
use crate::Result;

fn feature_sets(quick: bool) -> Vec<(&'static str, StructFeatConfig)> {
    let n2v = Node2VecConfig {
        dim: 8,
        walks_per_node: if quick { 2 } else { 4 },
        epochs: 1,
        ..Default::default()
    };
    vec![
        (
            "node2vec",
            StructFeatConfig {
                degrees: false,
                pagerank: false,
                katz: false,
                clustering: false,
                node2vec: Some(n2v.clone()),
                iterations: 20,
            },
        ),
        ("deg+pr+katz", StructFeatConfig::default()),
        (
            "deg+pr+katz+n2v",
            StructFeatConfig { node2vec: Some(n2v), ..Default::default() },
        ),
    ]
}

/// Regenerate Table 9 (structural-feature ablation); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("ieee-fraud", 1)?;
    let trials: u64 = if quick { 2 } else { 5 };
    // one fitted structure+features pipeline; only the aligner varies
    let fitted = Pipeline::builder()
        .aligner("random")
        .no_node_features()
        .fit(&ds)?;

    let mut rows = Vec::new();
    let mut records = Vec::new();
    // the original's degree profile is shared across every feature set
    // and trial instead of being re-derived per score
    let evaluator = Evaluator::new(&ds.edges, &ds.edge_features);
    for (name, feat_cfg) in feature_sets(quick) {
        let aligner = LearnedAligner::fit(
            &ds.edges,
            &ds.edge_features,
            Target::Edges,
            feat_cfg,
            &crate::aligner::gbt::GbtConfig::fast(),
        )?;
        let mut scores = Vec::new();
        for trial in 0..trials {
            let synth = fitted.generate(1, 100 + trial)?;
            let aligned = aligner.align(&synth.edges, &synth.edge_features, trial)?;
            scores.push(evaluator.degree_feature_distance(&synth.edges, &aligned));
        }
        let avg = stats::mean(&scores);
        let sd = stats::std_dev(&scores);
        rows.push(vec![name.to_string(), format!("{avg:.4}"), format!("±{sd:.4}")]);
        records.push(Json::obj(vec![
            ("features", Json::from(name)),
            ("avg", Json::Num(avg)),
            ("std", Json::Num(sd)),
        ]));
    }
    print_table(
        "Table 9: aligner structural features (paper: deg+pr+katz slightly beats node2vec)",
        &["features", "DegFeatDist_v avg", "std"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table9")), ("rows", Json::Arr(records))]);
    save("table9", &record)?;
    Ok(record)
}
