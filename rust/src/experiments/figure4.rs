//! Paper Figure 4 (§8.5): when do structure, features, and alignment
//! matter? Controlled synthetics with high/low homophily × high/low SNR;
//! GAT (structure+features, via artifacts) vs GBT feature-only model.
//! Falls back to the GBT-only comparison when artifacts are missing.

use super::{print_table, save};
use crate::aligner::gbt::{GbtClassifier, GbtConfig};
use crate::datasets::synth::homophily_snr;
use crate::gnn::node_task;
use crate::runtime::gnn_exec::{GnnKind, NodeClfRunner};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::Result;

/// Feature-only baseline: GBT on node features (the paper's XGBoost arm).
fn gbt_accuracy(ds: &crate::datasets::Dataset, seed: u64) -> f64 {
    let nf = ds.node_features.as_ref().unwrap();
    let labels = ds.node_labels.as_ref().unwrap();
    let n = nf.n_rows();
    let d = nf.n_cols();
    let mut x = Vec::with_capacity(n * d);
    for i in 0..n {
        x.extend(nf.row(i).0);
    }
    let mut rng = Pcg64::new(seed);
    let train: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
    let xtr: Vec<f64> = (0..n).filter(|&i| train[i]).flat_map(|i| x[i * d..(i + 1) * d].to_vec()).collect();
    let ytr: Vec<u32> = (0..n).filter(|&i| train[i]).map(|i| labels[i]).collect();
    let k = labels.iter().copied().max().unwrap_or(0) + 1;
    let m = GbtClassifier::fit(&xtr, &ytr, d, k, &GbtConfig::fast());
    let xte: Vec<f64> = (0..n).filter(|&i| !train[i]).flat_map(|i| x[i * d..(i + 1) * d].to_vec()).collect();
    let yte: Vec<u32> = (0..n).filter(|&i| !train[i]).map(|i| labels[i]).collect();
    let pred = m.predict(&xte, yte.len());
    pred.iter().zip(&yte).filter(|(a, b)| a == b).count() as f64 / yte.len().max(1) as f64
}

/// Regenerate Figure 4 (which component matters when); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let settings = [
        ("H^ SNR^", 0.85, 1.5),
        ("H^ SNRv", 0.85, 0.5),
        ("Hv SNR^", 0.15, 1.5),
        ("Hv SNRv", 0.15, 0.5),
    ];
    let have_rt = crate::runtime::artifacts_available();
    let rt = if have_rt { Some(crate::runtime::global()?) } else { None };
    let epochs = if quick { 20 } else { 80 };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for (name, h, snr) in settings {
        let ds = homophily_snr(h, snr, 4, 11);
        let gbt_acc = gbt_accuracy(&ds, 3);
        let gat_acc = if let Some(rt) = &rt {
            let task = node_task(&ds, 5)?;
            let mut runner = NodeClfRunner::new(rt.clone(), GnnKind::Gat, task.n)?;
            runner.train(&task, epochs, 0.01, 10)?.val_acc as f64
        } else {
            f64::NAN
        };
        rows.push(vec![
            name.to_string(),
            format!("{h:.2}"),
            format!("{snr:.1}"),
            format!("{gat_acc:.3}"),
            format!("{gbt_acc:.3}"),
        ]);
        records.push(Json::obj(vec![
            ("setting", Json::from(name)),
            ("homophily", Json::Num(h)),
            ("snr", Json::Num(snr)),
            ("gat_acc", Json::Num(gat_acc)),
            ("xgboost_acc", Json::Num(gbt_acc)),
        ]));
    }
    print_table(
        "Figure 4: GAT (struct+feat) vs XGBoost (feat-only) across homophily/SNR \
         (paper: GAT wins when H^; feature-only wins when Hv)",
        &["setting", "homophily", "snr", "GAT", "XGBoost"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("figure4")), ("rows", Json::Arr(records))]);
    save("figure4", &record)?;
    Ok(record)
}
