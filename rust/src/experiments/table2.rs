//! Paper Table 2: Degree Dist ↑ / Feature Corr ↑ / Degree-Feat Dist-Dist ↓
//! for {random, graphworld, ours} on {Tabformer, IEEE-Fraud, Credit,
//! Paysim} stand-ins.

use super::{print_table, save};
use crate::metrics;
use crate::pipeline::{Pipeline, PipelineBuilder};
use crate::util::json::Json;
use crate::Result;

/// The three method arms of Table 2, as registry-backed builders. The
/// node-feature leg is off: Table 2 scores edge metrics only.
pub fn methods() -> Vec<(&'static str, PipelineBuilder)> {
    vec![
        (
            "random",
            Pipeline::builder()
                .structure("erdos-renyi")
                .edge_features("random")
                .aligner("random")
                .no_node_features(),
        ),
        (
            "graphworld",
            Pipeline::builder()
                .structure("sbm")
                .edge_features("gaussian")
                .aligner("random")
                .no_node_features(),
        ),
        (
            "ours",
            Pipeline::builder()
                .structure("kronecker")
                .edge_features("kde")
                .aligner("learned")
                .no_node_features(),
        ),
    ]
}

/// Evaluate one (dataset, method) cell against a shared [`metrics::Evaluator`]
/// (the original's degree/association profiles are derived once per
/// dataset, not once per cell).
pub fn evaluate_cell(
    ds: &crate::datasets::Dataset,
    evaluator: &metrics::Evaluator<'_>,
    builder: &PipelineBuilder,
    seed: u64,
) -> Result<metrics::QualityReport> {
    let fitted = builder.fit(ds)?;
    let synth = fitted.generate(1, seed)?;
    Ok(evaluator.score(&synth.edges, &synth.edge_features))
}

/// Regenerate Table 2 (fidelity metrics per dataset); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let datasets = if quick {
        vec!["tabformer", "ieee-fraud"]
    } else {
        vec!["tabformer", "ieee-fraud", "credit", "paysim"]
    };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for name in &datasets {
        let ds = crate::datasets::load(name, 1)?;
        let evaluator = metrics::Evaluator::new(&ds.edges, &ds.edge_features);
        for (method, cfg) in methods() {
            let r = evaluate_cell(&ds, &evaluator, &cfg, 42)?;
            rows.push(vec![
                name.to_string(),
                method.to_string(),
                format!("{:.4}", r.degree_dist),
                format!("{:.4}", r.feature_corr),
                format!("{:.4}", r.degree_feat_dist),
            ]);
            records.push(Json::obj(vec![
                ("dataset", Json::from(*name)),
                ("method", Json::from(method)),
                ("degree_dist", Json::Num(r.degree_dist)),
                ("feature_corr", Json::Num(r.feature_corr)),
                ("degree_feat_dist", Json::Num(r.degree_feat_dist)),
            ]));
        }
    }
    print_table(
        "Table 2: quality vs baselines (paper: ours wins every column)",
        &["dataset", "method", "DegreeDist^", "FeatCorr^", "DegFeatDist_v"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table2")), ("rows", Json::Arr(records))]);
    save("table2", &record)?;
    Ok(record)
}
