//! Paper Figure 5 (§8.9): degree-distribution × feature-distribution
//! heat maps for original / ours / random / graphworld on IEEE-Fraud.
//! Renders ASCII heat maps and records the normalized matrices.

use super::save;
use crate::metrics::joint::heatmap_from;
use crate::metrics::DegreeProfile;
use crate::util::json::Json;
use crate::Result;

fn render(h: &[f64], rows: usize, cols: usize) -> String {
    let max = h.iter().copied().fold(0.0f64, f64::max).max(1e-12);
    let ramp = [' ', '.', ':', '+', '*', '#', '@'];
    let mut out = String::new();
    for r in (0..rows).rev() {
        for c in 0..cols {
            let t = (h[r * cols + c] / max * (ramp.len() - 1) as f64).round() as usize;
            out.push(ramp[t.min(ramp.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

/// Regenerate Figure 5 (degree x feature distribution grids); `quick` shrinks the sweep.
pub fn run(_quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("ieee-fraud", 1)?;
    let mut variants: Vec<(String, crate::datasets::Dataset)> =
        vec![("original".into(), ds.clone())];
    for (method, builder) in super::table2::methods() {
        variants.push((method.to_string(), builder.fit(&ds)?.generate(1, 13)?));
    }
    let mut records = Vec::new();
    println!("\n=== Figure 5: degree × feature heat maps (rows = degree bins, cols = feature bins) ===");
    for (name, d) in &variants {
        // accumulator path: derive each variant's degree profile once
        let profile = DegreeProfile::of(&d.edges);
        let (h, rows, cols) = heatmap_from(&profile, &d.edges, &d.edge_features)
            .ok_or_else(|| crate::Error::Data("no continuous feature".into()))?;
        println!("\n--- {name} ---\n{}", render(&h, rows, cols));
        records.push(Json::obj(vec![
            ("series", Json::from(name.as_str())),
            ("rows", Json::from(rows)),
            ("cols", Json::from(cols)),
            ("heatmap", Json::from(h)),
        ]));
    }
    println!("(paper: ours's heat map matches original; random/graphworld are uniform in degree)");
    let record = Json::obj(vec![("experiment", Json::from("figure5")), ("maps", Json::Arr(records))]);
    save("figure5", &record)?;
    Ok(record)
}
