//! Paper Table 4: GCN/GAT training throughput on original vs random vs
//! ours (Rel. Timing = 1 − |t_gen − t_orig| / t_orig). Uses the Cora
//! stand-in (node features + labels present) padded into the GNN
//! artifact bucket; epoch time is a full-batch PJRT step measured from
//! Rust. Requires `make artifacts`.

use super::{print_table, save};
use crate::gnn::{node_task, node_task_on_structure};
use crate::pipeline::Pipeline;
use crate::runtime::gnn_exec::{GnnKind, NodeClfRunner};
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 4 (GNN seconds/epoch); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    if !crate::runtime::artifacts_available() {
        println!("table4: artifacts missing — run `make artifacts` first (skipped)");
        return Ok(Json::obj(vec![("experiment", Json::from("table4")), ("skipped", Json::from(true))]));
    }
    let rt = crate::runtime::global()?;
    let ds = crate::datasets::load("cora", 1)?;
    let epochs = if quick { 3 } else { 10 };

    // structures: original + per-method synthetic of the same size
    let mut variants: Vec<(String, crate::graph::EdgeList)> =
        vec![("original".into(), ds.edges.clone())];
    for (name, backend) in [("random", "erdos-renyi"), ("ours", "kronecker")] {
        let synth = Pipeline::builder()
            .structure(backend)
            .no_node_features()
            .fit(&ds)?
            .generate(1, 5)?;
        variants.push((name.to_string(), synth.edges));
    }

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for kind in [GnnKind::Gcn, GnnKind::Gat] {
        let mut t_orig = 0.0f64;
        for (name, edges) in &variants {
            let g = node_task_on_structure(&ds, edges, 3)?;
            let bucket = g.n;
            let mut runner = NodeClfRunner::new(rt.clone(), kind, bucket)?;
            let res = runner.train(&g, epochs, 0.01, 0)?;
            if name == "original" {
                t_orig = res.secs_per_epoch;
            }
            let rel = 1.0 - ((res.secs_per_epoch - t_orig).abs() / t_orig.max(1e-9));
            rows.push(vec![
                kind.name().to_string(),
                name.clone(),
                format!("{:.4}", rel),
                format!("{:.4}s", res.secs_per_epoch),
                format!("{:.3}", res.val_acc),
            ]);
            records.push(Json::obj(vec![
                ("model", Json::from(kind.name())),
                ("method", Json::from(name.as_str())),
                ("rel_timing", Json::Num(rel)),
                ("secs_per_epoch", Json::Num(res.secs_per_epoch)),
                ("val_acc", Json::Num(res.val_acc as f64)),
            ]));
        }
    }
    // silence unused warning when only original measured
    let _ = node_task(&ds, 3);
    print_table(
        "Table 4: GNN epoch throughput, original vs synthetic (paper: ours closer to 1.0 than random)",
        &["model", "method", "RelTiming^", "secs/epoch", "val_acc"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table4")), ("rows", Json::Arr(records))]);
    save("table4", &record)?;
    Ok(record)
}
