//! Paper Table 3: big-graph generation time/memory across scales
//! (MAG240m scaled 1×…10× on 8×V100; here: MAG-mini stand-in on
//! multicore CPU). The claim under test is *linear time in E with
//! constant per-chunk memory* — the harness measures structural + tabular
//! phases separately like the paper and checks the scaling exponent.

use super::{print_table, save};
use crate::featgen::kde::KdeFeatureGen;
use crate::featgen::FeatureGenerator;
use crate::pipeline::orchestrator::stream_to_shards;
use crate::structgen::chunked::ChunkConfig;
use crate::structgen::fit::fit_kronecker;
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 3 (big-graph streaming run); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let scales: Vec<u64> = if quick { vec![1, 2] } else { vec![1, 2, 4, 8] };
    let base = crate::datasets::load("mag-mini", 1)?;
    let gen = fit_kronecker(&base.edges);
    let featgen = KdeFeatureGen::fit(&base.edge_features);
    let cfg = ChunkConfig::default();
    let tmp = std::env::temp_dir().join(format!("sgg_table3_{}", std::process::id()));

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for &s in &scales {
        let n_src = base.edges.spec.n_src * s;
        let n_dst = base.edges.spec.n_dst * s;
        let edges = base.edges.len() as u64 * s * s;
        // structural phase (streamed to shards, bounded memory)
        let report = stream_to_shards(&gen, n_src, n_dst, edges, 7, cfg, &tmp)?;
        // tabular phase: feature rows for a fixed sample rate (the paper
        // generates features per node; we generate per ~edge/8 to keep
        // CPU runtimes in minutes)
        let feat_rows = (edges / 8).max(1) as usize;
        let t0 = std::time::Instant::now();
        let _feats = featgen.sample(feat_rows, 9)?;
        let tab_secs = t0.elapsed().as_secs_f64();
        rows.push(vec![
            format!("{s}x"),
            format!("{}", n_src + n_dst),
            format!("{edges}"),
            format!("{:.2}s", report.wall_secs),
            format!("{:.1}MB", report.peak_buffer_bytes as f64 / 1e6),
            format!("{:.2}s", tab_secs),
            format!("{feat_rows}"),
            format!("{:.2}s", report.wall_secs + tab_secs),
        ]);
        records.push(Json::obj(vec![
            ("scale", Json::from(s)),
            ("nodes", Json::from(n_src + n_dst)),
            ("edges", Json::from(edges)),
            ("struct_secs", Json::Num(report.wall_secs)),
            ("struct_peak_bytes", Json::from(report.peak_buffer_bytes)),
            ("tab_secs", Json::Num(tab_secs)),
            ("total_secs", Json::Num(report.wall_secs + tab_secs)),
        ]));
        std::fs::remove_dir_all(&tmp).ok();
    }
    print_table(
        "Table 3: synthetic MAG generation timings (paper: time ~ edges, memory bounded per chunk)",
        &["scale", "nodes", "edges", "struct_time", "struct_mem", "tab_time", "features", "total"],
        &rows,
    );
    // scaling sanity: time should grow ~linearly in E (paper's large
    // scales are IO/memory bound; we check sub-quadratic growth)
    if records.len() >= 2 {
        let t0 = records[0].get("struct_secs").unwrap().as_f64().unwrap();
        let tn = records.last().unwrap().get("struct_secs").unwrap().as_f64().unwrap();
        let e0 = records[0].get("edges").unwrap().as_f64().unwrap();
        let en = records.last().unwrap().get("edges").unwrap().as_f64().unwrap();
        let exponent = (tn / t0.max(1e-9)).ln() / (en / e0).ln();
        println!("time-vs-edges scaling exponent: {exponent:.2} (1.0 = linear)");
    }
    let record = Json::obj(vec![("experiment", Json::from("table3")), ("rows", Json::Arr(records))]);
    save("table3", &record)?;
    Ok(record)
}
