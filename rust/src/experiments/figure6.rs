//! Paper Figure 6 (§8.10): feature-column CDF comparison — original vs
//! GAN vs KDE vs random on an IEEE-Fraud continuous column.

use super::{print_table, save};
use crate::featgen::gan::GanFeatureGen;
use crate::featgen::kde::KdeFeatureGen;
use crate::featgen::random::RandomFeatureGen;
use crate::featgen::FeatureGenerator;
use crate::util::json::Json;
use crate::util::stats;
use crate::Result;

/// Regenerate Figure 6 (feature-column CDF comparison); `quick` shrinks the sweep.
pub fn run(_quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("ieee-fraud", 1)?;
    let col = "amount"; // the C11-like heavy-tailed column
    let n = ds.edge_features.n_rows();

    let gan: Box<dyn FeatureGenerator> =
        if crate::runtime::artifacts_available() {
            let rt = crate::runtime::global()?;
            let backend = crate::runtime::gan_exec::PjrtGanBackend::new(
                rt,
                crate::runtime::gan_exec::GanTrainConfig { epochs: 3, ..Default::default() },
            )?;
            Box::new(GanFeatureGen::fit_with_backend(&ds.edge_features, Box::new(backend), 3)?)
        } else {
            Box::new(GanFeatureGen::fit_resample(&ds.edge_features, 3)?)
        };
    let generators: Vec<(&str, Box<dyn FeatureGenerator>)> = vec![
        ("gan", gan),
        ("kde", Box::new(KdeFeatureGen::fit(&ds.edge_features))),
        ("random", Box::new(RandomFeatureGen::fit(&ds.edge_features))),
    ];

    // evaluate CDFs on shared quantile grid of the original column
    let orig = ds.edge_features.column(col).unwrap().as_continuous();
    let grid: Vec<f64> = (0..=20).map(|i| stats::quantile(orig, i as f64 / 20.0)).collect();
    let cdf_at = |sample: &[f64]| -> Vec<f64> {
        let mut s: Vec<f64> = sample.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        grid.iter()
            .map(|&g| s.partition_point(|&x| x <= g) as f64 / s.len() as f64)
            .collect()
    };

    let mut rows = vec![vec![
        "original".to_string(),
        cdf_at(orig).iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(","),
        "0.0000".into(),
    ]];
    let mut records = vec![Json::obj(vec![
        ("series", Json::from("original")),
        ("cdf", Json::from(cdf_at(orig))),
    ])];
    let orig_cdf = cdf_at(orig);
    for (name, g) in &generators {
        let synth = g.sample(n, 17)?;
        let vals = synth.column(col).unwrap().as_continuous();
        let cdf = cdf_at(vals);
        let max_gap = cdf
            .iter()
            .zip(&orig_cdf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        rows.push(vec![
            name.to_string(),
            cdf.iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(","),
            format!("{max_gap:.4}"),
        ]);
        records.push(Json::obj(vec![
            ("series", Json::from(*name)),
            ("cdf", Json::from(cdf)),
            ("ks_gap", Json::Num(max_gap)),
        ]));
    }
    print_table(
        "Figure 6: feature CDF on `amount` (paper: fitted GAN tracks original; KS gap column added)",
        &["series", "cdf@orig-quantiles", "KS_gap_v"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("figure6")), ("rows", Json::Arr(records))]);
    save("figure6", &record)?;
    Ok(record)
}
