//! Paper Table 6: component ablation on the IEEE dataset —
//! {ours, TrillionG, Random} × {GAN, KDE, Random} × {xgboost, random}.
//! Runs on the registry API: every arm is just a triple of backend names.

use super::{print_table, save};
use crate::metrics;
use crate::pipeline::Pipeline;
use crate::util::json::Json;
use crate::Result;

/// Regenerate Table 6 (component ablations); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("ieee-fraud", 1)?;
    let structs = [
        ("ours", "kronecker"),
        ("trilliong", "trilliong"),
        ("random", "erdos-renyi"),
    ];
    let feats: Vec<(&str, &str)> = if quick {
        vec![("kde", "kde"), ("random", "random")]
    } else {
        vec![("gan", "gan"), ("kde", "kde"), ("random", "random")]
    };
    let aligns = [("xgboost", "learned"), ("random", "random")];

    let mut rows = Vec::new();
    let mut records = Vec::new();
    // all 12–18 ablation arms score against one shared original profile
    let evaluator = metrics::Evaluator::new(&ds.edges, &ds.edge_features);
    for (s_name, s_backend) in structs {
        for (f_name, f_backend) in &feats {
            for (a_name, a_backend) in aligns {
                let synth = Pipeline::builder()
                    .structure(s_backend)
                    .edge_features(*f_backend)
                    .aligner(a_backend)
                    .no_node_features()
                    .fit(&ds)?
                    .generate(1, 21)?;
                let r = evaluator.score(&synth.edges, &synth.edge_features);
                rows.push(vec![
                    s_name.to_string(),
                    f_name.to_string(),
                    a_name.to_string(),
                    format!("{:.4}", r.degree_dist),
                    format!("{:.4}", r.feature_corr),
                    format!("{:.4}", r.degree_feat_dist),
                ]);
                records.push(Json::obj(vec![
                    ("struct", Json::from(s_name)),
                    ("feat", Json::from(*f_name)),
                    ("align", Json::from(a_name)),
                    ("degree_dist", Json::Num(r.degree_dist)),
                    ("feature_corr", Json::Num(r.feature_corr)),
                    ("degree_feat_dist", Json::Num(r.degree_feat_dist)),
                ]));
            }
        }
    }
    print_table(
        "Table 6: ablation on IEEE (paper: fitted components beat random on their own metric; \
         xgboost aligner lowers DegFeatDist at fixed struct/feat)",
        &["struct", "feat", "aligner", "DegreeDist^", "FeatCorr^", "DegFeatDist_v"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table6")), ("rows", Json::Arr(records))]);
    save("table6", &record)?;
    Ok(record)
}
