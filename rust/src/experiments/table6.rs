//! Paper Table 6: component ablation on the IEEE dataset —
//! {ours, TrillionG, Random} × {GAN, KDE, Random} × {xgboost, random}.

use super::{print_table, save};
use crate::aligner::AlignKind;
use crate::featgen::FeatKind;
use crate::metrics;
use crate::pipeline::{Pipeline, PipelineConfig};
use crate::structgen::StructKind;
use crate::util::json::Json;
use crate::Result;

pub fn run(quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("ieee-fraud", 1)?;
    let structs = [
        ("ours", StructKind::Kronecker),
        ("trilliong", StructKind::TrillionG),
        ("random", StructKind::Random),
    ];
    let feats = if quick {
        vec![("kde", FeatKind::Kde), ("random", FeatKind::Random)]
    } else {
        vec![("gan", FeatKind::Gan), ("kde", FeatKind::Kde), ("random", FeatKind::Random)]
    };
    let aligns = [("xgboost", AlignKind::Learned), ("random", AlignKind::Random)];

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for (s_name, sk) in structs {
        for (f_name, fk) in &feats {
            for (a_name, ak) in aligns {
                let cfg = PipelineConfig {
                    struct_kind: sk,
                    feat_kind: *fk,
                    align_kind: ak,
                    ..Default::default()
                };
                let synth = Pipeline::fit(&ds, &cfg)?.generate(1, 21)?;
                let r = metrics::evaluate(
                    &ds.edges,
                    &ds.edge_features,
                    &synth.edges,
                    &synth.edge_features,
                );
                rows.push(vec![
                    s_name.to_string(),
                    f_name.to_string(),
                    a_name.to_string(),
                    format!("{:.4}", r.degree_dist),
                    format!("{:.4}", r.feature_corr),
                    format!("{:.4}", r.degree_feat_dist),
                ]);
                records.push(Json::obj(vec![
                    ("struct", Json::from(s_name)),
                    ("feat", Json::from(*f_name)),
                    ("align", Json::from(a_name)),
                    ("degree_dist", Json::Num(r.degree_dist)),
                    ("feature_corr", Json::Num(r.feature_corr)),
                    ("degree_feat_dist", Json::Num(r.degree_feat_dist)),
                ]));
            }
        }
    }
    print_table(
        "Table 6: ablation on IEEE (paper: fitted components beat random on their own metric; \
         xgboost aligner lowers DegFeatDist at fixed struct/feat)",
        &["struct", "feat", "aligner", "DegreeDist^", "FeatCorr^", "DegFeatDist_v"],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table6")), ("rows", Json::Arr(records))]);
    save("table6", &record)?;
    Ok(record)
}
