//! Paper Table 10 (§8.8): graph statistics of generated CORA-ML graphs —
//! our generator with/without noise vs the R-MAT-default baseline,
//! plus the original's row. (The paper's NetGAN/DC-SBM/... rows are
//! quoted constants from Bojchevski et al. in the original too; we
//! reprint the original + measure our three generators over 5 trials.)

use super::{print_table, save};
use crate::metrics::graphstats::{compute_vs, GraphStats};
use crate::structgen::fit::fit_kronecker;
use crate::structgen::kronecker::KroneckerGen;
use crate::structgen::theta::ThetaS;
use crate::structgen::StructureGenerator;
use crate::util::json::Json;
use crate::util::stats;
use crate::Result;

fn stat_row(name: &str, stats_list: &[GraphStats]) -> (Vec<String>, Json) {
    let avg = |f: fn(&GraphStats) -> f64| {
        let xs: Vec<f64> = stats_list.iter().map(f).collect();
        (stats::mean(&xs), stats::std_dev(&xs))
    };
    let (md, md_s) = avg(|s| s.max_degree);
    let (asrt, asrt_s) = avg(|s| s.assortativity);
    let (tri, tri_s) = avg(|s| s.triangles as f64);
    let (alpha, alpha_s) = avg(|s| s.power_law_exp);
    let (cc, cc_s) = avg(|s| s.avg_clustering);
    let (wed, _) = avg(|s| s.wedges as f64);
    let (claw, _) = avg(|s| s.claws as f64);
    let (ent, _) = avg(|s| s.rel_edge_entropy);
    let (lcc, _) = avg(|s| s.largest_cc as f64);
    let (gini, _) = avg(|s| s.gini);
    let (eo, _) = avg(|s| s.edge_overlap);
    let (cpl, cpl_s) = avg(|s| s.char_path_len);
    let row = vec![
        name.to_string(),
        format!("{md:.0}±{md_s:.0}"),
        format!("{asrt:+.3}±{asrt_s:.3}"),
        format!("{tri:.0}±{tri_s:.0}"),
        format!("{alpha:.3}±{alpha_s:.3}"),
        format!("{cc:.2e}±{cc_s:.1e}"),
        format!("{wed:.0}"),
        format!("{claw:.2e}"),
        format!("{ent:.3}"),
        format!("{lcc:.0}"),
        format!("{gini:.3}"),
        format!("{:.1}%", eo * 100.0),
        format!("{cpl:.2}±{cpl_s:.2}"),
    ];
    let rec = Json::obj(vec![
        ("method", Json::from(name)),
        ("max_degree", Json::Num(md)),
        ("assortativity", Json::Num(asrt)),
        ("triangles", Json::Num(tri)),
        ("power_law_exp", Json::Num(alpha)),
        ("clustering", Json::Num(cc)),
        ("wedges", Json::Num(wed)),
        ("claws", Json::Num(claw)),
        ("rel_edge_entropy", Json::Num(ent)),
        ("largest_cc", Json::Num(lcc)),
        ("gini", Json::Num(gini)),
        ("edge_overlap", Json::Num(eo)),
        ("char_path_len", Json::Num(cpl)),
    ]);
    (row, rec)
}

/// Regenerate Table 10 (graph statistics); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("cora-ml", 1)?;
    let trials: u64 = if quick { 2 } else { 5 };
    let path_samples = if quick { 32 } else { 128 };
    // the edge-overlap reference set is built once and shared by every
    // row — the original's included
    let reference_keys = ds.edges.edge_keys();
    let original = compute_vs(&ds.edges, &reference_keys, path_samples);

    let fitted = fit_kronecker(&ds.edges);
    let gens: Vec<(&str, KroneckerGen)> = vec![
        (
            "random-rmat",
            KroneckerGen::new(ThetaS::rmat_default(), ds.edges.spec, ds.edges.len() as u64),
        ),
        ("ours-no-noise", fitted.clone()),
        ("ours-noise", fitted.with_noise(0.5)),
    ];

    let mut rows = Vec::new();
    let mut records = Vec::new();
    let (orig_row, orig_rec) = stat_row("CORA-ML (original)", &[original]);
    rows.push(orig_row);
    records.push(orig_rec);
    for (name, gen) in gens {
        let mut all = Vec::new();
        for t in 0..trials {
            let g = gen.generate(1, 50 + t)?;
            all.push(compute_vs(&g, &reference_keys, path_samples));
        }
        let (row, rec) = stat_row(name, &all);
        rows.push(row);
        records.push(rec);
    }
    print_table(
        "Table 10: graph statistics on CORA-ML (paper: noise raises triangles/clustering toward original)",
        &[
            "method", "max_deg", "assort", "triangles", "alpha", "clustering",
            "wedges", "claws", "entropy", "LCC", "gini", "EO", "CPL",
        ],
        &rows,
    );
    let record = Json::obj(vec![("experiment", Json::from("table10")), ("rows", Json::Arr(records))]);
    save("table10", &record)?;
    Ok(record)
}
