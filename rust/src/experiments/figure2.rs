//! Paper Figure 2: degree-distribution curves (left) and hop-plots
//! (right) for original vs {ours, random, graphworld}. Prints the series
//! as text columns (plot-ready) and records them in results/figure2.json.

use super::{print_table, save};
use crate::metrics::degree::log_binned_degree_hist;
use crate::metrics::{hopplot::hop_plot, DegreeProfile};
use crate::util::json::Json;
use crate::Result;

/// Regenerate Figure 2 (degree distributions); `quick` shrinks the sweep.
pub fn run(quick: bool) -> Result<Json> {
    let ds = crate::datasets::load("tabformer", 1)?;
    let mut series: Vec<(String, crate::graph::EdgeList)> =
        vec![("original".into(), ds.edges.clone())];
    for (method, builder) in super::table2::methods() {
        let synth = builder.fit(&ds)?.generate(1, 7)?;
        series.push((method.to_string(), synth.edges));
    }
    let bins = 20;
    let samples = if quick { 32 } else { 128 };

    let mut rows = Vec::new();
    let mut rec_deg = Vec::new();
    let mut rec_hop = Vec::new();
    for (name, edges) in &series {
        // one shared degree profile per series (the accumulator path)
        let profile = DegreeProfile::of(edges);
        let hist = log_binned_degree_hist(profile.out_degrees(), bins);
        let total: f64 = hist.iter().sum::<f64>().max(1.0);
        let hp = hop_plot(edges, samples, 3);
        rows.push(vec![
            name.clone(),
            hist.iter()
                .map(|h| format!("{:.3}", h / total))
                .collect::<Vec<_>>()
                .join(","),
            hp.iter().map(|x| format!("{x:.3}")).collect::<Vec<_>>().join(","),
        ]);
        rec_deg.push(Json::obj(vec![
            ("series", Json::from(name.as_str())),
            ("hist", Json::from(hist.iter().map(|h| h / total).collect::<Vec<f64>>())),
        ]));
        rec_hop.push(Json::obj(vec![
            ("series", Json::from(name.as_str())),
            ("reach", Json::from(hp)),
        ]));
    }
    print_table(
        "Figure 2: degree distribution (log-binned) + hop plot (paper: ours tracks original's tail)",
        &["series", "degree_hist", "hop_plot"],
        &rows,
    );
    let record = Json::obj(vec![
        ("experiment", Json::from("figure2")),
        ("degree", Json::Arr(rec_deg)),
        ("hopplot", Json::Arr(rec_hop)),
    ]);
    save("figure2", &record)?;
    Ok(record)
}
