//! Joint degree×feature distribution distance ("Degree-Feat Dist-Dist ↓",
//! paper §4.3) and the Figure 5 heat-map dump (§8.9).
//!
//! For each edge, take (source degree, feature value); bin degrees
//! logarithmically and features linearly (categoricals by code); the
//! metric is the JS distance between the original's and the synthetic's
//! joint histograms, averaged over feature columns.

use crate::featgen::table::{ColumnData, FeatureTable};
use crate::graph::EdgeList;
use crate::util::stats;

/// Degree (log) bins × feature bins used by the metric.
const DEG_BINS: usize = 12;
const FEAT_BINS: usize = 12;

/// 2-D joint histogram of (src degree, feature) over the edges of a graph.
/// Returns a row-major `DEG_BINS × f_bins` matrix (counts).
pub fn joint_histogram(
    edges: &EdgeList,
    values: &ColumnData,
    max_degree: u32,
    feat_range: (f64, f64),
) -> Vec<f64> {
    let deg = edges.out_degrees();
    let max_d = max_degree.max(1) as f64;
    let f_bins = match values {
        ColumnData::Continuous(_) => FEAT_BINS,
        ColumnData::Categorical { cardinality, .. } => (*cardinality as usize).clamp(1, 64),
    };
    let mut hist = vec![0.0f64; DEG_BINS * f_bins];
    let (lo, hi) = feat_range;
    for (e, (s, _)) in edges.iter().enumerate() {
        let d = deg[s as usize] as f64;
        let td = if max_d <= 1.0 { 0.0 } else { (d.max(1.0)).ln() / max_d.ln() };
        let db = ((td * DEG_BINS as f64) as usize).min(DEG_BINS - 1);
        let fb = match values {
            ColumnData::Continuous(v) => {
                if hi <= lo {
                    0
                } else {
                    let t = (v[e] - lo) / (hi - lo);
                    ((t * FEAT_BINS as f64) as isize).clamp(0, FEAT_BINS as isize - 1) as usize
                }
            }
            ColumnData::Categorical { codes, .. } => (codes[e] as usize).min(f_bins - 1),
        };
        hist[db * f_bins + fb] += 1.0;
    }
    hist
}

/// "Degree-Feat Dist-Dist ↓": JS distance between joint (degree, feature)
/// histograms, averaged over all feature columns. In [0, 1], lower better.
pub fn degree_feature_distance(
    orig_edges: &EdgeList,
    orig_feats: &FeatureTable,
    synth_edges: &EdgeList,
    synth_feats: &FeatureTable,
) -> f64 {
    let k = orig_feats.n_cols();
    if k == 0 || synth_feats.n_cols() != k {
        return 1.0;
    }
    // shared normalization so the two histograms align
    let max_deg = orig_edges
        .out_degrees()
        .iter()
        .chain(synth_edges.out_degrees().iter())
        .copied()
        .max()
        .unwrap_or(1);
    let mut total = 0.0;
    for c in 0..k {
        let range = match (&orig_feats.columns[c].data, &synth_feats.columns[c].data) {
            (ColumnData::Continuous(a), ColumnData::Continuous(b)) => {
                let (lo1, hi1) = stats::min_max(a);
                let (lo2, hi2) = stats::min_max(b);
                (lo1.min(lo2), hi1.max(hi2))
            }
            _ => (0.0, 0.0),
        };
        let ho = joint_histogram(orig_edges, &orig_feats.columns[c].data, max_deg, range);
        let hs = joint_histogram(synth_edges, &synth_feats.columns[c].data, max_deg, range);
        if ho.len() != hs.len() {
            total += 1.0;
            continue;
        }
        total += stats::js_distance(&ho, &hs);
    }
    total / k as f64
}

/// Figure 5 heat map: normalized joint histogram of the first continuous
/// column (rows = degree bins, cols = feature bins).
pub fn heatmap(edges: &EdgeList, feats: &FeatureTable) -> Option<(Vec<f64>, usize, usize)> {
    let col = feats.columns.iter().find(|c| c.is_continuous())?;
    let (lo, hi) = stats::min_max(col.as_continuous());
    let max_deg = edges.out_degrees().iter().copied().max().unwrap_or(1);
    let mut h = joint_histogram(edges, &col.data, max_deg, (lo, hi));
    let total: f64 = h.iter().sum();
    if total > 0.0 {
        for x in h.iter_mut() {
            *x /= total;
        }
    }
    Some((h, DEG_BINS, FEAT_BINS))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::graph::PartiteSpec;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;
    use crate::structgen::StructureGenerator;
    use crate::util::rng::Pcg64;

    /// Edge features correlated (or not) with src degree.
    fn dataset(correlated: bool, seed: u64) -> (EdgeList, FeatureTable) {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 8_000);
        let edges = g.generate(1, seed).unwrap();
        let deg = edges.out_degrees();
        let mut rng = Pcg64::new(seed ^ 0xfeed);
        let vals: Vec<f64> = edges
            .iter()
            .map(|(s, _)| {
                if correlated {
                    (deg[s as usize] as f64).ln() + rng.normal() * 0.2
                } else {
                    rng.normal()
                }
            })
            .collect();
        (edges, FeatureTable::new(vec![Column::continuous("f", vals)]).unwrap())
    }

    #[test]
    fn same_process_has_low_distance() {
        let (e1, f1) = dataset(true, 1);
        let (e2, f2) = dataset(true, 2);
        let d = degree_feature_distance(&e1, &f1, &e2, &f2);
        assert!(d < 0.3, "d={d}");
    }

    #[test]
    fn decorrelated_process_has_higher_distance() {
        let (e1, f1) = dataset(true, 1);
        let (e2, f2) = dataset(true, 2);
        let (e3, f3) = dataset(false, 3);
        let d_same = degree_feature_distance(&e1, &f1, &e2, &f2);
        let d_diff = degree_feature_distance(&e1, &f1, &e3, &f3);
        assert!(d_diff > d_same, "diff={d_diff} same={d_same}");
    }

    #[test]
    fn identical_is_zero() {
        let (e, f) = dataset(true, 4);
        let d = degree_feature_distance(&e, &f, &e, &f);
        assert!(d < 1e-9, "d={d}");
    }

    #[test]
    fn heatmap_normalized() {
        let (e, f) = dataset(true, 5);
        let (h, rows, cols) = heatmap(&e, &f).unwrap();
        assert_eq!(h.len(), rows * cols);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categorical_joint_supported() {
        let (e, _) = dataset(true, 6);
        let deg = e.out_degrees();
        let codes: Vec<u32> = e.iter().map(|(s, _)| (deg[s as usize] > 20) as u32).collect();
        let f = FeatureTable::new(vec![Column::categorical("hub", codes)]).unwrap();
        let d = degree_feature_distance(&e, &f, &e, &f);
        assert!(d < 1e-9);
    }
}
