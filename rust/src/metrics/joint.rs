//! Joint degree×feature distribution distance ("Degree-Feat Dist-Dist ↓",
//! paper §4.3) and the Figure 5 heat-map dump (§8.9).
//!
//! For each edge, take (source degree, feature value); bin degrees
//! logarithmically and features linearly (categoricals by code); the
//! metric is the JS distance between the original's and the synthetic's
//! joint histograms, averaged over feature columns.
//!
//! The joint histogram is a **phase-2** accumulator (see
//! [`super::accum`]): binning needs the finalized source degrees and the
//! shared feature ranges first, then [`JointAccumulator`] counts
//! (degree-bin, feature-bin) pairs in one pass over any chunking of the
//! paired (edge, feature-row) stream. Counts are integers, so chunked +
//! merged accumulation reproduces the in-memory histogram bit for bit.

use super::accum::MetricAccumulator;
use super::degree::DegreeProfile;
use crate::featgen::table::{ColumnData, FeatureTable};
use crate::graph::EdgeList;
use crate::util::stats;

/// Degree (log) bins × feature bins used by the metric.
const DEG_BINS: usize = 12;
const FEAT_BINS: usize = 12;

/// How one feature column is binned in the joint histogram.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JointColLayout {
    /// Continuous: 12 linear bins over the shared `(lo, hi)` range.
    Continuous {
        /// Lower edge of the shared range.
        lo: f64,
        /// Upper edge of the shared range.
        hi: f64,
    },
    /// Categorical: one bin per code, clamped to `f_bins`.
    Categorical {
        /// Number of bins (the column's cardinality clamped to [1, 64]).
        f_bins: usize,
    },
}

impl JointColLayout {
    /// Layout for a column, given the shared feature range (ignored for
    /// categorical columns).
    pub fn of(data: &ColumnData, range: (f64, f64)) -> JointColLayout {
        match data {
            ColumnData::Continuous(_) => JointColLayout::Continuous { lo: range.0, hi: range.1 },
            ColumnData::Categorical { cardinality, .. } => JointColLayout::Categorical {
                f_bins: (*cardinality as usize).clamp(1, 64),
            },
        }
    }

    fn f_bins(&self) -> usize {
        match self {
            JointColLayout::Continuous { .. } => FEAT_BINS,
            JointColLayout::Categorical { f_bins } => *f_bins,
        }
    }
}

/// Phase-2 streaming accumulator of joint (src degree, feature)
/// histograms for a set of selected columns. Constructed from the
/// finalized degree array and the shared normalization (max degree +
/// feature ranges); observes paired (edge chunk, aligned feature rows)
/// via [`MetricAccumulator::observe_edges_with_features`]. Exactly
/// mergeable (integer counts).
pub struct JointAccumulator<'a> {
    deg: &'a [u32],
    max_d: f64,
    cols: Vec<(usize, JointColLayout)>,
    hists: Vec<Vec<f64>>,
}

impl<'a> JointAccumulator<'a> {
    /// Accumulator over `cols` — pairs of (column index into the
    /// observed tables, layout) — with `deg[s]` the finalized out-degree
    /// of source node `s` and `max_degree` the shared normalization.
    pub fn new(
        deg: &'a [u32],
        max_degree: u32,
        cols: Vec<(usize, JointColLayout)>,
    ) -> JointAccumulator<'a> {
        let hists = cols
            .iter()
            .map(|(_, layout)| vec![0.0f64; DEG_BINS * layout.f_bins()])
            .collect();
        JointAccumulator { deg, max_d: max_degree.max(1) as f64, cols, hists }
    }
}

impl MetricAccumulator for JointAccumulator<'_> {
    type Output = Vec<Vec<f64>>;

    fn observe_edges_with_features(&mut self, chunk: &EdgeList, rows: &FeatureTable) {
        assert_eq!(
            chunk.len(),
            rows.n_rows(),
            "JointAccumulator needs one feature row per edge"
        );
        for (e, (s, _)) in chunk.iter().enumerate() {
            let d = self.deg[s as usize] as f64;
            let td = if self.max_d <= 1.0 { 0.0 } else { (d.max(1.0)).ln() / self.max_d.ln() };
            let db = ((td * DEG_BINS as f64) as usize).min(DEG_BINS - 1);
            for ((col, layout), hist) in self.cols.iter().zip(self.hists.iter_mut()) {
                let f_bins = layout.f_bins();
                let fb = match (layout, &rows.columns[*col].data) {
                    (JointColLayout::Continuous { lo, hi }, ColumnData::Continuous(v)) => {
                        if *hi <= *lo {
                            0
                        } else {
                            let t = (v[e] - lo) / (hi - lo);
                            ((t * FEAT_BINS as f64) as isize).clamp(0, FEAT_BINS as isize - 1)
                                as usize
                        }
                    }
                    (
                        JointColLayout::Categorical { .. },
                        ColumnData::Categorical { codes, .. },
                    ) => (codes[e] as usize).min(f_bins - 1),
                    _ => panic!("JointAccumulator layout does not match the observed column"),
                };
                hist[db * f_bins + fb] += 1.0;
            }
        }
    }

    fn merge(&mut self, other: Self) {
        assert_eq!(self.cols, other.cols, "JointAccumulator merge across layouts");
        for (h, o) in self.hists.iter_mut().zip(&other.hists) {
            for (a, b) in h.iter_mut().zip(o) {
                *a += b;
            }
        }
    }

    fn finalize(self) -> Vec<Vec<f64>> {
        self.hists
    }
}

/// 2-D joint histogram of (src degree, feature) over the edges of a graph.
/// Returns a row-major `DEG_BINS × f_bins` matrix (counts). Thin wrapper
/// over [`JointAccumulator`] for one in-memory column.
pub fn joint_histogram(
    edges: &EdgeList,
    values: &ColumnData,
    max_degree: u32,
    feat_range: (f64, f64),
) -> Vec<f64> {
    let deg = DegreeProfile::of(edges);
    let table = FeatureTable::new(vec![crate::featgen::table::Column {
        name: "f".into(),
        data: values.clone(),
    }])
    .expect("single column");
    let mut acc = JointAccumulator::new(
        deg.out_degrees(),
        max_degree,
        vec![(0, JointColLayout::of(values, feat_range))],
    );
    acc.observe_edges_with_features(edges, &table);
    acc.finalize().remove(0)
}

/// "Degree-Feat Dist-Dist ↓": JS distance between joint (degree, feature)
/// histograms, averaged over all feature columns. In [0, 1], lower better.
pub fn degree_feature_distance(
    orig_edges: &EdgeList,
    orig_feats: &FeatureTable,
    synth_edges: &EdgeList,
    synth_feats: &FeatureTable,
) -> f64 {
    degree_feature_distance_with(
        &DegreeProfile::of(orig_edges),
        orig_edges,
        orig_feats,
        &DegreeProfile::of(synth_edges),
        synth_edges,
        synth_feats,
    )
}

/// [`degree_feature_distance`] over precomputed degree profiles, so
/// callers scoring several metrics (or several trials) derive the degree
/// arrays once and share them.
pub fn degree_feature_distance_with(
    orig_deg: &DegreeProfile,
    orig_edges: &EdgeList,
    orig_feats: &FeatureTable,
    synth_deg: &DegreeProfile,
    synth_edges: &EdgeList,
    synth_feats: &FeatureTable,
) -> f64 {
    let k = orig_feats.n_cols();
    if k == 0 || synth_feats.n_cols() != k {
        return 1.0;
    }
    // shared normalization so the two histograms align
    let max_deg = orig_deg
        .out_degrees()
        .iter()
        .chain(synth_deg.out_degrees().iter())
        .copied()
        .max()
        .unwrap_or(1);
    let mut total = 0.0;
    for c in 0..k {
        let range = match (&orig_feats.columns[c].data, &synth_feats.columns[c].data) {
            (ColumnData::Continuous(a), ColumnData::Continuous(b)) => {
                let (lo1, hi1) = stats::min_max(a);
                let (lo2, hi2) = stats::min_max(b);
                (lo1.min(lo2), hi1.max(hi2))
            }
            _ => (0.0, 0.0),
        };
        let observe = |deg: &DegreeProfile, edges: &EdgeList, feats: &FeatureTable| {
            let layout = JointColLayout::of(&feats.columns[c].data, range);
            let mut acc = JointAccumulator::new(deg.out_degrees(), max_deg, vec![(c, layout)]);
            acc.observe_edges_with_features(edges, feats);
            acc.finalize().remove(0)
        };
        let ho = observe(orig_deg, orig_edges, orig_feats);
        let hs = observe(synth_deg, synth_edges, synth_feats);
        if ho.len() != hs.len() {
            total += 1.0;
            continue;
        }
        total += stats::js_distance(&ho, &hs);
    }
    total / k as f64
}

/// Figure 5 heat map: normalized joint histogram of the first continuous
/// column (rows = degree bins, cols = feature bins).
pub fn heatmap(edges: &EdgeList, feats: &FeatureTable) -> Option<(Vec<f64>, usize, usize)> {
    heatmap_from(&DegreeProfile::of(edges), edges, feats)
}

/// [`heatmap`] over a precomputed degree profile (the experiment-harness
/// path: the profile is derived once and shared with the other metrics).
pub fn heatmap_from(
    deg: &DegreeProfile,
    edges: &EdgeList,
    feats: &FeatureTable,
) -> Option<(Vec<f64>, usize, usize)> {
    let (c, col) = feats
        .columns
        .iter()
        .enumerate()
        .find(|(_, c)| c.is_continuous())?;
    let (lo, hi) = stats::min_max(col.as_continuous());
    let max_deg = deg.max_out_degree().max(1);
    let mut acc = JointAccumulator::new(
        deg.out_degrees(),
        max_deg,
        vec![(c, JointColLayout::Continuous { lo, hi })],
    );
    acc.observe_edges_with_features(edges, feats);
    let mut h = acc.finalize().remove(0);
    let total: f64 = h.iter().sum();
    if total > 0.0 {
        for x in h.iter_mut() {
            *x /= total;
        }
    }
    Some((h, DEG_BINS, FEAT_BINS))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::graph::PartiteSpec;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;
    use crate::structgen::StructureGenerator;
    use crate::util::rng::Pcg64;

    /// Edge features correlated (or not) with src degree.
    fn dataset(correlated: bool, seed: u64) -> (EdgeList, FeatureTable) {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 8_000);
        let edges = g.generate(1, seed).unwrap();
        let deg = edges.out_degrees();
        let mut rng = Pcg64::new(seed ^ 0xfeed);
        let vals: Vec<f64> = edges
            .iter()
            .map(|(s, _)| {
                if correlated {
                    (deg[s as usize] as f64).ln() + rng.normal() * 0.2
                } else {
                    rng.normal()
                }
            })
            .collect();
        (edges, FeatureTable::new(vec![Column::continuous("f", vals)]).unwrap())
    }

    #[test]
    fn same_process_has_low_distance() {
        let (e1, f1) = dataset(true, 1);
        let (e2, f2) = dataset(true, 2);
        let d = degree_feature_distance(&e1, &f1, &e2, &f2);
        assert!(d < 0.3, "d={d}");
    }

    #[test]
    fn decorrelated_process_has_higher_distance() {
        let (e1, f1) = dataset(true, 1);
        let (e2, f2) = dataset(true, 2);
        let (e3, f3) = dataset(false, 3);
        let d_same = degree_feature_distance(&e1, &f1, &e2, &f2);
        let d_diff = degree_feature_distance(&e1, &f1, &e3, &f3);
        assert!(d_diff > d_same, "diff={d_diff} same={d_same}");
    }

    #[test]
    fn identical_is_zero() {
        let (e, f) = dataset(true, 4);
        let d = degree_feature_distance(&e, &f, &e, &f);
        assert!(d < 1e-9, "d={d}");
    }

    #[test]
    fn heatmap_normalized() {
        let (e, f) = dataset(true, 5);
        let (h, rows, cols) = heatmap(&e, &f).unwrap();
        assert_eq!(h.len(), rows * cols);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categorical_joint_supported() {
        let (e, _) = dataset(true, 6);
        let deg = e.out_degrees();
        let codes: Vec<u32> = e.iter().map(|(s, _)| (deg[s as usize] > 20) as u32).collect();
        let f = FeatureTable::new(vec![Column::categorical("hub", codes)]).unwrap();
        let d = degree_feature_distance(&e, &f, &e, &f);
        assert!(d < 1e-9);
    }

    #[test]
    fn chunked_joint_accumulation_is_exact() {
        let (e, f) = dataset(true, 7);
        let deg = DegreeProfile::of(&e);
        let max_deg = deg.max_out_degree();
        let (lo, hi) = stats::min_max(f.columns[0].as_continuous());
        let whole = joint_histogram(&e, &f.columns[0].data, max_deg, (lo, hi));
        // paired (edge, row) stream split into 4 chunks, merged partials
        let layout = JointColLayout::Continuous { lo, hi };
        let cuts = [0usize, e.len() / 7, e.len() / 3, e.len() / 2, e.len()];
        let mut merged: Option<JointAccumulator> = None;
        for w in cuts.windows(2) {
            let mut chunk = EdgeList::new(e.spec);
            for i in w[0]..w[1] {
                chunk.push(e.src[i], e.dst[i]);
            }
            let rows = f.gather(&(w[0]..w[1]).collect::<Vec<usize>>());
            let mut part = JointAccumulator::new(deg.out_degrees(), max_deg, vec![(0, layout)]);
            part.observe_edges_with_features(&chunk, &rows);
            match &mut merged {
                None => merged = Some(part),
                Some(m) => m.merge(part),
            }
        }
        let chunked = merged.unwrap().finalize().remove(0);
        assert_eq!(whole.len(), chunked.len());
        for (a, b) in whole.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
