//! Evaluation metrics (paper §4.3 + appendix §8.12 + Table 10).
//!
//! * [`degree`] — degree-distribution similarity (the "Degree Dist. ↑"
//!   column of Table 2) and the DCC coefficient of eq. 20.
//! * [`hopplot`] — sampled approximate neighbourhood function and
//!   effective diameter (Figure 2 right).
//! * [`featcorr`] — pairwise feature association matrix (Pearson /
//!   correlation-ratio / Theil's U) and its similarity score
//!   ("Feature Corr. ↑").
//! * [`joint`] — joint degree×feature distribution JS divergence
//!   ("Degree-Feat Dist-Dist ↓") and the Figure 5 heat map.
//! * [`graphstats`] — the 14 statistics of Table 10.

pub mod degree;
pub mod featcorr;
pub mod graphstats;
pub mod hopplot;
pub mod joint;

use crate::featgen::FeatureTable;
use crate::graph::EdgeList;

/// The three headline metrics of paper Table 2.
#[derive(Clone, Copy, Debug, Default)]
pub struct QualityReport {
    /// Degree-distribution similarity, higher is better (↑).
    pub degree_dist: f64,
    /// Feature-correlation similarity, higher is better (↑).
    pub feature_corr: f64,
    /// Joint degree-feature JS distance, lower is better (↓).
    pub degree_feat_dist: f64,
}

impl std::fmt::Display for QualityReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "degree_dist={:.4} feature_corr={:.4} degree_feat_dist={:.4}",
            self.degree_dist, self.feature_corr, self.degree_feat_dist
        )
    }
}

/// Evaluate a synthetic (structure, features) pair against the original —
/// one row of paper Table 2. Features are edge-level (one row per edge).
pub fn evaluate(
    orig_edges: &EdgeList,
    orig_feats: &FeatureTable,
    synth_edges: &EdgeList,
    synth_feats: &FeatureTable,
) -> QualityReport {
    QualityReport {
        degree_dist: degree::degree_dist_score(orig_edges, synth_edges),
        feature_corr: featcorr::feature_corr_score(orig_feats, synth_feats),
        degree_feat_dist: joint::degree_feature_distance(
            orig_edges,
            orig_feats,
            synth_edges,
            synth_feats,
        ),
    }
}
