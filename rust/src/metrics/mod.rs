//! Evaluation metrics (paper §4.3 + appendix §8.12 + Table 10).
//!
//! * [`degree`] — degree-distribution similarity (the "Degree Dist. ↑"
//!   column of Table 2) and the DCC coefficient of eq. 20.
//! * [`hopplot`] — sampled approximate neighbourhood function and
//!   effective diameter (Figure 2 right).
//! * [`featcorr`] — pairwise feature association matrix (Pearson /
//!   correlation-ratio / Theil's U) and its similarity score
//!   ("Feature Corr. ↑").
//! * [`joint`] — joint degree×feature distribution JS divergence
//!   ("Degree-Feat Dist-Dist ↓") and the Figure 5 heat map.
//! * [`graphstats`] — the 14 statistics of Table 10.
//!
//! Every score is backed by the **streaming accumulator engine** of
//! [`accum`]: one-pass, mergeable accumulators whose chunked evaluation
//! reproduces the in-memory scores exactly, so evaluation scales the
//! same way generation does. [`evaluate`] is a thin wrapper over
//! [`Evaluator`]; [`stream`] evaluates `ShardSink` output directly from
//! disk ( `sgg eval --shards` ) and taps in-flight generation.

pub mod accum;
pub mod degree;
pub mod featcorr;
pub mod graphstats;
pub mod hopplot;
pub mod joint;
pub mod stream;

pub use accum::{Evaluator, MetricAccumulator};
pub use degree::{DegreeAccumulator, DegreeProfile};
pub use featcorr::FeatureProfile;

use crate::featgen::FeatureTable;
use crate::graph::EdgeList;

/// The three headline metrics of paper Table 2.
#[derive(Clone, Copy, Debug, Default)]
pub struct QualityReport {
    /// Degree-distribution similarity, higher is better (↑).
    pub degree_dist: f64,
    /// Feature-correlation similarity, higher is better (↑).
    pub feature_corr: f64,
    /// Joint degree-feature JS distance, lower is better (↓).
    pub degree_feat_dist: f64,
}

impl std::fmt::Display for QualityReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "degree_dist={:.4} feature_corr={:.4} degree_feat_dist={:.4}",
            self.degree_dist, self.feature_corr, self.degree_feat_dist
        )
    }
}

impl QualityReport {
    /// Canonical JSON form (`sgg run --json` / `sgg evaluate` memory
    /// runs, and the final quality object of `sgg serve` memory jobs).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![
            ("degree_dist", self.degree_dist.into()),
            ("feature_corr", self.feature_corr.into()),
            ("degree_feat_dist", self.degree_feat_dist.into()),
        ])
    }
}

/// Evaluate a synthetic (structure, features) pair against the original —
/// one row of paper Table 2. Features are edge-level (one row per edge).
///
/// Thin wrapper over [`Evaluator`]: profile the original once, score the
/// synthetic pair. Callers scoring several synthetics against the same
/// original should hold an [`Evaluator`] instead, which shares the
/// original's profiles across calls.
pub fn evaluate(
    orig_edges: &EdgeList,
    orig_feats: &FeatureTable,
    synth_edges: &EdgeList,
    synth_feats: &FeatureTable,
) -> QualityReport {
    Evaluator::new(orig_edges, orig_feats).score(synth_edges, synth_feats)
}
