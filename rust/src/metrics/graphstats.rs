//! The graph statistics of paper Table 10 (NetGAN's benchmark set):
//! max degree, assortativity, triangle count, power-law exponent,
//! average clustering coefficient, wedge count, claw count, relative edge
//! distribution entropy, largest connected component, Gini coefficient of
//! degrees, edge overlap, and characteristic path length.
//!
//! # Exact vs. adjacency-bound vs. sampled
//!
//! The twelve statistics fall into three classes (documented here because
//! the streaming engine of [`super::accum`] can only take the first
//! class out-of-core today):
//!
//! * **Exactly streamable** — pure functions of the undirected degree
//!   multiset, which [`UndirectedDegreeAccumulator`] gathers in one
//!   mergeable pass: max degree, power-law α, wedge count, claw count,
//!   relative edge entropy, and the degree Gini ([`degree_only_stats`]).
//! * **Adjacency-bound** — need random access to neighbor lists and are
//!   computed from an in-memory CSR: assortativity, triangle count,
//!   average clustering, largest connected component, edge overlap.
//! * **Sampled** — characteristic path length (and the hop-plot family
//!   in [`super::hopplot`]) BFS-samples sources; exact computation is
//!   O(N·M) and out of reach at shard scale by design.

use super::accum::MetricAccumulator;
use super::degree::power_law_alpha;
use super::hopplot::characteristic_path_length;
use crate::graph::traversal::largest_component;
use crate::graph::{Csr, EdgeList, PartiteSpec};
use crate::util::stats;
use std::collections::HashSet;

/// All Table 10 statistics for one graph (+ edge overlap vs a reference).
#[derive(Clone, Debug, Default)]
pub struct GraphStats {
    /// Maximum degree.
    pub max_degree: f64,
    /// Degree assortativity coefficient.
    pub assortativity: f64,
    /// Triangle count.
    pub triangles: u64,
    /// Fitted power-law exponent of the degree distribution.
    pub power_law_exp: f64,
    /// Average local clustering coefficient.
    pub avg_clustering: f64,
    /// Wedge (2-path) count.
    pub wedges: u64,
    /// Claw (star with 3 leaves) count.
    pub claws: u64,
    /// Edge-distribution entropy relative to uniform.
    pub rel_edge_entropy: f64,
    /// Size of the largest connected component.
    pub largest_cc: usize,
    /// Gini coefficient of the degree distribution.
    pub gini: f64,
    /// Fraction of edges shared with the reference graph.
    pub edge_overlap: f64,
    /// Characteristic path length.
    pub char_path_len: f64,
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "max_deg={:.0} assort={:+.3} tri={} alpha={:.3} cc={:.2e} wedges={} claws={} \
             entr={:.3} lcc={} gini={:.3} eo={:.1}% cpl={:.2}",
            self.max_degree,
            self.assortativity,
            self.triangles,
            self.power_law_exp,
            self.avg_clustering,
            self.wedges,
            self.claws,
            self.rel_edge_entropy,
            self.largest_cc,
            self.gini,
            self.edge_overlap * 100.0,
            self.char_path_len
        )
    }
}

/// Streaming accumulator of the **undirected** per-node degree counts
/// over the global node space — exactly the degrees a
/// [`Csr::undirected`] view reports (each edge counts both endpoints;
/// self-loops once). Exactly mergeable (integer counts); the input of
/// [`degree_only_stats`].
#[derive(Clone, Debug, Default)]
pub struct UndirectedDegreeAccumulator {
    spec: Option<PartiteSpec>,
    deg: Vec<u32>,
}

impl UndirectedDegreeAccumulator {
    /// Empty accumulator; the node space is sized from the first chunk.
    pub fn new() -> UndirectedDegreeAccumulator {
        UndirectedDegreeAccumulator::default()
    }

    /// One-shot accumulation over an in-memory edge list.
    pub fn of(edges: &EdgeList) -> Vec<u32> {
        let mut a = UndirectedDegreeAccumulator::new();
        a.observe_edges(edges);
        a.finalize()
    }
}

impl MetricAccumulator for UndirectedDegreeAccumulator {
    type Output = Vec<u32>;

    fn observe_edges(&mut self, chunk: &EdgeList) {
        match self.spec {
            None => {
                self.spec = Some(chunk.spec);
                self.deg = vec![0; chunk.spec.total_nodes() as usize];
            }
            Some(s) => assert_eq!(
                s, chunk.spec,
                "UndirectedDegreeAccumulator fed chunks of differently-shaped graphs"
            ),
        }
        for (s, d) in chunk.iter() {
            let gs = chunk.spec.src_global(s) as usize;
            let gd = chunk.spec.dst_global(d) as usize;
            self.deg[gs] += 1;
            if gs != gd {
                self.deg[gd] += 1;
            }
        }
    }

    fn merge(&mut self, other: Self) {
        let Some(other_spec) = other.spec else { return };
        if self.spec.is_none() {
            *self = other;
            return;
        }
        assert_eq!(
            self.spec,
            Some(other_spec),
            "UndirectedDegreeAccumulator merge across differently-shaped graphs"
        );
        for (a, b) in self.deg.iter_mut().zip(&other.deg) {
            *a += b;
        }
    }

    fn finalize(self) -> Vec<u32> {
        self.deg
    }
}

/// The exactly-streamable half of Table 10: every statistic that is a
/// pure function of the undirected degree multiset.
#[derive(Clone, Copy, Debug, Default)]
pub struct DegreeOnlyStats {
    /// Maximum degree.
    pub max_degree: f64,
    /// MLE power-law exponent (d_min = 1).
    pub power_law_exp: f64,
    /// Wedge (2-path) count: Σ_v C(deg(v), 2).
    pub wedges: u64,
    /// Claw (3-star) count: Σ_v C(deg(v), 3).
    pub claws: u64,
    /// Degree-distribution entropy relative to uniform.
    pub rel_edge_entropy: f64,
    /// Gini coefficient of the degrees.
    pub gini: f64,
}

/// Compute [`DegreeOnlyStats`] from a finalized undirected degree array.
pub fn degree_only_stats(deg: &[u32]) -> DegreeOnlyStats {
    let degrees_f64: Vec<f64> = deg.iter().map(|&d| d as f64).collect();
    DegreeOnlyStats {
        max_degree: degrees_f64.iter().copied().fold(0.0, f64::max),
        power_law_exp: power_law_alpha(deg, 1),
        wedges: wedge_count_degrees(deg),
        claws: claw_count_degrees(deg),
        rel_edge_entropy: rel_edge_entropy_degrees(deg),
        gini: stats::gini(&degrees_f64),
    }
}

/// Degree assortativity: Pearson correlation of endpoint degrees over
/// edges (undirected view).
pub fn assortativity(csr: &Csr) -> f64 {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for v in 0..csr.n_nodes {
        let dv = csr.degree(v) as f64;
        for &w in csr.neighbors(v) {
            xs.push(dv);
            ys.push(csr.degree(w) as f64);
        }
    }
    stats::pearson(&xs, &ys)
}

/// Triangle count (each triangle counted once). Neighbor lists are
/// sorted, so intersection is a linear merge.
pub fn triangle_count(csr: &Csr) -> u64 {
    let mut count = 0u64;
    for v in 0..csr.n_nodes {
        for &w in csr.neighbors(v) {
            if w <= v {
                continue;
            }
            // common neighbors u > w close a triangle v<w<u exactly once
            let (mut i, mut j) = (0usize, 0usize);
            let nv = csr.neighbors(v);
            let nw = csr.neighbors(w);
            while i < nv.len() && j < nw.len() {
                match nv[i].cmp(&nw[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if nv[i] > w {
                            count += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    count
}

/// Wedge count from a degree array: Σ_v C(deg(v), 2).
pub fn wedge_count_degrees(deg: &[u32]) -> u64 {
    deg.iter()
        .map(|&d| {
            let d = d as u64;
            d * d.saturating_sub(1) / 2
        })
        .sum()
}

/// Wedge count: Σ_v C(deg(v), 2).
pub fn wedge_count(csr: &Csr) -> u64 {
    wedge_count_degrees(&csr_degrees(csr))
}

/// Claw (3-star) count from a degree array: Σ_v C(deg(v), 3).
pub fn claw_count_degrees(deg: &[u32]) -> u64 {
    deg.iter()
        .map(|&d| {
            let d = d as u64;
            if d < 3 {
                0
            } else {
                d * (d - 1) * (d - 2) / 6
            }
        })
        .sum()
}

/// Claw (3-star) count: Σ_v C(deg(v), 3).
pub fn claw_count(csr: &Csr) -> u64 {
    claw_count_degrees(&csr_degrees(csr))
}

/// Global average clustering coefficient: 3·triangles / wedges.
pub fn global_clustering(csr: &Csr) -> f64 {
    let w = wedge_count(csr);
    if w == 0 {
        0.0
    } else {
        3.0 * triangle_count(csr) as f64 / w as f64
    }
}

/// Relative edge-distribution entropy from a degree array:
/// H(degree distribution) / ln N.
pub fn rel_edge_entropy_degrees(deg: &[u32]) -> f64 {
    let n = deg.len() as f64;
    if n <= 1.0 {
        return 0.0;
    }
    let total: f64 = deg.iter().map(|&d| d as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &d in deg {
        let p = d as f64 / total;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h / n.ln()
}

/// Relative edge-distribution entropy: H(degree distribution) / ln N.
pub fn relative_edge_entropy(csr: &Csr) -> f64 {
    rel_edge_entropy_degrees(&csr_degrees(csr))
}

fn csr_degrees(csr: &Csr) -> Vec<u32> {
    (0..csr.n_nodes).map(|v| csr.degree(v) as u32).collect()
}

/// Compute the full Table 10 row. `reference` supplies the edge-overlap
/// target (use the original graph; pass the same graph for EO = 1).
pub fn compute(edges: &EdgeList, reference: &EdgeList, path_samples: usize) -> GraphStats {
    compute_vs(edges, &reference.edge_keys(), path_samples)
}

/// [`compute`] against a precomputed reference edge-key set, so repeated
/// trials against the same reference (Table 10's 5-trial sweeps) build
/// the overlap set once.
pub fn compute_vs(
    edges: &EdgeList,
    reference_keys: &HashSet<u128>,
    path_samples: usize,
) -> GraphStats {
    let csr = Csr::undirected(edges);
    // the degree-multiset half comes from the streaming accumulator; the
    // CSR serves only the adjacency-bound statistics
    let deg = UndirectedDegreeAccumulator::of(edges);
    let ds = degree_only_stats(&deg);
    let triangles = triangle_count(&csr);
    GraphStats {
        max_degree: ds.max_degree,
        assortativity: assortativity(&csr),
        triangles,
        power_law_exp: ds.power_law_exp,
        avg_clustering: if ds.wedges == 0 {
            0.0
        } else {
            3.0 * triangles as f64 / ds.wedges as f64
        },
        wedges: ds.wedges,
        claws: ds.claws,
        rel_edge_entropy: ds.rel_edge_entropy,
        largest_cc: largest_component(&csr),
        gini: ds.gini,
        edge_overlap: edges.edge_overlap_in(reference_keys),
        char_path_len: characteristic_path_length(edges, path_samples, 0xcafe),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;

    fn triangle_plus_tail() -> EdgeList {
        // triangle 0-1-2 plus edge 2-3
        EdgeList::from_pairs(PartiteSpec::square(4), &[(0, 1), (1, 2), (2, 0), (2, 3)])
    }

    #[test]
    fn triangle_and_wedge_counts() {
        let csr = Csr::undirected(&triangle_plus_tail());
        assert_eq!(triangle_count(&csr), 1);
        // degrees: 2,2,3,1 -> wedges 1+1+3+0 = 5
        assert_eq!(wedge_count(&csr), 5);
        // claws: C(3,3)=1 at node 2
        assert_eq!(claw_count(&csr), 1);
        let cc = global_clustering(&csr);
        assert!((cc - 3.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn star_counts() {
        let star = EdgeList::from_pairs(
            PartiteSpec::square(5),
            &[(0, 1), (0, 2), (0, 3), (0, 4)],
        );
        let csr = Csr::undirected(&star);
        assert_eq!(triangle_count(&csr), 0);
        assert_eq!(wedge_count(&csr), 6); // C(4,2)
        assert_eq!(claw_count(&csr), 4); // C(4,3)
        // star is disassortative
        assert!(assortativity(&csr) < 0.0);
    }

    #[test]
    fn clique_stats() {
        let mut pairs = Vec::new();
        for a in 0..6u64 {
            for b in (a + 1)..6 {
                pairs.push((a, b));
            }
        }
        let e = EdgeList::from_pairs(PartiteSpec::square(6), &pairs);
        let csr = Csr::undirected(&e);
        assert_eq!(triangle_count(&csr), 20); // C(6,3)
        assert!((global_clustering(&csr) - 1.0).abs() < 1e-12);
        // regular graph: assortativity undefined (constant degrees) -> 0
        assert_eq!(assortativity(&csr), 0.0);
    }

    #[test]
    fn entropy_uniform_vs_star() {
        let mut pairs = Vec::new();
        for a in 0..6u64 {
            pairs.push((a, (a + 1) % 6)); // cycle: uniform degrees
        }
        let cyc = Csr::undirected(&EdgeList::from_pairs(PartiteSpec::square(6), &pairs));
        let star = Csr::undirected(&EdgeList::from_pairs(
            PartiteSpec::square(6),
            &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)],
        ));
        assert!(relative_edge_entropy(&cyc) > relative_edge_entropy(&star));
    }

    #[test]
    fn full_stats_row() {
        let e = triangle_plus_tail();
        let s = compute(&e, &e, 4);
        assert_eq!(s.triangles, 1);
        assert_eq!(s.largest_cc, 4);
        assert!((s.edge_overlap - 1.0).abs() < 1e-12);
        assert!(s.char_path_len > 0.0);
        assert_eq!(s.max_degree, 3.0);
    }

    #[test]
    fn undirected_accumulator_matches_csr_degrees() {
        let e = triangle_plus_tail();
        let csr = Csr::undirected(&e);
        let acc_deg = UndirectedDegreeAccumulator::of(&e);
        let csr_deg: Vec<u32> = (0..csr.n_nodes).map(|v| csr.degree(v) as u32).collect();
        assert_eq!(acc_deg, csr_deg);
        // self-loops count once, like the CSR view
        let mut with_loop = e.clone();
        with_loop.push(1, 1);
        let csr2 = Csr::undirected(&with_loop);
        let acc2 = UndirectedDegreeAccumulator::of(&with_loop);
        let csr_deg2: Vec<u32> = (0..csr2.n_nodes).map(|v| csr2.degree(v) as u32).collect();
        assert_eq!(acc2, csr_deg2);
    }

    #[test]
    fn degree_only_stats_match_csr_paths() {
        let e = triangle_plus_tail();
        let csr = Csr::undirected(&e);
        let ds = degree_only_stats(&UndirectedDegreeAccumulator::of(&e));
        assert_eq!(ds.wedges, wedge_count(&csr));
        assert_eq!(ds.claws, claw_count(&csr));
        assert_eq!(ds.max_degree, 3.0);
        assert!((ds.rel_edge_entropy - relative_edge_entropy(&csr)).abs() < 1e-12);
    }

    #[test]
    fn compute_vs_shares_reference_set() {
        let e = triangle_plus_tail();
        let keys = e.edge_keys();
        let a = compute(&e, &e, 4);
        let b = compute_vs(&e, &keys, 4);
        assert_eq!(a.edge_overlap.to_bits(), b.edge_overlap.to_bits());
        assert_eq!(a.triangles, b.triangles);
    }
}
