//! The graph statistics of paper Table 10 (NetGAN's benchmark set):
//! max degree, assortativity, triangle count, power-law exponent,
//! average clustering coefficient, wedge count, claw count, relative edge
//! distribution entropy, largest connected component, Gini coefficient of
//! degrees, edge overlap, and characteristic path length.

use super::degree::power_law_alpha;
use super::hopplot::characteristic_path_length;
use crate::graph::traversal::largest_component;
use crate::graph::{Csr, EdgeList};
use crate::util::stats;

/// All Table 10 statistics for one graph (+ edge overlap vs a reference).
#[derive(Clone, Debug, Default)]
pub struct GraphStats {
    /// Maximum degree.
    pub max_degree: f64,
    /// Degree assortativity coefficient.
    pub assortativity: f64,
    /// Triangle count.
    pub triangles: u64,
    /// Fitted power-law exponent of the degree distribution.
    pub power_law_exp: f64,
    /// Average local clustering coefficient.
    pub avg_clustering: f64,
    /// Wedge (2-path) count.
    pub wedges: u64,
    /// Claw (star with 3 leaves) count.
    pub claws: u64,
    /// Edge-distribution entropy relative to uniform.
    pub rel_edge_entropy: f64,
    /// Size of the largest connected component.
    pub largest_cc: usize,
    /// Gini coefficient of the degree distribution.
    pub gini: f64,
    /// Fraction of edges shared with the reference graph.
    pub edge_overlap: f64,
    /// Characteristic path length.
    pub char_path_len: f64,
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "max_deg={:.0} assort={:+.3} tri={} alpha={:.3} cc={:.2e} wedges={} claws={} \
             entr={:.3} lcc={} gini={:.3} eo={:.1}% cpl={:.2}",
            self.max_degree,
            self.assortativity,
            self.triangles,
            self.power_law_exp,
            self.avg_clustering,
            self.wedges,
            self.claws,
            self.rel_edge_entropy,
            self.largest_cc,
            self.gini,
            self.edge_overlap * 100.0,
            self.char_path_len
        )
    }
}

/// Degree assortativity: Pearson correlation of endpoint degrees over
/// edges (undirected view).
pub fn assortativity(csr: &Csr) -> f64 {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for v in 0..csr.n_nodes {
        let dv = csr.degree(v) as f64;
        for &w in csr.neighbors(v) {
            xs.push(dv);
            ys.push(csr.degree(w) as f64);
        }
    }
    stats::pearson(&xs, &ys)
}

/// Triangle count (each triangle counted once). Neighbor lists are
/// sorted, so intersection is a linear merge.
pub fn triangle_count(csr: &Csr) -> u64 {
    let mut count = 0u64;
    for v in 0..csr.n_nodes {
        for &w in csr.neighbors(v) {
            if w <= v {
                continue;
            }
            // common neighbors u > w close a triangle v<w<u exactly once
            let (mut i, mut j) = (0usize, 0usize);
            let nv = csr.neighbors(v);
            let nw = csr.neighbors(w);
            while i < nv.len() && j < nw.len() {
                match nv[i].cmp(&nw[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if nv[i] > w {
                            count += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    count
}

/// Wedge count: Σ_v C(deg(v), 2).
pub fn wedge_count(csr: &Csr) -> u64 {
    (0..csr.n_nodes)
        .map(|v| {
            let d = csr.degree(v) as u64;
            d * d.saturating_sub(1) / 2
        })
        .sum()
}

/// Claw (3-star) count: Σ_v C(deg(v), 3).
pub fn claw_count(csr: &Csr) -> u64 {
    (0..csr.n_nodes)
        .map(|v| {
            let d = csr.degree(v) as u64;
            if d < 3 {
                0
            } else {
                d * (d - 1) * (d - 2) / 6
            }
        })
        .sum()
}

/// Global average clustering coefficient: 3·triangles / wedges.
pub fn global_clustering(csr: &Csr) -> f64 {
    let w = wedge_count(csr);
    if w == 0 {
        0.0
    } else {
        3.0 * triangle_count(csr) as f64 / w as f64
    }
}

/// Relative edge-distribution entropy: H(degree distribution) / ln N.
pub fn relative_edge_entropy(csr: &Csr) -> f64 {
    let n = csr.n_nodes as f64;
    if n <= 1.0 {
        return 0.0;
    }
    let total: f64 = (0..csr.n_nodes).map(|v| csr.degree(v) as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for v in 0..csr.n_nodes {
        let p = csr.degree(v) as f64 / total;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h / n.ln()
}

/// Compute the full Table 10 row. `reference` supplies the edge-overlap
/// target (use the original graph; pass the same graph for EO = 1).
pub fn compute(edges: &EdgeList, reference: &EdgeList, path_samples: usize) -> GraphStats {
    let csr = Csr::undirected(edges);
    let degrees: Vec<f64> = csr.degrees_f64();
    let deg_u32: Vec<u32> = degrees.iter().map(|&d| d as u32).collect();
    GraphStats {
        max_degree: degrees.iter().copied().fold(0.0, f64::max),
        assortativity: assortativity(&csr),
        triangles: triangle_count(&csr),
        power_law_exp: power_law_alpha(&deg_u32, 1),
        avg_clustering: global_clustering(&csr),
        wedges: wedge_count(&csr),
        claws: claw_count(&csr),
        rel_edge_entropy: relative_edge_entropy(&csr),
        largest_cc: largest_component(&csr),
        gini: stats::gini(&degrees),
        edge_overlap: edges.edge_overlap(reference),
        char_path_len: characteristic_path_length(edges, path_samples, 0xcafe),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;

    fn triangle_plus_tail() -> EdgeList {
        // triangle 0-1-2 plus edge 2-3
        EdgeList::from_pairs(PartiteSpec::square(4), &[(0, 1), (1, 2), (2, 0), (2, 3)])
    }

    #[test]
    fn triangle_and_wedge_counts() {
        let csr = Csr::undirected(&triangle_plus_tail());
        assert_eq!(triangle_count(&csr), 1);
        // degrees: 2,2,3,1 -> wedges 1+1+3+0 = 5
        assert_eq!(wedge_count(&csr), 5);
        // claws: C(3,3)=1 at node 2
        assert_eq!(claw_count(&csr), 1);
        let cc = global_clustering(&csr);
        assert!((cc - 3.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn star_counts() {
        let star = EdgeList::from_pairs(
            PartiteSpec::square(5),
            &[(0, 1), (0, 2), (0, 3), (0, 4)],
        );
        let csr = Csr::undirected(&star);
        assert_eq!(triangle_count(&csr), 0);
        assert_eq!(wedge_count(&csr), 6); // C(4,2)
        assert_eq!(claw_count(&csr), 4); // C(4,3)
        // star is disassortative
        assert!(assortativity(&csr) < 0.0);
    }

    #[test]
    fn clique_stats() {
        let mut pairs = Vec::new();
        for a in 0..6u64 {
            for b in (a + 1)..6 {
                pairs.push((a, b));
            }
        }
        let e = EdgeList::from_pairs(PartiteSpec::square(6), &pairs);
        let csr = Csr::undirected(&e);
        assert_eq!(triangle_count(&csr), 20); // C(6,3)
        assert!((global_clustering(&csr) - 1.0).abs() < 1e-12);
        // regular graph: assortativity undefined (constant degrees) -> 0
        assert_eq!(assortativity(&csr), 0.0);
    }

    #[test]
    fn entropy_uniform_vs_star() {
        let mut pairs = Vec::new();
        for a in 0..6u64 {
            pairs.push((a, (a + 1) % 6)); // cycle: uniform degrees
        }
        let cyc = Csr::undirected(&EdgeList::from_pairs(PartiteSpec::square(6), &pairs));
        let star = Csr::undirected(&EdgeList::from_pairs(
            PartiteSpec::square(6),
            &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)],
        ));
        assert!(relative_edge_entropy(&cyc) > relative_edge_entropy(&star));
    }

    #[test]
    fn full_stats_row() {
        let e = triangle_plus_tail();
        let s = compute(&e, &e, 4);
        assert_eq!(s.triangles, 1);
        assert_eq!(s.largest_cc, 4);
        assert!((s.edge_overlap - 1.0).abs() < 1e-12);
        assert!(s.char_path_len > 0.0);
        assert_eq!(s.max_degree, 3.0);
    }
}
