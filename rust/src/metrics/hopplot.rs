//! Hop-plot and effective diameter (paper §4.3, Figure 2 right).
//!
//! The hop-plot d(h) counts node pairs reachable within h hops. Exact
//! computation is O(N·M); we sample BFS sources (the standard ANF-style
//! approximation) which preserves the curve shape the paper compares.
//!
//! **Sampled fallback, by design.** Unlike the degree/joint/association
//! metrics — which the streaming engine ([`super::accum`]) computes
//! *exactly* from one mergeable pass — every function here needs random
//! access to adjacency and BFS-samples `samples` seeded sources. The
//! results are deterministic in `(samples, seed)` but approximate; at
//! shard scale, evaluate these on a subsampled in-memory view rather
//! than the full graph (see `docs/ARCHITECTURE.md` § Evaluation).

use crate::graph::traversal::bfs_distances;
use crate::graph::{Csr, EdgeList};
use crate::util::rng::Pcg64;

/// Hop-plot: `pairs[h]` ≈ fraction of (ordered) reachable pairs within h
/// hops, estimated from `samples` BFS sources. Index 0 counts self-pairs.
pub fn hop_plot(edges: &EdgeList, samples: usize, seed: u64) -> Vec<f64> {
    let csr = Csr::undirected(edges);
    let n = csr.n_nodes as usize;
    if n == 0 {
        return vec![];
    }
    let samples = samples.min(n).max(1);
    let mut rng = Pcg64::new(seed);
    let sources = rng.sample_indices(n, samples);
    let mut max_h = 0usize;
    let mut counts: Vec<u64> = Vec::new();
    for &s in &sources {
        let dist = bfs_distances(&csr, s as u64);
        for d in dist {
            if d == u32::MAX {
                continue;
            }
            let d = d as usize;
            if d >= counts.len() {
                counts.resize(d + 1, 0);
            }
            counts[d] += 1;
            max_h = max_h.max(d);
        }
    }
    // cumulative reachable pairs within h hops, normalized per source*N
    let total = (samples as f64) * n as f64;
    let mut acc = 0u64;
    counts
        .iter()
        .map(|&c| {
            acc += c;
            acc as f64 / total
        })
        .collect()
}

/// Effective diameter: smallest h such that ≥ `fraction` of reachable
/// pairs are within h hops (paper uses 0.9), linearly interpolated.
pub fn effective_diameter(edges: &EdgeList, fraction: f64, samples: usize, seed: u64) -> f64 {
    let hp = hop_plot(edges, samples, seed);
    if hp.is_empty() {
        return 0.0;
    }
    let reach = *hp.last().unwrap();
    let target = fraction * reach;
    for h in 0..hp.len() {
        if hp[h] >= target {
            if h == 0 {
                return 0.0;
            }
            let prev = hp[h - 1];
            let frac = if hp[h] > prev { (target - prev) / (hp[h] - prev) } else { 0.0 };
            return (h - 1) as f64 + frac;
        }
    }
    (hp.len() - 1) as f64
}

/// Characteristic (average) path length over sampled pairs (Table 10).
pub fn characteristic_path_length(edges: &EdgeList, samples: usize, seed: u64) -> f64 {
    let csr = Csr::undirected(edges);
    let n = csr.n_nodes as usize;
    if n == 0 {
        return 0.0;
    }
    let samples = samples.min(n).max(1);
    let mut rng = Pcg64::new(seed);
    let sources = rng.sample_indices(n, samples);
    let mut total = 0.0f64;
    let mut count = 0u64;
    for &s in &sources {
        let dist = bfs_distances(&csr, s as u64);
        for (v, d) in dist.iter().enumerate() {
            if *d != u32::MAX && v != s {
                total += *d as f64;
                count += 1;
            }
        }
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;

    fn path_graph(n: u64) -> EdgeList {
        let pairs: Vec<(u64, u64)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        EdgeList::from_pairs(PartiteSpec::square(n), &pairs)
    }

    fn clique(n: u64) -> EdgeList {
        let mut pairs = Vec::new();
        for a in 0..n {
            for b in (a + 1)..n {
                pairs.push((a, b));
            }
        }
        EdgeList::from_pairs(PartiteSpec::square(n), &pairs)
    }

    #[test]
    fn hop_plot_monotone_and_saturates() {
        let hp = hop_plot(&path_graph(20), 20, 1);
        for w in hp.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((hp.last().unwrap() - 1.0).abs() < 1e-9); // fully connected path
    }

    #[test]
    fn clique_diameter_one() {
        let d = effective_diameter(&clique(10), 0.9, 10, 1);
        assert!(d <= 1.0, "d={d}");
        let cpl = characteristic_path_length(&clique(10), 10, 1);
        assert!((cpl - 1.0).abs() < 1e-9, "cpl={cpl}");
    }

    #[test]
    fn path_diameter_grows() {
        let d_short = effective_diameter(&path_graph(8), 0.9, 8, 1);
        let d_long = effective_diameter(&path_graph(64), 0.9, 64, 1);
        assert!(d_long > d_short, "{d_long} vs {d_short}");
    }

    #[test]
    fn cpl_path_graph_known() {
        // path of 3 nodes: distances 1,1,2 (ordered pairs doubled) -> mean 4/3
        let cpl = characteristic_path_length(&path_graph(3), 3, 1);
        assert!((cpl - 4.0 / 3.0).abs() < 1e-9, "cpl={cpl}");
    }
}
