//! The streaming metric engine: one-pass, mergeable accumulators behind
//! every evaluation score (paper §4.3), so shard-scale graphs can be
//! evaluated without materializing them.
//!
//! # The accumulator contract
//!
//! A [`MetricAccumulator`] consumes a graph in pieces — edge chunks via
//! `observe_edges`, feature rows via `observe_features` — and two
//! accumulators over disjoint pieces of the same graph combine with
//! `merge`. `finalize` turns the accumulated state into the metric's
//! input (a degree profile, an association matrix, a joint histogram).
//! Three properties make streamed evaluation *exact* rather than
//! approximate:
//!
//! * **Sequential chunking is free.** Observing chunks `A` then `B` into
//!   one accumulator performs the identical operation sequence as
//!   observing the concatenation `A‖B`, so any chunking of a sequential
//!   pass is bit-for-bit equal to the in-memory pass.
//! * **Count-based accumulators merge exactly.** Degree counts, joint
//!   degree×feature histograms and categorical marginals are integer
//!   counters; their `merge` is associative *and* commutative bit for
//!   bit (below 2⁵³ events per bin), so parallel per-shard partials can
//!   combine in any order and still reproduce the in-memory scores
//!   exactly. Every metric of the shard-evaluation path
//!   ([`crate::metrics::stream`]) is built only from these.
//! * **Moment-based accumulators merge deterministically.** The feature
//!   association statistics ([`super::featcorr::AssocAccumulator`]) keep
//!   Welford/Chan-style running moments: `merge` is commutative bit for
//!   bit and associative up to f64 rounding (~1 ulp), so merged results
//!   are deterministic for a fixed merge order and mathematically equal
//!   to the one-pass result. In practice feature tables are observed
//!   sequentially (features are never sharded), so the exact path
//!   applies.
//!
//! Metrics that need *global* normalization before binning (the joint
//! degree×feature histogram needs the final degrees and feature ranges;
//! the single-column marginal needs the shared value range) run in two
//! phases: phase 1 accumulates degrees/moments/ranges one-pass, phase 2
//! re-streams the data into count-based accumulators parameterized by
//! the finalized phase-1 norms. Both phases are one-pass and mergeable.
//!
//! The accumulators themselves live next to the scores they back:
//! [`super::degree::DegreeAccumulator`],
//! [`super::featcorr::AssocAccumulator`] (+ the phase-2
//! [`super::featcorr::MarginalAccumulator`]),
//! [`super::joint::JointAccumulator`], and
//! [`super::graphstats::UndirectedDegreeAccumulator`]. [`Evaluator`] is
//! the high-level driver: it profiles the original dataset once and
//! scores any number of synthetic graphs against it —
//! [`crate::metrics::evaluate`] is a thin wrapper over it.

use super::degree::DegreeProfile;
use super::featcorr::{self, FeatureProfile};
use super::{degree, joint, QualityReport};
use crate::featgen::FeatureTable;
use crate::graph::EdgeList;

/// A one-pass, mergeable metric accumulator (see the module docs for the
/// exactness contract).
///
/// `observe_edges` / `observe_features` default to no-ops so structure-
/// only and feature-only accumulators implement just the side they
/// consume; accumulators over *paired* (edge, feature-row) streams
/// override `observe_edges_with_features` instead.
pub trait MetricAccumulator: Sized {
    /// What `finalize` produces.
    type Output;

    /// Consume one chunk of edges (any split of the edge stream).
    fn observe_edges(&mut self, _chunk: &EdgeList) {}

    /// Consume one block of feature rows (any split of the row stream).
    fn observe_features(&mut self, _rows: &FeatureTable) {}

    /// Consume a chunk of edges together with the feature rows aligned
    /// to those edges (row `i` belongs to edge `i` of the chunk).
    fn observe_edges_with_features(&mut self, chunk: &EdgeList, rows: &FeatureTable) {
        self.observe_edges(chunk);
        self.observe_features(rows);
    }

    /// Fold another accumulator over a disjoint part of the same graph
    /// into this one.
    fn merge(&mut self, other: Self);

    /// Finish accumulation and produce the metric input.
    fn finalize(self) -> Self::Output;
}

/// High-level evaluation driver: profiles the original (edges, features)
/// pair **once** and scores any number of synthetic graphs against it —
/// the shared-accumulator path behind [`crate::metrics::evaluate`] and
/// the experiment harnesses (Tables 2/5/6/9, Figures 2/5/7).
///
/// Profiling the original up front removes the repeated degree-vector
/// and association-matrix derivation the per-call metric functions would
/// otherwise redo for every synthetic sample.
pub struct Evaluator<'a> {
    orig_edges: &'a EdgeList,
    orig_feats: &'a FeatureTable,
    orig_deg: DegreeProfile,
    orig_feat: FeatureProfile,
}

impl<'a> Evaluator<'a> {
    /// Profile the original dataset (one pass over edges + features).
    pub fn new(edges: &'a EdgeList, feats: &'a FeatureTable) -> Evaluator<'a> {
        Evaluator {
            orig_edges: edges,
            orig_feats: feats,
            orig_deg: DegreeProfile::of(edges),
            orig_feat: FeatureProfile::of(feats),
        }
    }

    /// The original graph's finalized degree profile.
    pub fn degree_profile(&self) -> &DegreeProfile {
        &self.orig_deg
    }

    /// The original feature table's finalized profile.
    pub fn feature_profile(&self) -> &FeatureProfile {
        &self.orig_feat
    }

    /// Score one synthetic (structure, features) pair — one cell of
    /// paper Table 2. Identical to [`crate::metrics::evaluate`] on the
    /// same inputs.
    pub fn score(&self, synth_edges: &EdgeList, synth_feats: &FeatureTable) -> QualityReport {
        let synth_deg = DegreeProfile::of(synth_edges);
        let synth_feat = FeatureProfile::of(synth_feats);
        QualityReport {
            degree_dist: degree::degree_dist_score_profiles(&self.orig_deg, &synth_deg),
            feature_corr: featcorr::feature_corr_with(
                &self.orig_feat,
                &synth_feat,
                self.orig_feats,
                synth_feats,
            ),
            degree_feat_dist: joint::degree_feature_distance_with(
                &self.orig_deg,
                self.orig_edges,
                self.orig_feats,
                &synth_deg,
                synth_edges,
                synth_feats,
            ),
        }
    }

    /// The degree-distribution score alone, against an already-profiled
    /// synthetic graph (the streamed-evaluation path).
    pub fn degree_dist(&self, synth: &DegreeProfile) -> f64 {
        degree::degree_dist_score_profiles(&self.orig_deg, synth)
    }

    /// The joint degree×feature distance alone (Table 9's metric),
    /// reusing the original's profile across trials.
    pub fn degree_feature_distance(
        &self,
        synth_edges: &EdgeList,
        synth_feats: &FeatureTable,
    ) -> f64 {
        joint::degree_feature_distance_with(
            &self.orig_deg,
            self.orig_edges,
            self.orig_feats,
            &DegreeProfile::of(synth_edges),
            synth_edges,
            synth_feats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::graph::PartiteSpec;
    use crate::util::rng::Pcg64;

    fn graph_and_feats(seed: u64, n: u64, m: usize) -> (EdgeList, FeatureTable) {
        let mut rng = Pcg64::new(seed);
        let mut e = EdgeList::new(PartiteSpec::square(n));
        for _ in 0..m {
            e.push(rng.below(n), rng.below(n));
        }
        let vals: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let codes: Vec<u32> = (0..m).map(|_| rng.below(3) as u32).collect();
        let t = FeatureTable::new(vec![
            Column::continuous("v", vals),
            Column::categorical("c", codes),
        ])
        .unwrap();
        (e, t)
    }

    #[test]
    fn evaluator_matches_evaluate_bit_for_bit() {
        let (oe, of) = graph_and_feats(1, 128, 2_000);
        let (se, sf) = graph_and_feats(2, 128, 2_000);
        let direct = crate::metrics::evaluate(&oe, &of, &se, &sf);
        let ev = Evaluator::new(&oe, &of);
        let shared = ev.score(&se, &sf);
        assert_eq!(direct.degree_dist.to_bits(), shared.degree_dist.to_bits());
        assert_eq!(direct.feature_corr.to_bits(), shared.feature_corr.to_bits());
        assert_eq!(
            direct.degree_feat_dist.to_bits(),
            shared.degree_feat_dist.to_bits()
        );
    }

    #[test]
    fn evaluator_reuses_profiles_across_scores() {
        let (oe, of) = graph_and_feats(3, 64, 500);
        let ev = Evaluator::new(&oe, &of);
        // scoring twice against different synths shares the orig profile
        let (s1e, s1f) = graph_and_feats(4, 64, 500);
        let (s2e, s2f) = graph_and_feats(5, 64, 500);
        let r1 = ev.score(&s1e, &s1f);
        let r2 = ev.score(&s2e, &s2f);
        assert!(r1.degree_dist > 0.0 && r2.degree_dist > 0.0);
        // self-score is perfect on the degree metric
        let self_r = ev.score(&oe, &of);
        assert!((self_r.degree_dist - 1.0).abs() < 1e-9);
        assert!(self_r.degree_feat_dist < 1e-9);
    }
}
