//! Feature-correlation similarity ("Feature Corr. ↑", paper §4.3).
//!
//! Builds the pairwise association matrix of a feature table — Pearson
//! between continuous pairs, correlation ratio between categorical and
//! continuous, Theil's U between categorical pairs — and scores a
//! synthetic table by 1 − mean |assoc_orig − assoc_synth|.
//!
//! The statistics stream: [`AssocAccumulator`] keeps Welford/Chan-style
//! running moments per column and per column pair (plus exact joint
//! category counts), so the association matrix is computed in one pass
//! over any chunking of the rows and partial accumulators merge
//! deterministically (see [`super::accum`] for the exactness contract —
//! moment merges are commutative bit for bit and associative up to f64
//! rounding; category counts are exact). [`association_matrix`] and
//! [`feature_corr_score`] are thin wrappers over the accumulator.

use super::accum::MetricAccumulator;
use crate::featgen::table::{ColumnData, FeatureTable};
use crate::util::stats;
use std::collections::BTreeMap;

/// Bins used by the single-column continuous marginal similarity.
const MARGINAL_BINS: usize = 32;

/// Streaming per-column statistics.
#[derive(Clone, Debug)]
enum ColStats {
    /// Welford moments + observed range of a continuous column.
    Cont { n: u64, mean: f64, m2: f64, lo: f64, hi: f64 },
    /// Exact category counts (grown on demand past the declared
    /// cardinality).
    Cat { counts: Vec<u64>, cardinality: u32 },
}

impl ColStats {
    fn of(data: &ColumnData) -> ColStats {
        match data {
            ColumnData::Continuous(_) => ColStats::Cont {
                n: 0,
                mean: 0.0,
                m2: 0.0,
                lo: f64::INFINITY,
                hi: f64::NEG_INFINITY,
            },
            ColumnData::Categorical { cardinality, .. } => ColStats::Cat {
                counts: vec![0; *cardinality as usize],
                cardinality: *cardinality,
            },
        }
    }

    fn same_kind(&self, data: &ColumnData) -> bool {
        matches!(
            (self, data),
            (ColStats::Cont { .. }, ColumnData::Continuous(_))
                | (ColStats::Cat { .. }, ColumnData::Categorical { .. })
        )
    }
}

/// Streaming per-pair statistics (pair `(i, j)` with `i < j`).
#[derive(Clone, Debug)]
enum PairStats {
    /// Bivariate Welford moments for Pearson.
    ContCont { n: u64, mx: f64, my: f64, mxx: f64, myy: f64, cxy: f64 },
    /// Per-category (count, mean) of the continuous side plus the grand
    /// Welford moments, for the correlation ratio. `cat_first` records
    /// which side of the pair is the categorical column.
    CatCont { cats: Vec<(u64, f64)>, n: u64, mean: f64, m2: f64, cat_first: bool },
    /// Exact joint category counts for Theil's U.
    CatCat { joint: BTreeMap<(u32, u32), u64> },
}

impl PairStats {
    fn of(a: &ColumnData, b: &ColumnData) -> PairStats {
        match (a, b) {
            (ColumnData::Continuous(_), ColumnData::Continuous(_)) => PairStats::ContCont {
                n: 0,
                mx: 0.0,
                my: 0.0,
                mxx: 0.0,
                myy: 0.0,
                cxy: 0.0,
            },
            (ColumnData::Categorical { .. }, ColumnData::Continuous(_)) => PairStats::CatCont {
                cats: Vec::new(),
                n: 0,
                mean: 0.0,
                m2: 0.0,
                cat_first: true,
            },
            (ColumnData::Continuous(_), ColumnData::Categorical { .. }) => PairStats::CatCont {
                cats: Vec::new(),
                n: 0,
                mean: 0.0,
                m2: 0.0,
                cat_first: false,
            },
            (ColumnData::Categorical { .. }, ColumnData::Categorical { .. }) => {
                PairStats::CatCat { joint: BTreeMap::new() }
            }
        }
    }
}

/// One-pass, mergeable accumulator of the pairwise association matrix
/// (and the per-column ranges / marginals the other feature metrics
/// need). The column layout is adopted from the first observed block;
/// later blocks must match it.
#[derive(Clone, Debug, Default)]
pub struct AssocAccumulator {
    cols: Vec<ColStats>,
    pairs: Vec<PairStats>,
    started: bool,
}

impl AssocAccumulator {
    /// Empty accumulator; the column layout comes from the first block.
    pub fn new() -> AssocAccumulator {
        AssocAccumulator::default()
    }

    fn ensure_layout(&mut self, rows: &FeatureTable) {
        if !self.started {
            let k = rows.n_cols();
            self.cols = rows.columns.iter().map(|c| ColStats::of(&c.data)).collect();
            self.pairs = Vec::with_capacity(k.saturating_sub(1) * k / 2);
            for i in 0..k {
                for j in (i + 1)..k {
                    self.pairs
                        .push(PairStats::of(&rows.columns[i].data, &rows.columns[j].data));
                }
            }
            self.started = true;
            return;
        }
        assert_eq!(
            self.cols.len(),
            rows.n_cols(),
            "AssocAccumulator fed blocks with different column counts"
        );
        for (st, col) in self.cols.iter().zip(&rows.columns) {
            assert!(
                st.same_kind(&col.data),
                "AssocAccumulator fed blocks with different column kinds"
            );
        }
    }
}

/// Scalar value of row `r` of a column, as (continuous, categorical).
fn cell(data: &ColumnData, r: usize) -> (f64, u32) {
    match data {
        ColumnData::Continuous(v) => (v[r], 0),
        ColumnData::Categorical { codes, .. } => (0.0, codes[r]),
    }
}

fn bump_cat(counts: &mut Vec<u64>, code: u32) {
    if counts.len() <= code as usize {
        counts.resize(code as usize + 1, 0);
    }
    counts[code as usize] += 1;
}

/// Welford update of a per-category running mean.
fn bump_cat_mean(cats: &mut Vec<(u64, f64)>, code: u32, v: f64) {
    if cats.len() <= code as usize {
        cats.resize(code as usize + 1, (0, 0.0));
    }
    let (n, mean) = &mut cats[code as usize];
    *n += 1;
    *mean += (v - *mean) / *n as f64;
}

/// Merge two Welford (n, mean, m2) triples (Chan et al.). Every term is
/// written in a symmetric form (`x·a + y·b`, `(a + b) + t`), so the
/// merge is **commutative bit for bit** — swapping the argument triples
/// produces the identical f64s (IEEE `+`/`·`/negation commute exactly).
fn merge_moments(
    n1: u64,
    mean1: f64,
    m2_1: f64,
    n2: u64,
    mean2: f64,
    m2_2: f64,
) -> (u64, f64, f64) {
    if n2 == 0 {
        return (n1, mean1, m2_1);
    }
    if n1 == 0 {
        return (n2, mean2, m2_2);
    }
    let n = n1 + n2;
    let (n1f, n2f, nf) = (n1 as f64, n2 as f64, n as f64);
    let d = mean2 - mean1;
    let mean = (n1f * mean1 + n2f * mean2) / nf;
    let m2 = (m2_1 + m2_2) + d * d * (n1f * n2f / nf);
    (n, mean, m2)
}

impl MetricAccumulator for AssocAccumulator {
    type Output = FeatureProfile;

    fn observe_features(&mut self, rows: &FeatureTable) {
        self.ensure_layout(rows);
        let k = rows.n_cols();
        // per-row scratch of every column's cell, extracted once instead
        // of once per pair (k vs k² enum dispatches per row)
        let mut row_cells: Vec<(f64, u32)> = vec![(0.0, 0); k];
        for r in 0..rows.n_rows() {
            for (st, col) in self.cols.iter_mut().zip(&rows.columns) {
                match (st, &col.data) {
                    (ColStats::Cont { n, mean, m2, lo, hi }, ColumnData::Continuous(v)) => {
                        let x = v[r];
                        *n += 1;
                        let d = x - *mean;
                        *mean += d / *n as f64;
                        *m2 += d * (x - *mean);
                        if !x.is_nan() {
                            *lo = lo.min(x);
                            *hi = hi.max(x);
                        }
                    }
                    (ColStats::Cat { counts, .. }, ColumnData::Categorical { codes, .. }) => {
                        bump_cat(counts, codes[r]);
                    }
                    _ => unreachable!("layout checked in ensure_layout"),
                }
            }
            for (cell_slot, col) in row_cells.iter_mut().zip(&rows.columns) {
                *cell_slot = cell(&col.data, r);
            }
            let mut p = 0usize;
            for i in 0..k {
                for j in (i + 1)..k {
                    let (xi, ci) = row_cells[i];
                    let (xj, cj) = row_cells[j];
                    match &mut self.pairs[p] {
                        PairStats::ContCont { n, mx, my, mxx, myy, cxy } => {
                            *n += 1;
                            let nf = *n as f64;
                            let dx = xi - *mx;
                            *mx += dx / nf;
                            *mxx += dx * (xi - *mx);
                            let dy = xj - *my;
                            *my += dy / nf;
                            *myy += dy * (xj - *my);
                            *cxy += dx * (xj - *my);
                        }
                        PairStats::CatCont { cats, n, mean, m2, cat_first } => {
                            let (code, v) = if *cat_first { (ci, xj) } else { (cj, xi) };
                            bump_cat_mean(cats, code, v);
                            *n += 1;
                            let d = v - *mean;
                            *mean += d / *n as f64;
                            *m2 += d * (v - *mean);
                        }
                        PairStats::CatCat { joint } => {
                            *joint.entry((ci, cj)).or_insert(0) += 1;
                        }
                    }
                    p += 1;
                }
            }
        }
    }

    fn merge(&mut self, other: Self) {
        if !other.started {
            return;
        }
        if !self.started {
            *self = other;
            return;
        }
        assert_eq!(
            self.cols.len(),
            other.cols.len(),
            "AssocAccumulator merge across different column layouts"
        );
        for (a, b) in self.cols.iter_mut().zip(other.cols) {
            match (a, b) {
                (
                    ColStats::Cont { n, mean, m2, lo, hi },
                    ColStats::Cont { n: n2, mean: mean2, m2: m22, lo: lo2, hi: hi2 },
                ) => {
                    let (nn, nm, nm2) = merge_moments(*n, *mean, *m2, n2, mean2, m22);
                    (*n, *mean, *m2) = (nn, nm, nm2);
                    *lo = lo.min(lo2);
                    *hi = hi.max(hi2);
                }
                (ColStats::Cat { counts, .. }, ColStats::Cat { counts: c2, .. }) => {
                    if counts.len() < c2.len() {
                        counts.resize(c2.len(), 0);
                    }
                    for (a, b) in counts.iter_mut().zip(&c2) {
                        *a += b;
                    }
                }
                _ => panic!("AssocAccumulator merge across different column kinds"),
            }
        }
        for (a, b) in self.pairs.iter_mut().zip(other.pairs) {
            match (a, b) {
                (
                    PairStats::ContCont { n, mx, my, mxx, myy, cxy },
                    PairStats::ContCont {
                        n: n2,
                        mx: mx2,
                        my: my2,
                        mxx: mxx2,
                        myy: myy2,
                        cxy: cxy2,
                    },
                ) => {
                    if n2 == 0 {
                        continue;
                    }
                    if *n == 0 {
                        (*n, *mx, *my, *mxx, *myy, *cxy) = (n2, mx2, my2, mxx2, myy2, cxy2);
                        continue;
                    }
                    // symmetric forms: bit-commutative (see merge_moments)
                    let nt = *n + n2;
                    let (n1f, n2f, ntf) = (*n as f64, n2 as f64, nt as f64);
                    let dx = mx2 - *mx;
                    let dy = my2 - *my;
                    *mxx = (*mxx + mxx2) + dx * dx * (n1f * n2f / ntf);
                    *myy = (*myy + myy2) + dy * dy * (n1f * n2f / ntf);
                    *cxy = (*cxy + cxy2) + dx * dy * (n1f * n2f / ntf);
                    *mx = (n1f * *mx + n2f * mx2) / ntf;
                    *my = (n1f * *my + n2f * my2) / ntf;
                    *n = nt;
                }
                (
                    PairStats::CatCont { cats, n, mean, m2, .. },
                    PairStats::CatCont { cats: cats2, n: n2, mean: mean2, m2: m22, .. },
                ) => {
                    if cats.len() < cats2.len() {
                        cats.resize(cats2.len(), (0, 0.0));
                    }
                    for (a, b) in cats.iter_mut().zip(&cats2) {
                        let (nn, nm, _) = merge_moments(a.0, a.1, 0.0, b.0, b.1, 0.0);
                        *a = (nn, nm);
                    }
                    let (nn, nm, nm2) = merge_moments(*n, *mean, *m2, n2, mean2, m22);
                    (*n, *mean, *m2) = (nn, nm, nm2);
                }
                (PairStats::CatCat { joint }, PairStats::CatCat { joint: j2 }) => {
                    for (k, c) in j2 {
                        *joint.entry(k).or_insert(0) += c;
                    }
                }
                _ => panic!("AssocAccumulator merge across different pair kinds"),
            }
        }
    }

    fn finalize(self) -> FeatureProfile {
        let k = self.cols.len();
        let cols: Vec<ColSummary> = self
            .cols
            .into_iter()
            .map(|c| match c {
                ColStats::Cont { n, lo, hi, .. } => {
                    // match stats::min_max: empty / all-NaN input → (0, 0)
                    let (lo, hi) = if lo > hi { (0.0, 0.0) } else { (lo, hi) };
                    ColSummary::Continuous { n, lo, hi }
                }
                ColStats::Cat { counts, cardinality } => {
                    ColSummary::Categorical { counts, cardinality }
                }
            })
            .collect();
        let mut matrix = vec![0.0f64; k * k];
        let mut p = 0usize;
        for i in 0..k {
            matrix[i * k + i] = 1.0;
            for j in (i + 1)..k {
                let a = pair_association(&self.pairs[p]);
                matrix[i * k + j] = a;
                matrix[j * k + i] = a;
                p += 1;
            }
        }
        FeatureProfile { cols, matrix }
    }
}

/// Association of one finalized pair.
fn pair_association(pair: &PairStats) -> f64 {
    match pair {
        PairStats::ContCont { n, mxx, myy, cxy, .. } => {
            if *n < 2 || *mxx <= 0.0 || *myy <= 0.0 {
                0.0
            } else {
                (cxy / (mxx.sqrt() * myy.sqrt())).abs()
            }
        }
        PairStats::CatCont { cats, n, mean, m2, .. } => {
            if *n == 0 {
                return 0.0;
            }
            let between: f64 = cats
                .iter()
                .filter(|(nc, _)| *nc > 0)
                .map(|(nc, mc)| *nc as f64 * (mc - mean) * (mc - mean))
                .sum();
            if *m2 <= 0.0 {
                0.0
            } else {
                (between / m2).max(0.0).sqrt()
            }
        }
        PairStats::CatCat { joint } => {
            // symmetrized Theil's U from the exact joint counts
            0.5 * (theils_u_joint(joint, false) + theils_u_joint(joint, true))
        }
    }
}

/// Theil's U(x|y) from joint counts; `swap` computes U(y|x) instead.
/// Matches `stats::theils_u` (deterministic: BTreeMap iteration order).
fn theils_u_joint(joint: &BTreeMap<(u32, u32), u64>, swap: bool) -> f64 {
    let n: u64 = joint.values().sum();
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;
    let mut marg_x: BTreeMap<u32, u64> = BTreeMap::new();
    let mut marg_y: BTreeMap<u32, u64> = BTreeMap::new();
    for (&(a, b), &c) in joint {
        let (x, y) = if swap { (b, a) } else { (a, b) };
        *marg_x.entry(x).or_insert(0) += c;
        *marg_y.entry(y).or_insert(0) += c;
    }
    let hx: f64 = marg_x
        .values()
        .map(|&c| {
            let p = c as f64 / nf;
            -p * p.ln()
        })
        .sum();
    if hx <= 0.0 {
        return 1.0; // x is constant: fully determined
    }
    let mut hxy = 0.0;
    for (&(a, b), &c) in joint {
        let y = if swap { a } else { b };
        let pxy = c as f64 / nf;
        let py = marg_y[&y] as f64 / nf;
        hxy -= pxy * (pxy / py).ln();
    }
    ((hx - hxy) / hx).clamp(0.0, 1.0)
}

/// Finalized per-column summary inside a [`FeatureProfile`].
#[derive(Clone, Debug, PartialEq)]
pub enum ColSummary {
    /// Continuous column: row count and observed (NaN-ignoring) range.
    Continuous {
        /// Rows observed.
        n: u64,
        /// Smallest finite value (0 when nothing was observed).
        lo: f64,
        /// Largest finite value (0 when nothing was observed).
        hi: f64,
    },
    /// Categorical column: exact code histogram.
    Categorical {
        /// Count per code (grown past `cardinality` if codes exceed it).
        counts: Vec<u64>,
        /// Declared cardinality of the column.
        cardinality: u32,
    },
}

/// Finalized one-pass summary of a feature table: the association
/// matrix plus the per-column ranges / marginals the other feature
/// metrics need. Produced by [`AssocAccumulator::finalize`].
#[derive(Clone, Debug, Default)]
pub struct FeatureProfile {
    cols: Vec<ColSummary>,
    matrix: Vec<f64>,
}

impl FeatureProfile {
    /// Profile an in-memory table (single-block accumulation).
    pub fn of(t: &FeatureTable) -> FeatureProfile {
        let mut acc = AssocAccumulator::new();
        acc.observe_features(t);
        acc.finalize()
    }

    /// Number of profiled columns.
    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    /// Row-major k×k pairwise association matrix (diagonal = 1).
    pub fn matrix(&self) -> &[f64] {
        &self.matrix
    }

    /// Per-column summary.
    pub fn column(&self, i: usize) -> &ColSummary {
        &self.cols[i]
    }

    /// Observed (lo, hi) range of column `i`, or `None` for categorical
    /// columns.
    pub fn range(&self, i: usize) -> Option<(f64, f64)> {
        match &self.cols[i] {
            ColSummary::Continuous { lo, hi, .. } => Some((*lo, *hi)),
            ColSummary::Categorical { .. } => None,
        }
    }
}

/// Pairwise association matrix (row-major k×k, diagonal = 1) — thin
/// wrapper over [`AssocAccumulator`].
pub fn association_matrix(t: &FeatureTable) -> Vec<f64> {
    FeatureProfile::of(t).matrix.clone()
}

/// Phase-2 accumulator for the single-continuous-column marginal: a
/// fixed-range histogram (the range comes from the two tables' phase-1
/// profiles). Counts are exact, so `merge` is bit-exact in any order.
#[derive(Clone, Debug)]
pub struct MarginalAccumulator {
    col: usize,
    lo: f64,
    hi: f64,
    hist: Vec<f64>,
}

impl MarginalAccumulator {
    /// Histogram of column `col` over `[lo, hi]` with 32 bins (binning
    /// identical to `stats::histogram`).
    pub fn new(col: usize, lo: f64, hi: f64) -> MarginalAccumulator {
        MarginalAccumulator { col, lo, hi, hist: vec![0.0; MARGINAL_BINS] }
    }
}

impl MetricAccumulator for MarginalAccumulator {
    type Output = Vec<f64>;

    fn observe_features(&mut self, rows: &FeatureTable) {
        let ColumnData::Continuous(v) = &rows.columns[self.col].data else {
            panic!("MarginalAccumulator over a categorical column");
        };
        if self.hi <= self.lo {
            self.hist[0] += v.len() as f64;
            return;
        }
        let bins = self.hist.len();
        let w = (self.hi - self.lo) / bins as f64;
        for &x in v {
            let b = (((x - self.lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
            self.hist[b] += 1.0;
        }
    }

    fn merge(&mut self, other: Self) {
        for (a, b) in self.hist.iter_mut().zip(&other.hist) {
            *a += b;
        }
    }

    fn finalize(self) -> Vec<f64> {
        self.hist
    }
}

/// "Feature Corr. ↑": 1 − mean |Δassociation| over off-diagonal pairs,
/// in [0, 1]. Tables must have the same column layout. Single-column
/// tables fall back to marginal similarity (1 − JS distance of the
/// column's histogram). Thin wrapper over the streaming profiles.
pub fn feature_corr_score(orig: &FeatureTable, synth: &FeatureTable) -> f64 {
    feature_corr_with(&FeatureProfile::of(orig), &FeatureProfile::of(synth), orig, synth)
}

/// [`feature_corr_score`] over precomputed profiles (the raw tables are
/// only touched on the single-continuous-column fallback, which needs a
/// second histogram pass over the shared range).
pub fn feature_corr_with(
    a: &FeatureProfile,
    b: &FeatureProfile,
    orig: &FeatureTable,
    synth: &FeatureTable,
) -> f64 {
    let k = a.n_cols();
    if k == 0 || b.n_cols() != k {
        return 0.0;
    }
    if k == 1 {
        return match (a.column(0), b.column(0)) {
            (
                ColSummary::Continuous { lo: lo1, hi: hi1, .. },
                ColSummary::Continuous { lo: lo2, hi: hi2, .. },
            ) => {
                let (lo, hi) = (lo1.min(*lo2), hi1.max(*hi2));
                let ha = {
                    let mut m = MarginalAccumulator::new(0, lo, hi);
                    m.observe_features(orig);
                    m.finalize()
                };
                let hb = {
                    let mut m = MarginalAccumulator::new(0, lo, hi);
                    m.observe_features(synth);
                    m.finalize()
                };
                1.0 - stats::js_distance(&ha, &hb)
            }
            (
                ColSummary::Categorical { counts: ca, cardinality: k1 },
                ColSummary::Categorical { counts: cb, cardinality: k2 },
            ) => {
                let len = (*k1).max(*k2).max(ca.len() as u32).max(cb.len() as u32).max(1)
                    as usize;
                let mut ha = vec![0.0; len];
                let mut hb = vec![0.0; len];
                for (i, &c) in ca.iter().enumerate() {
                    ha[i] = c as f64;
                }
                for (i, &c) in cb.iter().enumerate() {
                    hb[i] = c as f64;
                }
                1.0 - stats::js_distance(&ha, &hb)
            }
            _ => 0.0,
        };
    }
    let (mo, ms) = (a.matrix(), b.matrix());
    let mut diff = 0.0;
    let mut count = 0;
    for i in 0..k {
        for j in (i + 1)..k {
            diff += (mo[i * k + j] - ms[i * k + j]).abs();
            count += 1;
        }
    }
    (1.0 - diff / count as f64).clamp(0.0, 1.0)
}

/// 1 − JS distance between the marginal distributions of two columns
/// (the single-column fallback of [`feature_corr_score`], kept for
/// direct use).
pub fn marginal_similarity(a: &ColumnData, b: &ColumnData) -> f64 {
    let ta = FeatureTable::new(vec![crate::featgen::table::Column {
        name: "a".into(),
        data: a.clone(),
    }])
    .unwrap();
    let tb = FeatureTable::new(vec![crate::featgen::table::Column {
        name: "b".into(),
        data: b.clone(),
    }])
    .unwrap();
    feature_corr_score(&ta, &tb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::util::rng::Pcg64;

    fn correlated(n: usize, seed: u64) -> FeatureTable {
        let mut rng = Pcg64::new(seed);
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut c = Vec::new();
        for _ in 0..n {
            let x = rng.normal();
            a.push(x);
            b.push(2.0 * x + rng.normal() * 0.2);
            c.push(if x > 0.0 { 1u32 } else { 0 });
        }
        FeatureTable::new(vec![
            Column::continuous("a", a),
            Column::continuous("b", b),
            Column::categorical("c", c),
        ])
        .unwrap()
    }

    fn independent(n: usize, seed: u64) -> FeatureTable {
        let mut rng = Pcg64::new(seed);
        FeatureTable::new(vec![
            Column::continuous("a", (0..n).map(|_| rng.normal()).collect()),
            Column::continuous("b", (0..n).map(|_| rng.normal()).collect()),
            Column::categorical("c", (0..n).map(|_| rng.below(2) as u32).collect()),
        ])
        .unwrap()
    }

    #[test]
    fn same_process_scores_high() {
        let s = feature_corr_score(&correlated(2000, 1), &correlated(2000, 2));
        assert!(s > 0.95, "s={s}");
    }

    #[test]
    fn independent_vs_correlated_scores_lower() {
        let high = feature_corr_score(&correlated(2000, 1), &correlated(2000, 2));
        let low = feature_corr_score(&correlated(2000, 1), &independent(2000, 3));
        assert!(low < high, "low={low} high={high}");
        assert!(low < 0.75, "low={low}");
    }

    #[test]
    fn association_matrix_symmetric_unit_diag() {
        let t = correlated(500, 4);
        let m = association_matrix(&t);
        let k = t.n_cols();
        for i in 0..k {
            assert!((m[i * k + i] - 1.0).abs() < 1e-12);
            for j in 0..k {
                assert!((m[i * k + j] - m[j * k + i]).abs() < 1e-12);
            }
        }
        // a-b strongly associated
        assert!(m[1] > 0.9, "m01={}", m[1]);
    }

    #[test]
    fn single_column_marginal_fallback() {
        let mut rng = Pcg64::new(9);
        let xs: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let a = FeatureTable::new(vec![Column::continuous("x", xs)]).unwrap();
        let b = FeatureTable::new(vec![Column::continuous("x", ys)]).unwrap();
        let s = feature_corr_score(&a, &b);
        assert!(s > 0.7, "s={s}");
        // shifted distribution scores lower
        let zs: Vec<f64> = (0..2000).map(|_| rng.normal_ms(4.0, 1.0)).collect();
        let c = FeatureTable::new(vec![Column::continuous("x", zs)]).unwrap();
        assert!(feature_corr_score(&a, &c) < s);
    }

    #[test]
    fn layout_mismatch_scores_zero() {
        let a = correlated(100, 1);
        let b = FeatureTable::new(vec![Column::continuous("x", vec![0.0; 100])]).unwrap();
        assert_eq!(feature_corr_score(&a, &b), 0.0);
    }

    #[test]
    fn sequential_chunking_is_bit_exact() {
        // observing row blocks into one accumulator == observing whole
        let t = correlated(1500, 6);
        let whole = FeatureProfile::of(&t);
        let mut acc = AssocAccumulator::new();
        for lo in [0usize, 400, 900] {
            let hi = match lo {
                0 => 400,
                400 => 900,
                _ => t.n_rows(),
            };
            let idx: Vec<usize> = (lo..hi).collect();
            acc.observe_features(&t.gather(&idx));
        }
        let chunked = acc.finalize();
        for (a, b) in whole.matrix().iter().zip(chunked.matrix()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn merge_is_commutative_and_near_associative() {
        let t = correlated(1200, 7);
        let blocks: Vec<FeatureTable> = [(0usize, 300usize), (300, 700), (700, 1200)]
            .iter()
            .map(|&(lo, hi)| t.gather(&(lo..hi).collect::<Vec<usize>>()))
            .collect();
        let part = |b: &FeatureTable| {
            let mut a = AssocAccumulator::new();
            a.observe_features(b);
            a
        };
        // commutativity: bit-exact
        let mut ab = part(&blocks[0]);
        ab.merge(part(&blocks[1]));
        let mut ba = part(&blocks[1]);
        ba.merge(part(&blocks[0]));
        for (x, y) in ab.clone().finalize().matrix().iter().zip(ba.finalize().matrix()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // associativity: mathematically equal, up to f64 rounding
        ab.merge(part(&blocks[2]));
        let mut bc = part(&blocks[1]);
        bc.merge(part(&blocks[2]));
        let mut a_bc = part(&blocks[0]);
        a_bc.merge(bc);
        let whole = FeatureProfile::of(&t);
        for ((x, y), w) in ab
            .finalize()
            .matrix()
            .iter()
            .zip(a_bc.finalize().matrix())
            .zip(whole.matrix())
        {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            assert!((x - w).abs() < 1e-9, "{x} vs whole {w}");
        }
    }

    #[test]
    fn profile_ranges_match_min_max() {
        let t = correlated(500, 8);
        let p = FeatureProfile::of(&t);
        let (lo, hi) = stats::min_max(t.columns[0].as_continuous());
        assert_eq!(p.range(0), Some((lo, hi)));
        assert_eq!(p.range(2), None);
    }
}
