//! Feature-correlation similarity ("Feature Corr. ↑", paper §4.3).
//!
//! Builds the pairwise association matrix of a feature table — Pearson
//! between continuous pairs, correlation ratio between categorical and
//! continuous, Theil's U between categorical pairs — and scores a
//! synthetic table by 1 − mean |assoc_orig − assoc_synth|.

use crate::featgen::table::{ColumnData, FeatureTable};
use crate::util::stats;

/// Pairwise association matrix (row-major k×k, diagonal = 1).
pub fn association_matrix(t: &FeatureTable) -> Vec<f64> {
    let k = t.n_cols();
    let mut m = vec![0.0f64; k * k];
    for i in 0..k {
        m[i * k + i] = 1.0;
        for j in (i + 1)..k {
            let a = association(&t.columns[i].data, &t.columns[j].data);
            m[i * k + j] = a;
            m[j * k + i] = a;
        }
    }
    m
}

fn association(a: &ColumnData, b: &ColumnData) -> f64 {
    match (a, b) {
        (ColumnData::Continuous(x), ColumnData::Continuous(y)) => stats::pearson(x, y).abs(),
        (ColumnData::Categorical { codes, .. }, ColumnData::Continuous(y)) => {
            let cats: Vec<usize> = codes.iter().map(|&c| c as usize).collect();
            stats::correlation_ratio(&cats, y)
        }
        (ColumnData::Continuous(x), ColumnData::Categorical { codes, .. }) => {
            let cats: Vec<usize> = codes.iter().map(|&c| c as usize).collect();
            stats::correlation_ratio(&cats, x)
        }
        (
            ColumnData::Categorical { codes: ca, .. },
            ColumnData::Categorical { codes: cb, .. },
        ) => {
            let xa: Vec<usize> = ca.iter().map(|&c| c as usize).collect();
            let xb: Vec<usize> = cb.iter().map(|&c| c as usize).collect();
            // symmetrized Theil's U
            0.5 * (stats::theils_u(&xa, &xb) + stats::theils_u(&xb, &xa))
        }
    }
}

/// "Feature Corr. ↑": 1 − mean |Δassociation| over off-diagonal pairs,
/// in [0, 1]. Tables must have the same column layout. Single-column
/// tables fall back to marginal similarity (1 − JS distance of the
/// column's histogram).
pub fn feature_corr_score(orig: &FeatureTable, synth: &FeatureTable) -> f64 {
    let k = orig.n_cols();
    if k == 0 || synth.n_cols() != k {
        return 0.0;
    }
    if k == 1 {
        return marginal_similarity(&orig.columns[0].data, &synth.columns[0].data);
    }
    let mo = association_matrix(orig);
    let ms = association_matrix(synth);
    let mut diff = 0.0;
    let mut count = 0;
    for i in 0..k {
        for j in (i + 1)..k {
            diff += (mo[i * k + j] - ms[i * k + j]).abs();
            count += 1;
        }
    }
    (1.0 - diff / count as f64).clamp(0.0, 1.0)
}

/// 1 − JS distance between the marginal distributions of two columns.
pub fn marginal_similarity(a: &ColumnData, b: &ColumnData) -> f64 {
    match (a, b) {
        (ColumnData::Continuous(x), ColumnData::Continuous(y)) => {
            let (lo1, hi1) = stats::min_max(x);
            let (lo2, hi2) = stats::min_max(y);
            let (lo, hi) = (lo1.min(lo2), hi1.max(hi2));
            let ha = stats::histogram(x, lo, hi, 32);
            let hb = stats::histogram(y, lo, hi, 32);
            1.0 - stats::js_distance(&ha, &hb)
        }
        (ColumnData::Categorical { codes: ca, cardinality: k1 },
         ColumnData::Categorical { codes: cb, cardinality: k2 }) => {
            let k = (*k1).max(*k2) as usize;
            let mut ha = vec![0.0; k.max(1)];
            let mut hb = vec![0.0; k.max(1)];
            for &c in ca {
                ha[c as usize] += 1.0;
            }
            for &c in cb {
                hb[c as usize] += 1.0;
            }
            1.0 - stats::js_distance(&ha, &hb)
        }
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::util::rng::Pcg64;

    fn correlated(n: usize, seed: u64) -> FeatureTable {
        let mut rng = Pcg64::new(seed);
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut c = Vec::new();
        for _ in 0..n {
            let x = rng.normal();
            a.push(x);
            b.push(2.0 * x + rng.normal() * 0.2);
            c.push(if x > 0.0 { 1u32 } else { 0 });
        }
        FeatureTable::new(vec![
            Column::continuous("a", a),
            Column::continuous("b", b),
            Column::categorical("c", c),
        ])
        .unwrap()
    }

    fn independent(n: usize, seed: u64) -> FeatureTable {
        let mut rng = Pcg64::new(seed);
        FeatureTable::new(vec![
            Column::continuous("a", (0..n).map(|_| rng.normal()).collect()),
            Column::continuous("b", (0..n).map(|_| rng.normal()).collect()),
            Column::categorical("c", (0..n).map(|_| rng.below(2) as u32).collect()),
        ])
        .unwrap()
    }

    #[test]
    fn same_process_scores_high() {
        let s = feature_corr_score(&correlated(2000, 1), &correlated(2000, 2));
        assert!(s > 0.95, "s={s}");
    }

    #[test]
    fn independent_vs_correlated_scores_lower() {
        let high = feature_corr_score(&correlated(2000, 1), &correlated(2000, 2));
        let low = feature_corr_score(&correlated(2000, 1), &independent(2000, 3));
        assert!(low < high, "low={low} high={high}");
        assert!(low < 0.75, "low={low}");
    }

    #[test]
    fn association_matrix_symmetric_unit_diag() {
        let t = correlated(500, 4);
        let m = association_matrix(&t);
        let k = t.n_cols();
        for i in 0..k {
            assert!((m[i * k + i] - 1.0).abs() < 1e-12);
            for j in 0..k {
                assert!((m[i * k + j] - m[j * k + i]).abs() < 1e-12);
            }
        }
        // a-b strongly associated
        assert!(m[1] > 0.9, "m01={}", m[1]);
    }

    #[test]
    fn single_column_marginal_fallback() {
        let mut rng = Pcg64::new(9);
        let xs: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let a = FeatureTable::new(vec![Column::continuous("x", xs)]).unwrap();
        let b = FeatureTable::new(vec![Column::continuous("x", ys)]).unwrap();
        let s = feature_corr_score(&a, &b);
        assert!(s > 0.7, "s={s}");
        // shifted distribution scores lower
        let zs: Vec<f64> = (0..2000).map(|_| rng.normal_ms(4.0, 1.0)).collect();
        let c = FeatureTable::new(vec![Column::continuous("x", zs)]).unwrap();
        assert!(feature_corr_score(&a, &c) < s);
    }

    #[test]
    fn layout_mismatch_scores_zero() {
        let a = correlated(100, 1);
        let b = FeatureTable::new(vec![Column::continuous("x", vec![0.0; 100])]).unwrap();
        assert_eq!(feature_corr_score(&a, &b), 0.0);
    }
}
