//! Shard-scale streaming evaluation: score `ShardSink` output straight
//! from disk — without materializing the graph — and tap in-flight
//! generation so a quality report falls out of a streamed run for free.
//!
//! Two entry points:
//!
//! * [`evaluate_shards`] — the `sgg eval --shards DIR` path. Shards are
//!   read chunk-by-chunk on the parallel runner's worker pool (each
//!   worker folds its shard range into a private
//!   [`DegreeAccumulator`]), partials merge deterministically, and the
//!   finalized profile is scored against the original. Because degree
//!   accumulators are integer-count-based, the result is **bit-for-bit
//!   identical** to the in-memory `metrics` scores for any worker count
//!   and any shard count, while peak memory stays bounded by one shard
//!   (plus the O(nodes) degree arrays) instead of the edge count.
//! * [`GenerationTap`] / [`TappedSink`] — wrap any
//!   [`Sink`](crate::pipeline::Sink) so chunks are observed as they
//!   stream past; a shard run then carries a [`StructuralReport`] in its
//!   [`StreamReport`](crate::pipeline::StreamReport) at near-zero extra
//!   memory (the accumulator's degree arrays only).
//!
//! Shards carry structure only (the paper's out-of-core path never
//! materializes features), so the streamed scores are the *structural*
//! metrics — the Table 2 degree column plus the DCC of eq. 20; they
//! reproduce `metrics::evaluate`'s `degree_dist` exactly. Feature
//! metrics need the in-memory path (`sgg evaluate`).

use super::accum::MetricAccumulator;
use super::degree::{self, DegreeAccumulator, DegreeProfile};
use crate::graph::io::ShardReader;
use crate::graph::EdgeList;
use crate::pipeline::fault::{FaultPlan, FaultReader, RetryPolicy};
use crate::pipeline::parallel::ParallelChunkRunner;
use crate::pipeline::sink::{Sink, SinkFinish};
use crate::structgen::chunked::Chunk;
use crate::util::json::Json;
use crate::Result;
use std::path::Path;

/// DCC sample count used by the streamed reports (eq. 20's K).
pub const DCC_SAMPLES: usize = 16;

/// What one pass over a shard directory saw (sizes only — the scores
/// live in [`ShardEvalReport`]).
#[derive(Clone, Copy, Debug)]
pub struct ShardScan {
    /// Number of shard files.
    pub shards: usize,
    /// Total edges across all shards (from the validated headers).
    pub edges: u64,
    /// Largest single shard's edge count — the resident-chunk bound of
    /// the streamed pass.
    pub peak_shard_edges: u64,
}

/// Build the degree profile of a sharded graph by streaming its shards,
/// chunk by chunk, on `workers` threads (contiguous shard ranges per
/// worker, one private accumulator each, merged in worker order).
/// Exact: the profile equals the one an in-memory pass would produce,
/// for any worker or shard count.
pub fn profile_shards(dir: &Path, workers: usize) -> Result<(DegreeProfile, ShardScan)> {
    profile_shards_with(dir, workers, None, RetryPolicy::default())
}

/// [`profile_shards`] with explicit robustness knobs: shard reads go
/// through a [`FaultReader`], which injects the fault plan's scheduled
/// transient read faults (if any) and retries transient failures —
/// injected or real — under `retry`. The profile is unchanged by any
/// recovered fault: retries re-read the same immutable shard.
pub fn profile_shards_with(
    dir: &Path,
    workers: usize,
    faults: Option<FaultPlan>,
    retry: RetryPolicy,
) -> Result<(DegreeProfile, ShardScan)> {
    profile_reader_with(&ShardReader::open(dir)?, workers, faults, retry)
}

/// [`profile_shards_with`] over an already-opened [`ShardReader`] — the
/// shared core of single-directory, multi-directory (unmerged
/// distributed output), and host-report profiling.
pub fn profile_reader_with(
    reader: &ShardReader,
    workers: usize,
    faults: Option<FaultPlan>,
    retry: RetryPolicy,
) -> Result<(DegreeProfile, ShardScan)> {
    let scan = ShardScan {
        shards: reader.len(),
        edges: reader.total_edges(),
        peak_shard_edges: reader.max_shard_edges(),
    };
    let faulted = FaultReader::new(reader, faults, retry);
    let runner = ParallelChunkRunner::new(workers.max(1), 1);
    // Per-worker state: accumulator + reusable decode buffers, so the
    // hot loop allocates nothing once the largest shard has been seen.
    let partials = runner.fold_indices(
        faulted.len(),
        |_worker| {
            (
                DegreeAccumulator::with_spec(reader.spec()),
                Vec::new(),
                EdgeList::new(reader.spec()),
            )
        },
        |(acc, scratch, buf), i| {
            faulted.read_into(i, scratch, buf)?;
            acc.observe_edges(buf);
            Ok(())
        },
    )?;
    let mut acc = DegreeAccumulator::with_spec(reader.spec());
    for (p, _, _) in partials {
        acc.merge(p);
    }
    Ok((acc.finalize(), scan))
}

/// Streamed evaluation result of a shard directory against an original.
#[derive(Clone, Copy, Debug)]
pub struct ShardEvalReport {
    /// "Degree Dist. ↑" of Table 2 — bit-identical to the in-memory
    /// `metrics::evaluate` value on the same graphs.
    pub degree_dist: f64,
    /// Degree Comparison Coefficient of eq. 20 (higher is better).
    pub dcc: f64,
    /// Total synthetic edges evaluated.
    pub edges: u64,
    /// Number of shards read.
    pub shards: usize,
    /// Largest single shard (edges) — the streamed pass's resident
    /// chunk bound.
    pub peak_shard_edges: u64,
    /// Bytes held by the finalized degree profile (O(nodes), not edges).
    pub profile_bytes: u64,
}

impl std::fmt::Display for ShardEvalReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "degree_dist={:.4} dcc={:.4} over {} edges in {} shards \
             (peak shard {} edges, degree profile {} bytes)",
            self.degree_dist,
            self.dcc,
            self.edges,
            self.shards,
            self.peak_shard_edges,
            self.profile_bytes
        )
    }
}

impl ShardEvalReport {
    /// Canonical JSON form (`sgg eval --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("degree_dist", Json::from(self.degree_dist)),
            ("dcc", Json::from(self.dcc)),
            ("edges", Json::u64_exact(self.edges)),
            ("shards", Json::from(self.shards)),
            ("peak_shard_edges", Json::u64_exact(self.peak_shard_edges)),
            ("profile_bytes", Json::u64_exact(self.profile_bytes)),
        ])
    }
}

/// Evaluate `ShardSink` output against an original degree profile
/// without materializing the synthetic graph. See the module docs for
/// the exactness and memory contract.
pub fn evaluate_shards(
    dir: &Path,
    orig: &DegreeProfile,
    workers: usize,
) -> Result<ShardEvalReport> {
    evaluate_shard_dirs(std::slice::from_ref(&dir.to_path_buf()), orig, workers)
}

/// [`evaluate_shards`] over several shard directories treated as one
/// logical graph — the unmerged per-host output of a distributed run.
/// Shards are ordered by file name across the directories (chunk-index
/// order), so the scores are bit-identical to evaluating the merged
/// directory.
pub fn evaluate_shard_dirs(
    dirs: &[std::path::PathBuf],
    orig: &DegreeProfile,
    workers: usize,
) -> Result<ShardEvalReport> {
    let reader = ShardReader::open_dirs(dirs)?;
    let (synth, scan) =
        profile_reader_with(&reader, workers.max(1), None, RetryPolicy::default())?;
    Ok(ShardEvalReport {
        degree_dist: degree::degree_dist_score_profiles(orig, &synth),
        dcc: degree::dcc_profiles(orig, &synth, DCC_SAMPLES),
        edges: scan.edges,
        shards: scan.shards,
        peak_shard_edges: scan.peak_shard_edges,
        profile_bytes: (synth.out_degrees().len() + synth.in_degrees().len()) as u64 * 4,
    })
}

/// The structure-only quality scores a streamed run can compute while
/// generating (features are never materialized on the shard path).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StructuralReport {
    /// "Degree Dist. ↑" of Table 2 against the fit source.
    pub degree_dist: f64,
    /// Degree Comparison Coefficient of eq. 20.
    pub dcc: f64,
}

impl std::fmt::Display for StructuralReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "degree_dist={:.4} dcc={:.4}", self.degree_dist, self.dcc)
    }
}

impl StructuralReport {
    /// Canonical JSON form (the `quality` object of a
    /// [`StreamReport`](crate::pipeline::StreamReport)'s JSON document).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("degree_dist", Json::from(self.degree_dist)),
            ("dcc", Json::from(self.dcc)),
        ])
    }

    /// Parse the canonical JSON form.
    pub fn from_json(doc: &Json) -> Result<StructuralReport> {
        Ok(StructuralReport {
            degree_dist: doc.req_f64("degree_dist")?,
            dcc: doc.req_f64("dcc")?,
        })
    }
}

/// Observes generated structure chunks as they stream past and scores
/// the finished graph against an original profile — the metrics "tap"
/// behind `[evaluate]` scenario runs. Memory cost: the synthetic degree
/// arrays (O(nodes)); every chunk is observed and dropped.
pub struct GenerationTap {
    orig: DegreeProfile,
    synth: DegreeAccumulator,
}

impl GenerationTap {
    /// Tap scoring against the original edge list (profiled here, once).
    pub fn new(orig_edges: &EdgeList) -> GenerationTap {
        GenerationTap::with_profile(DegreeProfile::of(orig_edges))
    }

    /// Tap scoring against an already-computed original profile.
    pub fn with_profile(orig: DegreeProfile) -> GenerationTap {
        GenerationTap { orig, synth: DegreeAccumulator::new() }
    }

    /// Observe one generated structure chunk.
    pub fn observe(&mut self, chunk: &EdgeList) {
        self.synth.observe_edges(chunk);
    }

    /// Score everything observed so far against the original.
    pub fn report(&self) -> StructuralReport {
        let synth = self.synth.clone().finalize();
        StructuralReport {
            degree_dist: degree::degree_dist_score_profiles(&self.orig, &synth),
            dcc: degree::dcc_profiles(&self.orig, &synth, DCC_SAMPLES),
        }
    }
}

/// A [`Sink`] adapter that feeds every chunk through a [`GenerationTap`]
/// before forwarding it, and attaches the tap's [`StructuralReport`] to
/// the run's [`StreamReport`](crate::pipeline::StreamReport) at finish
/// time. In-memory (collected) runs pass through untouched — their
/// full [`QualityReport`](super::QualityReport) is computed after
/// feature assembly instead.
pub struct TappedSink<'a> {
    inner: &'a mut dyn Sink,
    tap: GenerationTap,
}

impl<'a> TappedSink<'a> {
    /// Wrap `inner`, observing every chunk with `tap`.
    pub fn new(inner: &'a mut dyn Sink, tap: GenerationTap) -> TappedSink<'a> {
        TappedSink { inner, tap }
    }
}

impl Sink for TappedSink<'_> {
    fn name(&self) -> &'static str {
        "tapped"
    }

    fn edges(&mut self, chunk: &mut Chunk) -> Result<()> {
        self.tap.observe(&chunk.edges);
        self.inner.edges(chunk)
    }

    fn finish(&mut self) -> Result<SinkFinish> {
        match self.inner.finish()? {
            SinkFinish::Streamed(mut report) => {
                report.quality = Some(self.tap.report());
                Ok(SinkFinish::Streamed(report))
            }
            collected => Ok(collected),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{io, PartiteSpec};
    use crate::pipeline::sink::ShardSink;
    use crate::structgen::chunked::ChunkConfig;
    use crate::util::rng::Pcg64;
    use std::path::PathBuf;

    fn random_graph(seed: u64, n: u64, m: usize) -> EdgeList {
        let mut rng = Pcg64::new(seed);
        let mut e = EdgeList::new(PartiteSpec::square(n));
        for _ in 0..m {
            e.push(rng.below(n), rng.below(n));
        }
        e
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_stream_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    /// Split `edges` into `k` near-equal shards on disk.
    fn write_shards(dir: &Path, edges: &EdgeList, k: usize) {
        let per = edges.len().div_ceil(k);
        for (i, start) in (0..edges.len()).step_by(per.max(1)).enumerate() {
            let mut chunk = EdgeList::new(edges.spec);
            for j in start..(start + per).min(edges.len()) {
                chunk.push(edges.src[j], edges.dst[j]);
            }
            io::write_binary(&dir.join(format!("shard-{i:05}.sgg")), &chunk).unwrap();
        }
    }

    #[test]
    fn shard_eval_exact_for_any_workers_and_shard_counts() {
        let orig = random_graph(1, 256, 6_000);
        let synth = random_graph(2, 256, 6_000);
        let orig_prof = DegreeProfile::of(&orig);
        let expected = degree::degree_dist_score(&orig, &synth);
        let expected_dcc = degree::dcc(&orig, &synth, DCC_SAMPLES);
        for shards in [1usize, 3, 8] {
            let dir = tmp_dir(&format!("exact{shards}"));
            write_shards(&dir, &synth, shards);
            for workers in [1usize, 2, 5] {
                let r = evaluate_shards(&dir, &orig_prof, workers).unwrap();
                assert_eq!(
                    r.degree_dist.to_bits(),
                    expected.to_bits(),
                    "shards={shards} workers={workers}"
                );
                assert_eq!(r.dcc.to_bits(), expected_dcc.to_bits());
                assert_eq!(r.edges, synth.len() as u64);
                assert_eq!(r.shards, std::fs::read_dir(&dir).unwrap().count());
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn faulted_profile_is_bit_identical_to_clean() {
        use crate::pipeline::fault::{FaultPlan, RetryPolicy};
        let synth = random_graph(9, 128, 4_000);
        let dir = tmp_dir("faultprof");
        write_shards(&dir, &synth, 6);
        let (clean, _) = profile_shards(&dir, 3).unwrap();
        let plan = FaultPlan { read_rate: 400, max_faulty_attempts: 1, ..FaultPlan::transient(5) };
        let (faulted, scan) =
            profile_shards_with(&dir, 3, Some(plan), RetryPolicy::default()).unwrap();
        assert_eq!(clean.out_degrees(), faulted.out_degrees());
        assert_eq!(clean.in_degrees(), faulted.in_degrees());
        assert_eq!(scan.edges, synth.len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_is_data_error() {
        let dir = tmp_dir("empty");
        let err = profile_shards(&dir, 2).unwrap_err();
        assert!(err.to_string().contains("no shards"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tapped_shard_sink_attaches_quality() {
        let orig = random_graph(3, 128, 2_000);
        let synth = random_graph(4, 128, 2_000);
        let dir = tmp_dir("tap");
        let cfg = ChunkConfig {
            prefix_levels: 1,
            workers: 1,
            queue_capacity: 2,
            ..ChunkConfig::default()
        };
        let mut sink = ShardSink::new(&dir, cfg).unwrap();
        let mut tapped = TappedSink::new(&mut sink, GenerationTap::new(&orig));
        // feed the synthetic graph as three chunks
        let cuts = [0usize, 700, 1_400, synth.len()];
        for (i, w) in cuts.windows(2).enumerate() {
            let mut chunk = EdgeList::new(synth.spec);
            for j in w[0]..w[1] {
                chunk.push(synth.src[j], synth.dst[j]);
            }
            tapped
                .edges(&mut Chunk {
                    index: i,
                    worker: 0,
                    sample_secs: 0.0,
                    encode_secs: 0.0,
                    edges: chunk,
                    encoded: None,
                })
                .unwrap();
        }
        let report = match tapped.finish().unwrap() {
            SinkFinish::Streamed(r) => r,
            SinkFinish::Collected(_) => panic!("shard sink collected"),
        };
        let q = report.quality.expect("tap attached no quality");
        let expected = degree::degree_dist_score(&orig, &synth);
        assert_eq!(q.degree_dist.to_bits(), expected.to_bits());
        // the report prints its quality
        assert!(report.to_string().contains("degree_dist"), "{report}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
