//! Degree-distribution similarity metrics.
//!
//! * [`degree_dist_score`] — the "Degree Dist. ↑" column of Table 2:
//!   1 − JS-distance between log-binned, normalized degree distributions
//!   of the two graphs (both sides averaged: in + out). Sizes may differ —
//!   degrees are normalized by each graph's max degree first, matching
//!   the paper's requirement to compare graphs of different scales.
//! * [`dcc`] — the scalar Degree Comparison Coefficient of eq. 20/21.
//! * [`power_law_alpha`] — MLE power-law exponent (Table 10 column).
//!
//! Both scores are pure functions of the two graphs' per-node degree
//! counts, which [`DegreeAccumulator`] gathers in one streaming pass
//! (exactly mergeable — integer counts; see [`super::accum`]). The
//! `_profiles` variants score finalized [`DegreeProfile`]s directly so
//! callers that need several degree metrics (or stream edges chunk by
//! chunk) derive the degree vectors once and share them.

use super::accum::MetricAccumulator;
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::checksum::Fnv1a;
use crate::util::stats;
use crate::{Error, Result};

/// Number of logarithmic bins used by the scores.
const LOG_BINS: usize = 24;

/// Streaming per-node degree counter: one pass over any chunking of the
/// edge stream, `merge` adds counts elementwise (exact — associative and
/// commutative bit for bit). The partite spec is adopted from the first
/// observed chunk; every chunk must carry the same spec.
#[derive(Clone, Debug, Default)]
pub struct DegreeAccumulator {
    spec: Option<PartiteSpec>,
    out: Vec<u32>,
    in_: Vec<u32>,
    edges: u64,
}

impl DegreeAccumulator {
    /// Empty accumulator; the node space is sized from the first chunk.
    pub fn new() -> DegreeAccumulator {
        DegreeAccumulator::default()
    }

    /// Accumulator with the node space pre-sized.
    pub fn with_spec(spec: PartiteSpec) -> DegreeAccumulator {
        let mut a = DegreeAccumulator::new();
        a.ensure_spec(spec);
        a
    }

    /// Total edges observed so far.
    pub fn edges_observed(&self) -> u64 {
        self.edges
    }

    /// Rebuild an accumulator from serialized per-node counts (e.g. a
    /// host report from a distributed run) so partials computed on other
    /// machines can be folded with the same exact [`MetricAccumulator`]
    /// merges as in-process partials. The vector lengths must match the
    /// spec's node counts.
    pub fn from_counts(
        spec: PartiteSpec,
        out: Vec<u32>,
        in_: Vec<u32>,
        edges: u64,
    ) -> Result<DegreeAccumulator> {
        if out.len() != spec.n_src as usize || in_.len() != spec.n_dst as usize {
            return Err(Error::Data(format!(
                "degree counts ({} out / {} in) do not match the node space \
                 ({} src / {} dst)",
                out.len(),
                in_.len(),
                spec.n_src,
                spec.n_dst
            )));
        }
        Ok(DegreeAccumulator { spec: Some(spec), out, in_, edges })
    }

    fn ensure_spec(&mut self, spec: PartiteSpec) {
        match self.spec {
            None => {
                self.out = vec![0; spec.n_src as usize];
                self.in_ = vec![0; spec.n_dst as usize];
                self.spec = Some(spec);
            }
            Some(s) => assert_eq!(
                s, spec,
                "DegreeAccumulator fed chunks of differently-shaped graphs"
            ),
        }
    }
}

impl MetricAccumulator for DegreeAccumulator {
    type Output = DegreeProfile;

    fn observe_edges(&mut self, chunk: &EdgeList) {
        self.ensure_spec(chunk.spec);
        for &s in &chunk.src {
            self.out[s as usize] += 1;
        }
        for &d in &chunk.dst {
            self.in_[d as usize] += 1;
        }
        self.edges += chunk.len() as u64;
    }

    fn merge(&mut self, other: Self) {
        let Some(other_spec) = other.spec else { return };
        if self.spec.is_none() {
            *self = other;
            return;
        }
        assert_eq!(
            self.spec,
            Some(other_spec),
            "DegreeAccumulator merge across differently-shaped graphs"
        );
        for (a, b) in self.out.iter_mut().zip(&other.out) {
            *a += b;
        }
        for (a, b) in self.in_.iter_mut().zip(&other.in_) {
            *a += b;
        }
        self.edges += other.edges;
    }

    fn finalize(self) -> DegreeProfile {
        DegreeProfile { out: self.out, in_: self.in_ }
    }
}

/// Finalized per-node degree counts of one graph: the shared input of
/// every degree-derived metric (Table 2 degree score, DCC, the joint
/// degree×feature histogram's normalization).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DegreeProfile {
    out: Vec<u32>,
    in_: Vec<u32>,
}

impl DegreeProfile {
    /// Profile an in-memory edge list (single-chunk accumulation).
    pub fn of(edges: &EdgeList) -> DegreeProfile {
        let mut acc = DegreeAccumulator::new();
        acc.observe_edges(edges);
        acc.finalize()
    }

    /// Out-degree per source node (`out[i] = deg(v_i)`).
    pub fn out_degrees(&self) -> &[u32] {
        &self.out
    }

    /// In-degree per destination node.
    pub fn in_degrees(&self) -> &[u32] {
        &self.in_
    }

    /// Largest out-degree (0 for an empty node space).
    pub fn max_out_degree(&self) -> u32 {
        self.out.iter().copied().max().unwrap_or(0)
    }
}

/// FNV-1a over both degree arrays, each length-prefixed (so `[1],[2]`
/// and `[1,2],[]` hash differently) with every value eaten as 8
/// little-endian bytes. This is the "bit-identical profile" fingerprint
/// shared by the conformance harness and distributed-merge validation.
pub fn profile_hash(prof: &DegreeProfile) -> u64 {
    let mut h = Fnv1a::new();
    for side in [prof.out_degrees(), prof.in_degrees()] {
        h.write_u64(side.len() as u64);
        for &d in side {
            h.write_u64(d as u64);
        }
    }
    h.finish()
}

/// Log-binned histogram of a degree sample normalized to [0, 1].
/// Zero-degree nodes are dropped (log scale); mass is normalized.
pub fn log_binned_degree_hist(degrees: &[u32], bins: usize) -> Vec<f64> {
    let max_d = degrees.iter().copied().max().unwrap_or(0).max(1) as f64;
    let mut hist = vec![0.0f64; bins];
    for &d in degrees {
        if d == 0 {
            continue;
        }
        // position of d in log space over [1, max_d]
        let t = if max_d <= 1.0 { 0.0 } else { (d as f64).ln() / max_d.ln() };
        let b = ((t * bins as f64) as usize).min(bins - 1);
        hist[b] += 1.0;
    }
    hist
}

/// "Degree Dist. ↑" of Table 2 over two finalized degree profiles: mean
/// over in/out sides of `1 − JS-distance(log-binned degree hists)`.
pub fn degree_dist_score_profiles(a: &DegreeProfile, b: &DegreeProfile) -> f64 {
    let score = |da: &[u32], db: &[u32]| -> f64 {
        let ha = log_binned_degree_hist(da, LOG_BINS);
        let hb = log_binned_degree_hist(db, LOG_BINS);
        1.0 - stats::js_distance(&ha, &hb)
    };
    0.5 * (score(a.out_degrees(), b.out_degrees()) + score(a.in_degrees(), b.in_degrees()))
}

/// "Degree Dist. ↑" of Table 2: convenience wrapper over
/// [`degree_dist_score_profiles`] for in-memory edge lists.
pub fn degree_dist_score(a: &EdgeList, b: &EdgeList) -> f64 {
    degree_dist_score_profiles(&DegreeProfile::of(a), &DegreeProfile::of(b))
}

/// DCC of paper eq. 20 over two finalized degree profiles (see [`dcc`]).
pub fn dcc_profiles(a: &DegreeProfile, b: &DegreeProfile, k_samples: usize) -> f64 {
    let coef = |da: &[u32], db: &[u32]| -> f64 {
        let (na, nb) = (normalized_ccdf(da), normalized_ccdf(db));
        let mut err = 0.0;
        let mut count = 0;
        for i in 0..k_samples {
            // log-spaced x in (0, 1]
            let x = (10f64).powf(-3.0 * (1.0 - (i as f64 + 1.0) / k_samples as f64));
            let ca = eval_step(&na, x);
            let cb = eval_step(&nb, x);
            if ca > 0.0 {
                err += ((ca - cb) / ca).abs().min(1.0);
                count += 1;
            }
        }
        if count == 0 {
            0.0
        } else {
            (1.0 - err / count as f64).clamp(0.0, 1.0)
        }
    };
    0.5 * (coef(a.out_degrees(), b.out_degrees()) + coef(a.in_degrees(), b.in_degrees()))
}

/// DCC of paper eq. 20: mean relative error of the normalized degree
/// counts sampled at K log-spaced normalized degrees. Returned as the
/// *coefficient* 1 − mean|rel err| clamped to [0,1] so that 1 = perfect
/// (the paper's Figure 7 plots high-is-better values).
pub fn dcc(a: &EdgeList, b: &EdgeList, k_samples: usize) -> f64 {
    dcc_profiles(&DegreeProfile::of(a), &DegreeProfile::of(b), k_samples)
}

/// Normalized complementary CDF of degrees: points (d/max_d, frac nodes
/// with degree ≥ d), sorted by x.
fn normalized_ccdf(degrees: &[u32]) -> Vec<(f64, f64)> {
    let n = degrees.len().max(1) as f64;
    let max_d = degrees.iter().copied().max().unwrap_or(1).max(1) as f64;
    let mut sorted: Vec<u32> = degrees.to_vec();
    sorted.sort_unstable();
    let mut pts = Vec::new();
    let mut i = 0usize;
    while i < sorted.len() {
        let d = sorted[i];
        let ge = sorted.len() - i;
        pts.push((d as f64 / max_d, ge as f64 / n));
        let mut j = i;
        while j < sorted.len() && sorted[j] == d {
            j += 1;
        }
        i = j;
    }
    pts
}

fn eval_step(pts: &[(f64, f64)], x: f64) -> f64 {
    // fraction of nodes with normalized degree >= x
    let mut val = 0.0;
    for &(px, py) in pts {
        if px >= x {
            val = py;
            break;
        }
    }
    val
}

/// MLE power-law exponent α for degrees ≥ `d_min` (Clauset et al.):
/// α = 1 + n / Σ ln(d_i / (d_min − 0.5)).
pub fn power_law_alpha(degrees: &[u32], d_min: u32) -> f64 {
    let d_min = d_min.max(1);
    let tail: Vec<f64> = degrees
        .iter()
        .filter(|&&d| d >= d_min)
        .map(|&d| d as f64)
        .collect();
    if tail.is_empty() {
        return f64::NAN;
    }
    let s: f64 = tail.iter().map(|d| (d / (d_min as f64 - 0.5)).ln()).sum();
    if s <= 0.0 {
        return f64::NAN;
    }
    1.0 + tail.len() as f64 / s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::erdos_renyi::ErdosRenyi;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;
    use crate::structgen::StructureGenerator;

    fn kron(seed: u64) -> EdgeList {
        KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 20_000)
            .generate(1, seed)
            .unwrap()
    }

    fn er(seed: u64) -> EdgeList {
        ErdosRenyi { spec: PartiteSpec::square(1 << 10), edges: 20_000 }
            .generate(1, seed)
            .unwrap()
    }

    #[test]
    fn identical_graphs_score_one() {
        let g = kron(1);
        let s = degree_dist_score(&g, &g);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn same_model_scores_high() {
        let s = degree_dist_score(&kron(1), &kron(2));
        assert!(s > 0.85, "s={s}");
    }

    #[test]
    fn er_vs_kron_scores_low() {
        let same = degree_dist_score(&kron(1), &kron(2));
        let diff = degree_dist_score(&kron(1), &er(3));
        assert!(diff < same, "diff={diff} same={same}");
        assert!(diff < 0.8, "diff={diff}");
    }

    #[test]
    fn dcc_orders_generators() {
        let orig = kron(1);
        let dcc_same = dcc(&orig, &kron(2), 16);
        let dcc_er = dcc(&orig, &er(3), 16);
        assert!(dcc_same > dcc_er, "same={dcc_same} er={dcc_er}");
    }

    #[test]
    fn dcc_cross_scale_stays_high() {
        // the paper's Fig 7 claim: scaling preserves the shape
        let g1 = kron(1);
        let g4 = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 20_000)
            .generate(2, 9)
            .unwrap();
        let d = dcc(&g1, &g4, 16);
        assert!(d > 0.5, "d={d}");
    }

    #[test]
    fn power_law_alpha_on_pareto() {
        // synthetic degrees from P(d) ∝ d^-2.5
        let mut rng = crate::util::rng::Pcg64::new(5);
        let degrees: Vec<u32> = (0..20_000)
            .map(|_| {
                let u: f64 = rng.f64().max(1e-12);
                (u.powf(-1.0 / 1.5)).min(1e6) as u32
            })
            .collect();
        // discretization biases the continuous MLE; use a higher d_min
        let alpha = power_law_alpha(&degrees, 5);
        assert!((alpha - 2.5).abs() < 0.25, "alpha={alpha}");
    }

    #[test]
    fn log_binned_hist_mass() {
        let h = log_binned_degree_hist(&[1, 2, 3, 100], 10);
        let total: f64 = h.iter().sum();
        assert_eq!(total, 4.0);
    }

    #[test]
    fn profile_matches_edge_list_degrees() {
        let g = kron(7);
        let p = DegreeProfile::of(&g);
        assert_eq!(p.out_degrees(), &g.out_degrees()[..]);
        assert_eq!(p.in_degrees(), &g.in_degrees()[..]);
        assert_eq!(
            p.max_out_degree(),
            g.out_degrees().iter().copied().max().unwrap()
        );
    }

    #[test]
    fn chunked_accumulation_is_exact() {
        let g = kron(9);
        let whole = DegreeProfile::of(&g);
        // split into 3 uneven chunks observed into one accumulator
        let cuts = [0usize, g.len() / 5, g.len() / 2, g.len()];
        let mut seq = DegreeAccumulator::new();
        // and into independently-merged partials
        let mut partials: Vec<DegreeAccumulator> = Vec::new();
        for w in cuts.windows(2) {
            let mut chunk = EdgeList::new(g.spec);
            for i in w[0]..w[1] {
                chunk.push(g.src[i], g.dst[i]);
            }
            seq.observe_edges(&chunk);
            let mut p = DegreeAccumulator::new();
            p.observe_edges(&chunk);
            partials.push(p);
        }
        assert_eq!(seq.edges_observed(), g.len() as u64);
        assert_eq!(seq.finalize(), whole);
        // merge in reverse order: counts are order-independent
        let mut merged = DegreeAccumulator::new();
        for p in partials.into_iter().rev() {
            merged.merge(p);
        }
        assert_eq!(merged.finalize(), whole);
    }

    #[test]
    fn profile_scores_match_edge_list_scores() {
        let (a, b) = (kron(1), er(2));
        let (pa, pb) = (DegreeProfile::of(&a), DegreeProfile::of(&b));
        assert_eq!(
            degree_dist_score(&a, &b).to_bits(),
            degree_dist_score_profiles(&pa, &pb).to_bits()
        );
        assert_eq!(
            dcc(&a, &b, 16).to_bits(),
            dcc_profiles(&pa, &pb, 16).to_bits()
        );
    }
}
