//! Degree-distribution similarity metrics.
//!
//! * [`degree_dist_score`] — the "Degree Dist. ↑" column of Table 2:
//!   1 − JS-distance between log-binned, normalized degree distributions
//!   of the two graphs (both sides averaged: in + out). Sizes may differ —
//!   degrees are normalized by each graph's max degree first, matching
//!   the paper's requirement to compare graphs of different scales.
//! * [`dcc`] — the scalar Degree Comparison Coefficient of eq. 20/21.
//! * [`power_law_alpha`] — MLE power-law exponent (Table 10 column).

use crate::graph::EdgeList;
use crate::util::stats;

/// Number of logarithmic bins used by the scores.
const LOG_BINS: usize = 24;

/// Log-binned histogram of a degree sample normalized to [0, 1].
/// Zero-degree nodes are dropped (log scale); mass is normalized.
pub fn log_binned_degree_hist(degrees: &[u32], bins: usize) -> Vec<f64> {
    let max_d = degrees.iter().copied().max().unwrap_or(0).max(1) as f64;
    let mut hist = vec![0.0f64; bins];
    for &d in degrees {
        if d == 0 {
            continue;
        }
        // position of d in log space over [1, max_d]
        let t = if max_d <= 1.0 { 0.0 } else { (d as f64).ln() / max_d.ln() };
        let b = ((t * bins as f64) as usize).min(bins - 1);
        hist[b] += 1.0;
    }
    hist
}

/// "Degree Dist. ↑" of Table 2: mean over in/out sides of
/// `1 − JS-distance(log-binned degree hists)` ∈ [0, 1].
pub fn degree_dist_score(a: &EdgeList, b: &EdgeList) -> f64 {
    let score = |da: &[u32], db: &[u32]| -> f64 {
        let ha = log_binned_degree_hist(da, LOG_BINS);
        let hb = log_binned_degree_hist(db, LOG_BINS);
        1.0 - stats::js_distance(&ha, &hb)
    };
    0.5 * (score(&a.out_degrees(), &b.out_degrees()) + score(&a.in_degrees(), &b.in_degrees()))
}

/// DCC of paper eq. 20: mean relative error of the normalized degree
/// counts sampled at K log-spaced normalized degrees. Returned as the
/// *coefficient* 1 − mean|rel err| clamped to [0,1] so that 1 = perfect
/// (the paper's Figure 7 plots high-is-better values).
pub fn dcc(a: &EdgeList, b: &EdgeList, k_samples: usize) -> f64 {
    let coef = |da: &[u32], db: &[u32]| -> f64 {
        let (na, nb) = (normalized_ccdf(da), normalized_ccdf(db));
        let mut err = 0.0;
        let mut count = 0;
        for i in 0..k_samples {
            // log-spaced x in (0, 1]
            let x = (10f64).powf(-3.0 * (1.0 - (i as f64 + 1.0) / k_samples as f64));
            let ca = eval_step(&na, x);
            let cb = eval_step(&nb, x);
            if ca > 0.0 {
                err += ((ca - cb) / ca).abs().min(1.0);
                count += 1;
            }
        }
        if count == 0 {
            0.0
        } else {
            (1.0 - err / count as f64).clamp(0.0, 1.0)
        }
    };
    0.5 * (coef(&a.out_degrees(), &b.out_degrees()) + coef(&a.in_degrees(), &b.in_degrees()))
}

/// Normalized complementary CDF of degrees: points (d/max_d, frac nodes
/// with degree ≥ d), sorted by x.
fn normalized_ccdf(degrees: &[u32]) -> Vec<(f64, f64)> {
    let n = degrees.len().max(1) as f64;
    let max_d = degrees.iter().copied().max().unwrap_or(1).max(1) as f64;
    let mut sorted: Vec<u32> = degrees.to_vec();
    sorted.sort_unstable();
    let mut pts = Vec::new();
    let mut i = 0usize;
    while i < sorted.len() {
        let d = sorted[i];
        let ge = sorted.len() - i;
        pts.push((d as f64 / max_d, ge as f64 / n));
        let mut j = i;
        while j < sorted.len() && sorted[j] == d {
            j += 1;
        }
        i = j;
    }
    pts
}

fn eval_step(pts: &[(f64, f64)], x: f64) -> f64 {
    // fraction of nodes with normalized degree >= x
    let mut val = 0.0;
    for &(px, py) in pts {
        if px >= x {
            val = py;
            break;
        }
    }
    val
}

/// MLE power-law exponent α for degrees ≥ `d_min` (Clauset et al.):
/// α = 1 + n / Σ ln(d_i / (d_min − 0.5)).
pub fn power_law_alpha(degrees: &[u32], d_min: u32) -> f64 {
    let d_min = d_min.max(1);
    let tail: Vec<f64> = degrees
        .iter()
        .filter(|&&d| d >= d_min)
        .map(|&d| d as f64)
        .collect();
    if tail.is_empty() {
        return f64::NAN;
    }
    let s: f64 = tail.iter().map(|d| (d / (d_min as f64 - 0.5)).ln()).sum();
    if s <= 0.0 {
        return f64::NAN;
    }
    1.0 + tail.len() as f64 / s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::erdos_renyi::ErdosRenyi;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;
    use crate::structgen::StructureGenerator;

    fn kron(seed: u64) -> EdgeList {
        KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 20_000)
            .generate(1, seed)
            .unwrap()
    }

    fn er(seed: u64) -> EdgeList {
        ErdosRenyi { spec: PartiteSpec::square(1 << 10), edges: 20_000 }
            .generate(1, seed)
            .unwrap()
    }

    #[test]
    fn identical_graphs_score_one() {
        let g = kron(1);
        let s = degree_dist_score(&g, &g);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn same_model_scores_high() {
        let s = degree_dist_score(&kron(1), &kron(2));
        assert!(s > 0.85, "s={s}");
    }

    #[test]
    fn er_vs_kron_scores_low() {
        let same = degree_dist_score(&kron(1), &kron(2));
        let diff = degree_dist_score(&kron(1), &er(3));
        assert!(diff < same, "diff={diff} same={same}");
        assert!(diff < 0.8, "diff={diff}");
    }

    #[test]
    fn dcc_orders_generators() {
        let orig = kron(1);
        let dcc_same = dcc(&orig, &kron(2), 16);
        let dcc_er = dcc(&orig, &er(3), 16);
        assert!(dcc_same > dcc_er, "same={dcc_same} er={dcc_er}");
    }

    #[test]
    fn dcc_cross_scale_stays_high() {
        // the paper's Fig 7 claim: scaling preserves the shape
        let g1 = kron(1);
        let g4 = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 20_000)
            .generate(2, 9)
            .unwrap();
        let d = dcc(&g1, &g4, 16);
        assert!(d > 0.5, "d={d}");
    }

    #[test]
    fn power_law_alpha_on_pareto() {
        // synthetic degrees from P(d) ∝ d^-2.5
        let mut rng = crate::util::rng::Pcg64::new(5);
        let degrees: Vec<u32> = (0..20_000)
            .map(|_| {
                let u: f64 = rng.f64().max(1e-12);
                (u.powf(-1.0 / 1.5)).min(1e6) as u32
            })
            .collect();
        // discretization biases the continuous MLE; use a higher d_min
        let alpha = power_law_alpha(&degrees, 5);
        assert!((alpha - 2.5).abs() < 0.25, "alpha={alpha}");
    }

    #[test]
    fn log_binned_hist_mass() {
        let h = log_binned_degree_hist(&[1, 2, 3, 100], 10);
        let total: f64 = h.iter().sum();
        assert_eq!(total, 4.0);
    }
}
