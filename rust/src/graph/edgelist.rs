//! Edge list: the primary interchange representation between the
//! generators, the aligner and the metrics. Stored column-major
//! (struct-of-arrays) for cache-friendly scans.

use super::bipartite::PartiteSpec;

/// A directed edge list over a (possibly bipartite) node space.
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    /// Source node id per edge (row partite for bipartite graphs).
    pub src: Vec<u64>,
    /// Destination node id per edge (column partite for bipartite graphs).
    pub dst: Vec<u64>,
    /// Partite layout.
    pub spec: PartiteSpec,
}

impl EdgeList {
    /// Create an empty edge list with the given partite spec.
    pub fn new(spec: PartiteSpec) -> Self {
        EdgeList { src: Vec::new(), dst: Vec::new(), spec }
    }

    /// Create with pre-allocated capacity.
    pub fn with_capacity(spec: PartiteSpec, cap: usize) -> Self {
        EdgeList { src: Vec::with_capacity(cap), dst: Vec::with_capacity(cap), spec }
    }

    /// Build from parallel src/dst vectors.
    pub fn from_pairs(spec: PartiteSpec, pairs: &[(u64, u64)]) -> Self {
        let mut e = EdgeList::with_capacity(spec, pairs.len());
        for &(s, d) in pairs {
            e.push(s, d);
        }
        e
    }

    /// Number of edges.
    #[inline]
    pub fn len(&self) -> usize {
        self.src.len()
    }

    /// True when there are no edges.
    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    /// Append an edge.
    #[inline]
    pub fn push(&mut self, s: u64, d: u64) {
        self.src.push(s);
        self.dst.push(d);
    }

    /// Drop all edges, keeping the allocated capacity.
    pub fn clear(&mut self) {
        self.src.clear();
        self.dst.clear();
    }

    /// Reuse this list for a new chunk: drop the edges, keep the
    /// allocations, and take on `spec`. The arena primitive behind the
    /// parallel runner's recycled chunk buffers.
    pub fn reset(&mut self, spec: PartiteSpec) {
        self.clear();
        self.spec = spec;
    }

    /// Reserve capacity for at least `additional` more edges.
    pub fn reserve(&mut self, additional: usize) {
        self.src.reserve(additional);
        self.dst.reserve(additional);
    }

    /// Allocated capacity in edges (minimum of the two columns).
    pub fn capacity(&self) -> usize {
        self.src.capacity().min(self.dst.capacity())
    }

    /// Sort edges by (src, dst), keeping duplicates — the within-chunk
    /// canonical order the delta-encoded shard format stores. Unlike
    /// [`EdgeList::sort_dedup`] the multiset is unchanged.
    pub fn sort_within(&mut self) {
        let mut keys: Vec<u128> = self
            .iter()
            .map(|(s, d)| ((s as u128) << 64) | d as u128)
            .collect();
        keys.sort_unstable();
        self.src.clear();
        self.dst.clear();
        for k in keys {
            self.src.push((k >> 64) as u64);
            self.dst.push(k as u64);
        }
    }

    /// Append all edges of another list (same spec assumed).
    pub fn extend_from(&mut self, other: &EdgeList) {
        self.src.extend_from_slice(&other.src);
        self.dst.extend_from_slice(&other.dst);
    }

    /// Iterate over `(src, dst)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.src.iter().copied().zip(self.dst.iter().copied())
    }

    /// Number of source-partite nodes.
    pub fn n_src(&self) -> u64 {
        self.spec.n_src
    }

    /// Number of destination-partite nodes.
    pub fn n_dst(&self) -> u64 {
        self.spec.n_dst
    }

    /// Total node count across partites (N = n + m in the paper).
    pub fn n_nodes(&self) -> u64 {
        self.spec.total_nodes()
    }

    /// Out-degree histogram over source nodes: `out[i] = deg(v_i)`.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.spec.n_src as usize];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degree histogram over destination nodes.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.spec.n_dst as usize];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Sort edges by (src, dst) and remove duplicates. Returns the number
    /// of duplicates removed. Used by generators that sample with
    /// replacement and by the ingest path.
    pub fn sort_dedup(&mut self) -> usize {
        let mut keys: Vec<u128> = self
            .iter()
            .map(|(s, d)| ((s as u128) << 64) | d as u128)
            .collect();
        keys.sort_unstable();
        let before = keys.len();
        keys.dedup();
        let removed = before - keys.len();
        self.src.clear();
        self.dst.clear();
        for k in keys {
            self.src.push((k >> 64) as u64);
            self.dst.push(k as u64);
        }
        removed
    }

    /// Validate that all endpoints are within the partite bounds.
    pub fn validate(&self) -> Result<(), String> {
        for (i, (s, d)) in self.iter().enumerate() {
            if s >= self.spec.n_src {
                return Err(format!("edge {i}: src {s} >= n_src {}", self.spec.n_src));
            }
            if d >= self.spec.n_dst {
                return Err(format!("edge {i}: dst {d} >= n_dst {}", self.spec.n_dst));
            }
        }
        Ok(())
    }

    /// The edges as a set of packed `(src << 64) | dst` keys — the
    /// membership structure behind [`EdgeList::edge_overlap`]. Build it
    /// once when the same reference graph is compared repeatedly.
    pub fn edge_keys(&self) -> std::collections::HashSet<u128> {
        self.iter().map(|(s, d)| ((s as u128) << 64) | d as u128).collect()
    }

    /// Edge overlap against a precomputed reference key set (see
    /// [`EdgeList::edge_keys`]): |E ∩ ref| / |E|.
    pub fn edge_overlap_in(&self, reference: &std::collections::HashSet<u128>) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let hit = self
            .iter()
            .filter(|(s, d)| reference.contains(&(((*s as u128) << 64) | *d as u128)))
            .count();
        hit as f64 / self.len() as f64
    }

    /// Edge overlap with another edge list over the same node space:
    /// |E ∩ E'| / |E| — the "EO" column of paper Table 10.
    pub fn edge_overlap(&self, other: &EdgeList) -> f64 {
        self.edge_overlap_in(&other.edge_keys())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: u64, m: u64) -> PartiteSpec {
        PartiteSpec::bipartite(n, m)
    }

    #[test]
    fn push_and_degrees() {
        let mut e = EdgeList::new(spec(3, 2));
        e.push(0, 0);
        e.push(0, 1);
        e.push(2, 1);
        assert_eq!(e.len(), 3);
        assert_eq!(e.out_degrees(), vec![2, 0, 1]);
        assert_eq!(e.in_degrees(), vec![1, 2]);
    }

    #[test]
    fn sort_dedup_removes_duplicates() {
        let mut e = EdgeList::from_pairs(spec(4, 4), &[(1, 2), (0, 0), (1, 2), (3, 3), (0, 0)]);
        let removed = e.sort_dedup();
        assert_eq!(removed, 2);
        assert_eq!(e.len(), 3);
        let pairs: Vec<_> = e.iter().collect();
        assert_eq!(pairs, vec![(0, 0), (1, 2), (3, 3)]);
    }

    #[test]
    fn sort_within_keeps_duplicates() {
        let mut e = EdgeList::from_pairs(spec(4, 4), &[(3, 1), (0, 2), (3, 1), (0, 0)]);
        e.sort_within();
        let pairs: Vec<_> = e.iter().collect();
        assert_eq!(pairs, vec![(0, 0), (0, 2), (3, 1), (3, 1)]);
    }

    #[test]
    fn reset_keeps_capacity_and_swaps_spec() {
        let mut e = EdgeList::with_capacity(spec(4, 4), 64);
        e.push(1, 1);
        let cap = e.capacity();
        assert!(cap >= 64);
        e.reset(PartiteSpec::square(9));
        assert!(e.is_empty());
        assert_eq!(e.spec, PartiteSpec::square(9));
        assert_eq!(e.capacity(), cap);
    }

    #[test]
    fn validate_bounds() {
        let e = EdgeList::from_pairs(spec(2, 2), &[(0, 1)]);
        assert!(e.validate().is_ok());
        let bad = EdgeList::from_pairs(spec(2, 2), &[(2, 0)]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn edge_overlap_fraction() {
        let a = EdgeList::from_pairs(spec(4, 4), &[(0, 0), (1, 1), (2, 2), (3, 3)]);
        let b = EdgeList::from_pairs(spec(4, 4), &[(0, 0), (1, 1), (0, 3)]);
        assert!((a.edge_overlap(&b) - 0.5).abs() < 1e-12);
        assert!((b.edge_overlap(&a) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn total_nodes_bipartite_vs_square() {
        let e = EdgeList::new(PartiteSpec::bipartite(5, 7));
        assert_eq!(e.n_nodes(), 12);
        let sq = EdgeList::new(PartiteSpec::square(5));
        assert_eq!(sq.n_nodes(), 5);
    }
}
