//! Graph data structures: edge lists, CSR adjacency, bipartite views,
//! traversals, and on-disk formats.
//!
//! SGG graphs follow the paper's formulation (§3.1): a graph is a triple
//! `G(S, F_V, F_E)` — here the structure `S` lives in this module, feature
//! matrices in [`crate::featgen::table`], and the two are combined by the
//! pipeline after alignment.
//!
//! Node ids are `u64`. For bipartite graphs (the paper's n×m non-square
//! adjacency), source ids index the row partite and destination ids the
//! column partite; [`bipartite::PartiteSpec`] carries the partite sizes.

pub mod bipartite;
pub mod csr;
pub mod edgelist;
pub mod io;
pub mod traversal;

pub use bipartite::PartiteSpec;
pub use csr::Csr;
pub use edgelist::EdgeList;
