//! Partite layout descriptors.
//!
//! The paper's structure generator (§3.2.2) works on a possibly non-square
//! n×m adjacency where rows and columns may represent *different* nodes
//! (bipartite / K-partite graphs) or the *same* nodes (homogeneous square
//! graphs). `PartiteSpec` records which interpretation applies; it decides
//! how degree distributions, metrics and the aligner map row/column indices
//! to node identities.

/// Describes the node space behind an adjacency matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartiteSpec {
    /// Number of row (source) nodes, `N` in the paper.
    pub n_src: u64,
    /// Number of column (destination) nodes, `M` in the paper.
    pub n_dst: u64,
    /// If true, rows and columns index the *same* node set (homogeneous
    /// graph, square adjacency); total nodes = n_src. Otherwise the graph
    /// is bipartite and total nodes = n_src + n_dst.
    pub square: bool,
}

impl Default for PartiteSpec {
    fn default() -> Self {
        PartiteSpec::square(0)
    }
}

impl PartiteSpec {
    /// Homogeneous graph over `n` nodes (square adjacency).
    pub fn square(n: u64) -> Self {
        PartiteSpec { n_src: n, n_dst: n, square: true }
    }

    /// Bipartite graph with `n` source and `m` destination nodes.
    pub fn bipartite(n: u64, m: u64) -> Self {
        PartiteSpec { n_src: n, n_dst: m, square: false }
    }

    /// Total number of distinct nodes.
    pub fn total_nodes(&self) -> u64 {
        if self.square {
            self.n_src
        } else {
            self.n_src + self.n_dst
        }
    }

    /// Global node id of source-row `i` (row partite comes first).
    pub fn src_global(&self, i: u64) -> u64 {
        i
    }

    /// Global node id of destination-column `j`.
    pub fn dst_global(&self, j: u64) -> u64 {
        if self.square {
            j
        } else {
            self.n_src + j
        }
    }

    /// Scale both partites by `k` (paper §8.2: nodes scale linearly).
    pub fn scaled(&self, k: u64) -> PartiteSpec {
        PartiteSpec { n_src: self.n_src * k, n_dst: self.n_dst * k, square: self.square }
    }

    /// Graph density E / (N·M) (paper eq. 22).
    pub fn density(&self, edges: u64) -> f64 {
        let cells = self.n_src as f64 * self.n_dst as f64;
        if cells <= 0.0 {
            0.0
        } else {
            edges as f64 / cells
        }
    }

    /// Number of edges that preserves this spec's density in a graph
    /// scaled by `k` in both partites (eq. 22: E scales as k²).
    pub fn density_preserving_edges(&self, edges: u64, k: u64) -> u64 {
        edges.saturating_mul(k).saturating_mul(k)
    }

    /// Serialize for a `.sggm` model artifact.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![
            ("n_src", crate::util::json::Json::u64_exact(self.n_src)),
            ("n_dst", crate::util::json::Json::u64_exact(self.n_dst)),
            ("square", crate::util::json::Json::from(self.square)),
        ])
    }

    /// Inverse of [`PartiteSpec::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> crate::Result<PartiteSpec> {
        Ok(PartiteSpec {
            n_src: v.req_u64("n_src")?,
            n_dst: v.req_u64("n_dst")?,
            square: v.req_bool("square")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_ids_bipartite() {
        let s = PartiteSpec::bipartite(10, 5);
        assert_eq!(s.src_global(3), 3);
        assert_eq!(s.dst_global(0), 10);
        assert_eq!(s.dst_global(4), 14);
        assert_eq!(s.total_nodes(), 15);
    }

    #[test]
    fn global_ids_square() {
        let s = PartiteSpec::square(8);
        assert_eq!(s.dst_global(5), 5);
        assert_eq!(s.total_nodes(), 8);
    }

    #[test]
    fn density_preserved_under_scaling() {
        let s = PartiteSpec::bipartite(100, 50);
        let e = 1000u64;
        let d0 = s.density(e);
        let k = 4;
        let s2 = s.scaled(k);
        let e2 = s.density_preserving_edges(e, k);
        let d1 = s2.density(e2);
        assert!((d0 - d1).abs() < 1e-12, "{d0} vs {d1}");
    }
}
