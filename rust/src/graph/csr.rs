//! Compressed sparse row adjacency built from an [`EdgeList`].
//!
//! Used by everything that needs neighborhood queries: BFS/hop-plot,
//! PageRank/Katz, clustering coefficients, triangle counting, node2vec
//! walks, and the GNN data prep. For bipartite graphs the CSR is built
//! over the *global* node space (rows then columns) with edges in both
//! directions when an undirected view is requested.

use super::edgelist::EdgeList;

/// CSR adjacency. `neighbors(v)` is `adj[offsets[v]..offsets[v+1]]`.
#[derive(Clone, Debug)]
pub struct Csr {
    /// Row offsets, length `n_nodes + 1`.
    pub offsets: Vec<u64>,
    /// Column indices (global node ids).
    pub adj: Vec<u64>,
    /// Number of nodes in the global id space.
    pub n_nodes: u64,
}

impl Csr {
    /// Directed CSR over global ids: edges go src_global -> dst_global.
    pub fn directed(edges: &EdgeList) -> Csr {
        Self::build(edges, false)
    }

    /// Undirected CSR: each edge contributes both directions (self-loops
    /// once). This is the view used by hop-plots, clustering and
    /// components, matching how the paper evaluates its graphs.
    pub fn undirected(edges: &EdgeList) -> Csr {
        Self::build(edges, true)
    }

    fn build(edges: &EdgeList, symmetrize: bool) -> Csr {
        let n = edges.spec.total_nodes();
        let mut deg = vec![0u64; n as usize];
        for (s, d) in edges.iter() {
            let gs = edges.spec.src_global(s);
            let gd = edges.spec.dst_global(d);
            deg[gs as usize] += 1;
            if symmetrize && gs != gd {
                deg[gd as usize] += 1;
            }
        }
        let mut offsets = vec![0u64; n as usize + 1];
        for i in 0..n as usize {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut adj = vec![0u64; offsets[n as usize] as usize];
        let mut cursor = offsets.clone();
        for (s, d) in edges.iter() {
            let gs = edges.spec.src_global(s) as usize;
            let gd = edges.spec.dst_global(d) as usize;
            adj[cursor[gs] as usize] = gd as u64;
            cursor[gs] += 1;
            if symmetrize && gs != gd {
                adj[cursor[gd] as usize] = gs as u64;
                cursor[gd] += 1;
            }
        }
        let mut csr = Csr { offsets, adj, n_nodes: n };
        csr.sort_neighbors();
        csr
    }

    fn sort_neighbors(&mut self) {
        for v in 0..self.n_nodes as usize {
            let (a, b) = (self.offsets[v] as usize, self.offsets[v + 1] as usize);
            self.adj[a..b].sort_unstable();
        }
    }

    /// Neighbor slice of node `v` (sorted).
    #[inline]
    pub fn neighbors(&self, v: u64) -> &[u64] {
        let a = self.offsets[v as usize] as usize;
        let b = self.offsets[v as usize + 1] as usize;
        &self.adj[a..b]
    }

    /// Degree of node `v` in this view.
    #[inline]
    pub fn degree(&self, v: u64) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// All degrees as f64 (for metric computations).
    pub fn degrees_f64(&self) -> Vec<f64> {
        (0..self.n_nodes).map(|v| self.degree(v) as f64).collect()
    }

    /// True if edge (u, v) exists in this view (binary search).
    pub fn has_edge(&self, u: u64, v: u64) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Total number of stored directed arcs.
    pub fn n_arcs(&self) -> usize {
        self.adj.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::bipartite::PartiteSpec;

    fn petersen_outer() -> EdgeList {
        // simple 5-cycle
        EdgeList::from_pairs(
            PartiteSpec::square(5),
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)],
        )
    }

    #[test]
    fn directed_preserves_arcs() {
        let e = petersen_outer();
        let csr = Csr::directed(&e);
        assert_eq!(csr.n_arcs(), 5);
        assert_eq!(csr.neighbors(0), &[1]);
        assert_eq!(csr.degree(4), 1);
    }

    #[test]
    fn undirected_symmetrizes() {
        let e = petersen_outer();
        let csr = Csr::undirected(&e);
        assert_eq!(csr.n_arcs(), 10);
        assert_eq!(csr.neighbors(0), &[1, 4]);
        assert!(csr.has_edge(1, 0));
        assert!(!csr.has_edge(0, 2));
    }

    #[test]
    fn bipartite_global_ids() {
        let e = EdgeList::from_pairs(PartiteSpec::bipartite(2, 3), &[(0, 0), (1, 2)]);
        let csr = Csr::undirected(&e);
        assert_eq!(csr.n_nodes, 5);
        // dst 0 is global 2; dst 2 is global 4
        assert_eq!(csr.neighbors(0), &[2]);
        assert_eq!(csr.neighbors(4), &[1]);
    }

    #[test]
    fn self_loop_counted_once_undirected() {
        let e = EdgeList::from_pairs(PartiteSpec::square(3), &[(1, 1), (0, 2)]);
        let csr = Csr::undirected(&e);
        assert_eq!(csr.neighbors(1), &[1]);
        assert_eq!(csr.degree(1), 1);
    }

    #[test]
    fn degrees_f64_matches() {
        let e = petersen_outer();
        let csr = Csr::undirected(&e);
        assert_eq!(csr.degrees_f64(), vec![2.0; 5]);
    }
}
