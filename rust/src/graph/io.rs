//! On-disk edge-list formats: a compact little-endian binary format for
//! shard outputs (16 bytes/edge) and a TSV text format for interchange.
//!
//! Binary reads and writes move data through a reusable ~1 MiB record
//! buffer (one syscall per batch, not per edge), and every header is
//! validated against the actual file size before any allocation trusts
//! it. [`ShardReader`] opens a whole `ShardSink` directory, validates
//! every shard header up front, and serves shards one at a time — the
//! substrate of the streaming evaluation path
//! (`metrics::stream::evaluate_shards`).

use super::bipartite::PartiteSpec;
use super::edgelist::EdgeList;
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"SGGEDGE1";

/// Fixed header size: magic + n_src + n_dst + square + n_edges.
const HEADER_LEN: usize = 8 + 8 + 8 + 1 + 8;

/// Edges per IO batch (×16 bytes ≈ 1 MiB buffers).
const IO_BATCH_EDGES: usize = 65_536;

/// Error-mapping closure attaching shard-file context: a failed shard
/// in a thousand-shard run is identifiable from the message alone.
fn shard_io(path: &Path, offset: u64) -> impl FnOnce(std::io::Error) -> Error + '_ {
    move |source| Error::ShardIo { path: path.to_path_buf(), offset, source }
}

/// Write an edge list in the binary shard format:
/// `magic | n_src u64 | n_dst u64 | square u8 | n_edges u64 | (src,dst)*`.
///
/// Records are staged in a reusable buffer and flushed in ~1 MiB
/// batches — one `write_all` per batch instead of per edge.
pub fn write_binary(path: &Path, edges: &EdgeList) -> Result<()> {
    let mut f = std::fs::File::create(path).map_err(shard_io(path, 0))?;
    let cap = HEADER_LEN + edges.len().min(IO_BATCH_EDGES) * 16;
    let mut buf: Vec<u8> = Vec::with_capacity(cap);
    let mut written = 0u64;
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&edges.spec.n_src.to_le_bytes());
    buf.extend_from_slice(&edges.spec.n_dst.to_le_bytes());
    buf.push(edges.spec.square as u8);
    buf.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    for (s, d) in edges.iter() {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        if buf.len() >= IO_BATCH_EDGES * 16 {
            f.write_all(&buf).map_err(shard_io(path, written))?;
            written += buf.len() as u64;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        f.write_all(&buf).map_err(shard_io(path, written))?;
    }
    Ok(())
}

/// [`write_binary`] with crash atomicity: the shard is staged as
/// `<path>.tmp` and renamed into place only after every byte is
/// written, so an interrupted run never leaves a partial file under the
/// final name. A complete `shard-NNNNN.sgg` therefore doubles as that
/// chunk's durable completion record — the basis of `--resume`.
pub fn write_binary_atomic(path: &Path, edges: &EdgeList) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    if let Err(e) = write_binary(&tmp, edges) {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path).map_err(shard_io(path, 0))
}

/// Parse and validate the fixed-size binary header.
fn parse_header(h: &[u8; HEADER_LEN], path: &Path) -> Result<(PartiteSpec, u64)> {
    if &h[0..8] != MAGIC {
        return Err(Error::Data(format!("{}: bad magic", path.display())));
    }
    let n_src = u64::from_le_bytes(h[8..16].try_into().unwrap());
    let n_dst = u64::from_le_bytes(h[16..24].try_into().unwrap());
    let square = h[24] == 1;
    let n_edges = u64::from_le_bytes(h[25..33].try_into().unwrap());
    let spec = if square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    Ok((spec, n_edges))
}

/// Check that the header's edge count matches the file's actual size —
/// a corrupt or truncated header must not drive `with_capacity` or a
/// silent short read.
fn validate_file_len(path: &Path, actual: u64, n_edges: u64) -> Result<()> {
    let expected = n_edges
        .checked_mul(16)
        .and_then(|b| b.checked_add(HEADER_LEN as u64))
        .ok_or_else(|| {
            Error::Data(format!(
                "{}: header edge count {n_edges} overflows the file size",
                path.display()
            ))
        })?;
    if actual != expected {
        return Err(Error::Data(format!(
            "{}: header claims {n_edges} edges ({expected} bytes) but file is {actual} bytes",
            path.display()
        )));
    }
    Ok(())
}

/// Open a shard, parse its header, and validate the declared edge count
/// against the file size — the shared prelude of every binary read
/// path. The returned handle is positioned at the first edge record.
fn open_validated(path: &Path) -> Result<(std::fs::File, PartiteSpec, u64)> {
    let mut f = std::fs::File::open(path).map_err(shard_io(path, 0))?;
    let actual = f.metadata().map_err(shard_io(path, 0))?.len();
    if (actual as usize) < HEADER_LEN {
        return Err(Error::Data(format!(
            "{}: {actual} bytes is shorter than the {HEADER_LEN}-byte header",
            path.display()
        )));
    }
    let mut h = [0u8; HEADER_LEN];
    f.read_exact(&mut h).map_err(shard_io(path, 0))?;
    let (spec, n_edges) = parse_header(&h, path)?;
    validate_file_len(path, actual, n_edges)?;
    Ok((f, spec, n_edges))
}

/// Read and validate only the header of a binary shard: its partite
/// spec and edge count. The edge count is checked against the file size.
pub fn read_binary_header(path: &Path) -> Result<(PartiteSpec, u64)> {
    let (_f, spec, n_edges) = open_validated(path)?;
    Ok((spec, n_edges))
}

/// Read the binary shard format written by [`write_binary`]. The header
/// edge count is validated against the file size before it is trusted
/// (no blind `with_capacity`, no silent truncation), and records are
/// read through a reusable ~1 MiB batch buffer.
pub fn read_binary(path: &Path) -> Result<EdgeList> {
    let (mut f, spec, n_edges) = open_validated(path)?;
    let n_edges = n_edges as usize;
    let mut edges = EdgeList::with_capacity(spec, n_edges);
    let mut buf = vec![0u8; n_edges.clamp(1, IO_BATCH_EDGES) * 16];
    let mut remaining = n_edges;
    while remaining > 0 {
        let take = remaining.min(IO_BATCH_EDGES);
        let bytes = &mut buf[..take * 16];
        let offset = (HEADER_LEN + (n_edges - remaining) * 16) as u64;
        f.read_exact(bytes).map_err(shard_io(path, offset))?;
        for rec in bytes.chunks_exact(16) {
            let s = u64::from_le_bytes(rec[0..8].try_into().unwrap());
            let d = u64::from_le_bytes(rec[8..16].try_into().unwrap());
            edges.push(s, d);
        }
        remaining -= take;
    }
    Ok(edges)
}

/// Validated header of one shard in a [`ShardReader`] directory.
#[derive(Clone, Copy, Debug)]
pub struct ShardHeader {
    /// Partite layout declared by the shard.
    pub spec: PartiteSpec,
    /// Edge count declared by the shard (verified against its size).
    pub n_edges: u64,
}

/// A `ShardSink` output directory opened for chunk-by-chunk reading:
/// all `*.sgg` shards in path order, every header validated (magic,
/// size, and cross-shard spec consistency) before any body is read.
/// Reading one shard at a time keeps the resident set bounded by the
/// largest shard — the substrate of streamed evaluation.
pub struct ShardReader {
    paths: Vec<PathBuf>,
    headers: Vec<ShardHeader>,
    spec: PartiteSpec,
}

impl ShardReader {
    /// Open a shard directory. Errors if the directory holds no `.sgg`
    /// files, any header is invalid, or the shards disagree on the
    /// partite spec.
    pub fn open(dir: &Path) -> Result<ShardReader> {
        ShardReader::open_dirs(std::slice::from_ref(&dir.to_path_buf()))
    }

    /// Open several shard directories as one logical graph — the
    /// unmerged output of a distributed run, where each host's directory
    /// holds a disjoint slice of the canonical `shard-NNNNN.sgg` series.
    /// Shards are ordered by file *name* across all directories (the
    /// zero-padded names make lexical order equal chunk-index order
    /// regardless of which directory a shard lives in), so the combined
    /// read order matches a merged single-directory run exactly.
    /// Duplicate shard names across directories are rejected: two hosts
    /// claiming the same chunk is a partitioning error, not an input to
    /// silently prefer one side of.
    pub fn open_dirs(dirs: &[PathBuf]) -> Result<ShardReader> {
        let mut paths: Vec<PathBuf> = Vec::new();
        for dir in dirs {
            for entry in std::fs::read_dir(dir)? {
                let p = entry?.path();
                if p.extension().map(|x| x == "sgg").unwrap_or(false) {
                    paths.push(p);
                }
            }
        }
        paths.sort_by(|a, b| a.file_name().cmp(&b.file_name()).then_with(|| a.cmp(b)));
        for w in paths.windows(2) {
            if w[0].file_name() == w[1].file_name() {
                return Err(Error::Data(format!(
                    "duplicate shard `{}` appears in more than one directory ({} and {})",
                    w[0].file_name().unwrap_or_default().to_string_lossy(),
                    w[0].display(),
                    w[1].display()
                )));
            }
        }
        if paths.is_empty() {
            let names: Vec<String> = dirs.iter().map(|d| d.display().to_string()).collect();
            return Err(Error::Data(format!("no shards in {}", names.join(", "))));
        }
        let mut headers = Vec::with_capacity(paths.len());
        for p in &paths {
            let (spec, n_edges) = read_binary_header(p)?;
            headers.push(ShardHeader { spec, n_edges });
        }
        let spec = headers[0].spec;
        for (h, p) in headers.iter().zip(&paths) {
            if h.spec != spec {
                return Err(Error::Data(format!(
                    "{}: shard spec {:?} differs from the directory's first shard {:?}",
                    p.display(),
                    h.spec,
                    spec
                )));
            }
        }
        Ok(ShardReader { paths, headers, spec })
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.paths.len()
    }

    /// True when the reader holds no shards (never, by construction —
    /// [`ShardReader::open`] rejects empty directories).
    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// The partite spec shared by every shard.
    pub fn spec(&self) -> PartiteSpec {
        self.spec
    }

    /// Total edges across all shards (from the validated headers).
    pub fn total_edges(&self) -> u64 {
        self.headers.iter().map(|h| h.n_edges).sum()
    }

    /// Largest single shard's edge count.
    pub fn max_shard_edges(&self) -> u64 {
        self.headers.iter().map(|h| h.n_edges).max().unwrap_or(0)
    }

    /// Validated header of shard `i`.
    pub fn header(&self, i: usize) -> &ShardHeader {
        &self.headers[i]
    }

    /// Path of shard `i`.
    pub fn path(&self, i: usize) -> &Path {
        &self.paths[i]
    }

    /// Read shard `i` into memory.
    pub fn read(&self, i: usize) -> Result<EdgeList> {
        read_binary(&self.paths[i])
    }
}

/// Write TSV: header `# n_src n_dst square` then `src\tdst` lines.
pub fn write_tsv(path: &Path, edges: &EdgeList) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# {} {} {}",
        edges.spec.n_src, edges.spec.n_dst, edges.spec.square as u8
    )?;
    for (s, d) in edges.iter() {
        writeln!(w, "{s}\t{d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read the TSV format written by [`write_tsv`].
pub fn read_tsv(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Data("empty tsv".into()))??;
    let parts: Vec<&str> = header.trim_start_matches('#').split_whitespace().collect();
    if parts.len() != 3 {
        return Err(Error::Data(format!("bad tsv header `{header}`")));
    }
    let n_src: u64 = parts[0].parse().map_err(|_| Error::Data("bad n_src".into()))?;
    let n_dst: u64 = parts[1].parse().map_err(|_| Error::Data("bad n_dst".into()))?;
    let square = parts[2] == "1";
    let spec = if square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut edges = EdgeList::new(spec);
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let s: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        let d: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        edges.push(s, d);
    }
    Ok(edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EdgeList {
        EdgeList::from_pairs(PartiteSpec::bipartite(10, 20), &[(0, 19), (9, 0), (5, 5)])
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn binary_roundtrip() {
        let path = tmp("bin");
        let e = sample();
        write_binary(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        assert_eq!(r.dst, e.dst);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_roundtrip_across_batch_boundary() {
        // more edges than one IO batch, with a ragged tail
        let path = tmp("batch");
        let n = IO_BATCH_EDGES * 2 + 17;
        let mut e = EdgeList::with_capacity(PartiteSpec::square(1 << 20), n);
        for i in 0..n as u64 {
            e.push(i % (1 << 20), (i * 7) % (1 << 20));
        }
        write_binary(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.src, e.src);
        assert_eq!(r.dst, e.dst);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn tsv_roundtrip() {
        let path = tmp("tsv");
        let e = sample();
        write_tsv(&path, &e).unwrap();
        let r = read_tsv(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_size_mismatch() {
        let path = tmp("sizemismatch");
        let e = sample();
        write_binary(&path, &e).unwrap();
        // truncate the body: header still claims 3 edges
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
        // inflate the header's edge count without growing the file
        let mut forged = bytes.clone();
        forged[25..33].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &forged).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // a plausible but wrong count is also rejected (no huge
        // allocation, no short read)
        forged[25..33].copy_from_slice(&1_000u64.to_le_bytes());
        std::fs::write(&path, &forged).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("1000 edges"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn atomic_write_roundtrips_and_leaves_no_tmp() {
        let path = tmp("atomic");
        let e = sample();
        write_binary_atomic(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.src, e.src);
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "stale .tmp left behind");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn io_errors_carry_shard_path_context() {
        let path = tmp("does_not_exist");
        std::fs::remove_file(&path).ok();
        let err = read_binary(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("shard io error"), "{msg}");
        assert!(msg.contains("does_not_exist"), "{msg}");
        assert!(msg.contains("byte 0"), "{msg}");
    }

    #[test]
    fn header_only_read_validates() {
        let path = tmp("hdr");
        let e = sample();
        write_binary(&path, &e).unwrap();
        let (spec, n) = read_binary_header(&path).unwrap();
        assert_eq!(spec, e.spec);
        assert_eq!(n, 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shard_reader_opens_and_validates() {
        let dir = tmp("shdir");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let e = sample();
        write_binary(&dir.join("shard-00000.sgg"), &e).unwrap();
        write_binary(&dir.join("shard-00001.sgg"), &e).unwrap();
        let r = ShardReader::open(&dir).unwrap();
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        assert_eq!(r.spec(), e.spec);
        assert_eq!(r.total_edges(), 6);
        assert_eq!(r.max_shard_edges(), 3);
        assert_eq!(r.header(0).n_edges, 3);
        assert!(r.path(1).ends_with("shard-00001.sgg"));
        assert_eq!(r.read(0).unwrap().src, e.src);
        // a shard with a different spec is rejected at open
        let other = EdgeList::from_pairs(PartiteSpec::square(4), &[(0, 1)]);
        write_binary(&dir.join("shard-00002.sgg"), &other).unwrap();
        assert!(ShardReader::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_reader_spans_directories_in_name_order() {
        let (a, b) = (tmp("multi_a"), tmp("multi_b"));
        for d in [&a, &b] {
            std::fs::remove_dir_all(d).ok();
            std::fs::create_dir_all(d).unwrap();
        }
        let e = sample();
        // global chunk indices split across the two dirs, out of order
        write_binary(&a.join("shard-00002.sgg"), &e).unwrap();
        write_binary(&b.join("shard-00000.sgg"), &e).unwrap();
        write_binary(&b.join("shard-00001.sgg"), &e).unwrap();
        let r = ShardReader::open_dirs(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(r.len(), 3);
        assert!(r.path(0).ends_with("shard-00000.sgg"));
        assert!(r.path(2).ends_with("shard-00002.sgg"));
        // the same shard name in two dirs is a partitioning error
        write_binary(&a.join("shard-00001.sgg"), &e).unwrap();
        let err = ShardReader::open_dirs(&[a.clone(), b.clone()]).unwrap_err();
        assert!(err.to_string().contains("duplicate shard"), "{err}");
        for d in [&a, &b] {
            std::fs::remove_dir_all(d).ok();
        }
    }
}
