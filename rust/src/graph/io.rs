//! On-disk edge-list formats: a compact little-endian binary format for
//! shard outputs (16 bytes/edge) and a TSV text format for interchange.

use super::bipartite::PartiteSpec;
use super::edgelist::EdgeList;
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SGGEDGE1";

/// Write an edge list in the binary shard format:
/// `magic | n_src u64 | n_dst u64 | square u8 | n_edges u64 | (src,dst)*`.
pub fn write_binary(path: &Path, edges: &EdgeList) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&edges.spec.n_src.to_le_bytes())?;
    w.write_all(&edges.spec.n_dst.to_le_bytes())?;
    w.write_all(&[edges.spec.square as u8])?;
    w.write_all(&(edges.len() as u64).to_le_bytes())?;
    for (s, d) in edges.iter() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&d.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary shard format written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Data(format!("{}: bad magic", path.display())));
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<std::fs::File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n_src = read_u64(&mut r)?;
    let n_dst = read_u64(&mut r)?;
    let mut sq = [0u8; 1];
    r.read_exact(&mut sq)?;
    let spec = if sq[0] == 1 {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    let n_edges = u64::from_le_bytes(buf) as usize;
    let mut edges = EdgeList::with_capacity(spec, n_edges);
    let mut pair = [0u8; 16];
    for _ in 0..n_edges {
        r.read_exact(&mut pair)?;
        let s = u64::from_le_bytes(pair[0..8].try_into().unwrap());
        let d = u64::from_le_bytes(pair[8..16].try_into().unwrap());
        edges.push(s, d);
    }
    Ok(edges)
}

/// Write TSV: header `# n_src n_dst square` then `src\tdst` lines.
pub fn write_tsv(path: &Path, edges: &EdgeList) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# {} {} {}",
        edges.spec.n_src, edges.spec.n_dst, edges.spec.square as u8
    )?;
    for (s, d) in edges.iter() {
        writeln!(w, "{s}\t{d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read the TSV format written by [`write_tsv`].
pub fn read_tsv(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Data("empty tsv".into()))??;
    let parts: Vec<&str> = header.trim_start_matches('#').split_whitespace().collect();
    if parts.len() != 3 {
        return Err(Error::Data(format!("bad tsv header `{header}`")));
    }
    let n_src: u64 = parts[0].parse().map_err(|_| Error::Data("bad n_src".into()))?;
    let n_dst: u64 = parts[1].parse().map_err(|_| Error::Data("bad n_dst".into()))?;
    let square = parts[2] == "1";
    let spec = if square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut edges = EdgeList::new(spec);
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let s: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        let d: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        edges.push(s, d);
    }
    Ok(edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EdgeList {
        EdgeList::from_pairs(PartiteSpec::bipartite(10, 20), &[(0, 19), (9, 0), (5, 5)])
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn binary_roundtrip() {
        let path = tmp("bin");
        let e = sample();
        write_binary(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        assert_eq!(r.dst, e.dst);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn tsv_roundtrip() {
        let path = tmp("tsv");
        let e = sample();
        write_tsv(&path, &e).unwrap();
        let r = read_tsv(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
