//! On-disk edge-list formats: two binary shard encodings plus a TSV
//! text format for interchange.
//!
//! * `SGGEDGE1` — fixed-width little-endian records, 16 bytes/edge, in
//!   sampling order. Simple, seekable, byte-stable across runs.
//! * `SGGEDGE2` — edges sorted by `(src, dst)` within the shard and
//!   delta-encoded as LEB128 varints (typically 3–5× smaller). The
//!   header carries the payload length and an FNV-1a payload checksum;
//!   decoding is strict (exact edge count, exact payload consumption,
//!   overflow-checked deltas) and every corruption fails loudly with
//!   [`Error::ShardIo`] naming the file and byte offset.
//!
//! Readers auto-detect the format from the 8-byte magic, so a
//! [`ShardReader`] directory may mix formats (e.g. distributed hosts on
//! different settings). Because `SGGEDGE2` re-orders within a shard,
//! cross-format identity is defined on *decoded edges*: the
//! order-invariant [`decoded_checksum`] is the contract distributed
//! runs, resume, and the conformance harness pin — not raw bytes.
//!
//! Binary reads and writes move data through a reusable ~1 MiB record
//! buffer (one syscall per batch, not per edge), and every header is
//! validated against the actual file size before any allocation trusts
//! it. [`ShardReader`] opens a whole `ShardSink` directory, validates
//! every shard header up front, and serves shards one at a time — the
//! substrate of the streaming evaluation path
//! (`metrics::stream::evaluate_shards`).

use super::bipartite::PartiteSpec;
use super::edgelist::EdgeList;
use crate::error::{Error, Result};
use crate::util::checksum::{fnv1a_bytes, Fnv1a};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"SGGEDGE1";
const MAGIC2: &[u8; 8] = b"SGGEDGE2";

/// Fixed header size: magic + n_src + n_dst + square + n_edges.
const HEADER_LEN: usize = 8 + 8 + 8 + 1 + 8;

/// `SGGEDGE2` header: the `SGGEDGE1` fields + payload_len + payload FNV.
const HEADER2_LEN: usize = HEADER_LEN + 8 + 8;

/// Edges per IO batch (×16 bytes ≈ 1 MiB buffers).
const IO_BATCH_EDGES: usize = 65_536;

/// On-disk shard encoding. Decoded edges are identical across formats —
/// only bytes, ordering-within-shard, and size differ.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardFormat {
    /// `SGGEDGE1`: fixed-width 16 bytes/edge, sampling order preserved.
    #[default]
    Edge1,
    /// `SGGEDGE2`: sorted within shard, varint delta-encoded, payload
    /// checksum in the header.
    Edge2,
}

impl ShardFormat {
    /// Parse a spec/CLI format name (`sggedge1`/`edge1`, `sggedge2`/`edge2`).
    pub fn parse(s: &str) -> Option<ShardFormat> {
        match s {
            "sggedge1" | "edge1" => Some(ShardFormat::Edge1),
            "sggedge2" | "edge2" => Some(ShardFormat::Edge2),
            _ => None,
        }
    }

    /// Canonical spec name of this format.
    pub fn name(&self) -> &'static str {
        match self {
            ShardFormat::Edge1 => "sggedge1",
            ShardFormat::Edge2 => "sggedge2",
        }
    }
}

impl std::fmt::Display for ShardFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Error-mapping closure attaching shard-file context: a failed shard
/// in a thousand-shard run is identifiable from the message alone.
fn shard_io(path: &Path, offset: u64) -> impl FnOnce(std::io::Error) -> Error + '_ {
    move |source| Error::ShardIo { path: path.to_path_buf(), offset, source }
}

/// A corruption finding (not an OS error) reported with shard context:
/// same [`Error::ShardIo`] shape, `InvalidData` source, never transient.
fn shard_corrupt(path: &Path, offset: u64, msg: String) -> Error {
    Error::ShardIo {
        path: path.to_path_buf(),
        offset,
        source: std::io::Error::new(std::io::ErrorKind::InvalidData, msg),
    }
}

/// Append one LEB128 varint (7 data bits per byte, high bit = continue).
fn push_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

/// Read one LEB128 varint at `*pos`, advancing it. `None` on truncation
/// or a value that overflows u64 (more than 10 bytes / stray high bits).
fn read_varint(payload: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *payload.get(*pos)?;
        *pos += 1;
        let bits = (b & 0x7f) as u64;
        if shift == 63 && bits > 1 {
            return None;
        }
        v |= bits << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

/// Encode the `SGGEDGE2` payload: edges sorted by `(src, dst)` (the
/// input order is irrelevant — the format's canonical order is sorted),
/// then per edge `varint(Δsrc)` followed by `varint(dst − prev_dst)`
/// when Δsrc = 0 (runs within one source) or `varint(dst)` when the
/// source advanced. `buf` is cleared and reused.
fn encode_delta_payload(edges: &EdgeList, buf: &mut Vec<u8>) {
    buf.clear();
    append_delta_payload(edges, buf);
}

/// [`encode_delta_payload`] without the clear: the worker-encode path
/// stages the payload directly behind the header in one buffer.
fn append_delta_payload(edges: &EdgeList, buf: &mut Vec<u8>) {
    let mut keys: Vec<u128> = edges
        .iter()
        .map(|(s, d)| ((s as u128) << 64) | d as u128)
        .collect();
    keys.sort_unstable();
    let (mut prev_s, mut prev_d) = (0u64, 0u64);
    for k in keys {
        let s = (k >> 64) as u64;
        let d = k as u64;
        let ds = s - prev_s;
        push_varint(buf, ds);
        if ds == 0 {
            push_varint(buf, d - prev_d);
        } else {
            push_varint(buf, d);
        }
        prev_s = s;
        prev_d = d;
    }
}

/// Order-invariant multiset checksum of decoded edges: the wrapping sum
/// over edges of the FNV-1a digest of `src‖dst` (little-endian). Equal
/// for any within-shard ordering of the same edge multiset, so an
/// `SGGEDGE1` shard (sampling order) and its `SGGEDGE2` re-encoding
/// (sorted) checksum identically. This is the quantity distributed host
/// reports, `sgg merge` validation, and the conformance harness pin —
/// the **decoded-edge determinism contract** that replaced raw-byte
/// identity when the compressed format landed.
pub fn decoded_checksum(edges: &EdgeList) -> u64 {
    let mut sum = 0u64;
    for (s, d) in edges.iter() {
        let mut h = Fnv1a::new();
        h.write_u64(s);
        h.write_u64(d);
        sum = sum.wrapping_add(h.finish());
    }
    sum
}

/// [`decoded_checksum`] of one shard file in either format.
pub fn shard_decoded_checksum(path: &Path) -> Result<u64> {
    Ok(decoded_checksum(&read_binary(path)?))
}

/// Write an edge list in the binary shard format:
/// `magic | n_src u64 | n_dst u64 | square u8 | n_edges u64 | (src,dst)*`.
///
/// Records are staged in a reusable buffer and flushed in ~1 MiB
/// batches — one `write_all` per batch instead of per edge.
pub fn write_binary(path: &Path, edges: &EdgeList) -> Result<()> {
    let mut f = std::fs::File::create(path).map_err(shard_io(path, 0))?;
    let cap = HEADER_LEN + edges.len().min(IO_BATCH_EDGES) * 16;
    let mut buf: Vec<u8> = Vec::with_capacity(cap);
    let mut written = 0u64;
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&edges.spec.n_src.to_le_bytes());
    buf.extend_from_slice(&edges.spec.n_dst.to_le_bytes());
    buf.push(edges.spec.square as u8);
    buf.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    for (s, d) in edges.iter() {
        buf.extend_from_slice(&s.to_le_bytes());
        buf.extend_from_slice(&d.to_le_bytes());
        if buf.len() >= IO_BATCH_EDGES * 16 {
            f.write_all(&buf).map_err(shard_io(path, written))?;
            written += buf.len() as u64;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        f.write_all(&buf).map_err(shard_io(path, written))?;
    }
    Ok(())
}

/// Write an edge list in the `SGGEDGE2` format:
/// `magic | n_src u64 | n_dst u64 | square u8 | n_edges u64 |
/// payload_len u64 | payload_fnv u64 | delta-varint payload`.
///
/// Edges are sorted by `(src, dst)` during encoding regardless of input
/// order — sorted-within-shard is the format's canonical order.
/// `payload` is the caller's reusable encode scratch (cleared here), so
/// a sink writing thousands of shards allocates the staging buffer once.
pub fn write_binary2_with(path: &Path, edges: &EdgeList, payload: &mut Vec<u8>) -> Result<()> {
    encode_delta_payload(edges, payload);
    let mut f = std::fs::File::create(path).map_err(shard_io(path, 0))?;
    let mut head = Vec::with_capacity(HEADER2_LEN);
    head.extend_from_slice(MAGIC2);
    head.extend_from_slice(&edges.spec.n_src.to_le_bytes());
    head.extend_from_slice(&edges.spec.n_dst.to_le_bytes());
    head.push(edges.spec.square as u8);
    head.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    head.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    head.extend_from_slice(&fnv1a_bytes(payload).to_le_bytes());
    f.write_all(&head).map_err(shard_io(path, 0))?;
    f.write_all(payload).map_err(shard_io(path, HEADER2_LEN as u64))?;
    Ok(())
}

/// Write an edge list in the `SGGEDGE2` format (one-shot scratch).
pub fn write_binary2(path: &Path, edges: &EdgeList) -> Result<()> {
    write_binary2_with(path, edges, &mut Vec::new())
}

/// Write an edge list in the requested shard format.
pub fn write_shard(path: &Path, edges: &EdgeList, format: ShardFormat) -> Result<()> {
    match format {
        ShardFormat::Edge1 => write_binary(path, edges),
        ShardFormat::Edge2 => write_binary2(path, edges),
    }
}

/// A chunk already serialized to its final shard wire bytes — header,
/// payload, and (for `SGGEDGE2`) checksum included, byte-identical to
/// what [`write_shard`] would put on disk. Pool workers produce these
/// right after sampling, while the chunk is cache-hot and encoding is
/// embarrassingly parallel (per-chunk deterministic); the writer thread
/// then only sequences buffers and issues [`write_encoded_atomic`]
/// calls. The `bytes` buffer doubles as the recycle vessel of the
/// runner's byte-buffer arena.
#[derive(Clone, Debug)]
pub struct EncodedChunk {
    /// Wire format `bytes` is encoded in.
    pub format: ShardFormat,
    /// The complete shard file image.
    pub bytes: Vec<u8>,
}

/// Serialize `edges` into the complete shard file image for `format`,
/// byte-identical to the file [`write_shard`] produces. `out` is
/// cleared and reused — the worker-side encode stage recycles these
/// buffers through the runner's arena, so steady-state encoding
/// allocates nothing.
pub fn encode_chunk(edges: &EdgeList, format: ShardFormat, out: &mut Vec<u8>) {
    out.clear();
    match format {
        ShardFormat::Edge1 => {
            out.reserve(HEADER_LEN + edges.len() * 16);
            out.extend_from_slice(MAGIC);
            out.extend_from_slice(&edges.spec.n_src.to_le_bytes());
            out.extend_from_slice(&edges.spec.n_dst.to_le_bytes());
            out.push(edges.spec.square as u8);
            out.extend_from_slice(&(edges.len() as u64).to_le_bytes());
            for (s, d) in edges.iter() {
                out.extend_from_slice(&s.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
            }
        }
        ShardFormat::Edge2 => {
            out.resize(HEADER2_LEN, 0);
            append_delta_payload(edges, out);
            let payload_len = (out.len() - HEADER2_LEN) as u64;
            let fnv = fnv1a_bytes(&out[HEADER2_LEN..]);
            out[0..8].copy_from_slice(MAGIC2);
            out[8..16].copy_from_slice(&edges.spec.n_src.to_le_bytes());
            out[16..24].copy_from_slice(&edges.spec.n_dst.to_le_bytes());
            out[24] = edges.spec.square as u8;
            out[25..33].copy_from_slice(&(edges.len() as u64).to_le_bytes());
            out[33..41].copy_from_slice(&payload_len.to_le_bytes());
            out[41..49].copy_from_slice(&fnv.to_le_bytes());
        }
    }
}

/// Flush a freshly staged file's bytes to stable storage.
fn sync_file(path: &Path) -> Result<()> {
    std::fs::File::open(path).and_then(|f| f.sync_all()).map_err(shard_io(path, 0))
}

/// Flush a rename's directory entry to stable storage. Directory
/// handles can only be fsync'd on Unix; other platforms no-op.
fn sync_dir(dir: &Path) -> Result<()> {
    #[cfg(unix)]
    std::fs::File::open(dir).and_then(|f| f.sync_all()).map_err(shard_io(dir, 0))?;
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Persist an [`EncodedChunk`]'s bytes under `path` with crash
/// atomicity *and* durability: staged as `<path>.tmp`, fsync'd, renamed
/// into place, and the parent directory entry fsync'd — only then may
/// the shard count as a per-chunk completion record resume can trust.
pub fn write_encoded_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let staged = std::fs::File::create(&tmp)
        .and_then(|mut f| f.write_all(bytes).and_then(|_| f.sync_all()))
        .map_err(shard_io(&tmp, 0));
    if let Err(e) = staged {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path).map_err(shard_io(path, 0))?;
    match path.parent() {
        Some(dir) => sync_dir(dir),
        None => Ok(()),
    }
}

/// [`write_shard`] with crash atomicity and durability: the shard is
/// staged as `<path>.tmp`, fsync'd, and renamed into place only after
/// every byte is on stable storage; the parent directory entry is
/// fsync'd after the rename. An interrupted run therefore never leaves
/// a partial file under the final name, and a complete
/// `shard-NNNNN.sgg` doubles as that chunk's *durable* completion
/// record — the basis of `--resume` (without the fsyncs, a crash after
/// rename could surface a completion record with unflushed bytes).
/// `scratch` is the reusable `SGGEDGE2` encode buffer (unused by
/// `SGGEDGE1`).
pub fn write_shard_atomic_with(
    path: &Path,
    edges: &EdgeList,
    format: ShardFormat,
    scratch: &mut Vec<u8>,
) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let staged = match format {
        ShardFormat::Edge1 => write_binary(&tmp, edges),
        ShardFormat::Edge2 => write_binary2_with(&tmp, edges, scratch),
    };
    if let Err(e) = staged.and_then(|_| sync_file(&tmp)) {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path).map_err(shard_io(path, 0))?;
    match path.parent() {
        Some(dir) => sync_dir(dir),
        None => Ok(()),
    }
}

/// [`write_shard_atomic_with`] with a one-shot scratch buffer.
pub fn write_shard_atomic(path: &Path, edges: &EdgeList, format: ShardFormat) -> Result<()> {
    write_shard_atomic_with(path, edges, format, &mut Vec::new())
}

/// [`write_binary`] with crash atomicity (see [`write_shard_atomic_with`]).
pub fn write_binary_atomic(path: &Path, edges: &EdgeList) -> Result<()> {
    write_shard_atomic(path, edges, ShardFormat::Edge1)
}

/// Common header fields of either on-disk format, validated against the
/// actual file size. For `SGGEDGE1`, `payload_len` is the derived
/// `n_edges × 16` and `payload_fnv` is 0 (the format carries none).
struct RawHeader {
    format: ShardFormat,
    spec: PartiteSpec,
    n_edges: u64,
    payload_len: u64,
    payload_fnv: u64,
}

/// Decode the spec fields shared by both headers (bytes 8..33).
fn parse_spec_fields(h: &[u8]) -> (PartiteSpec, u64) {
    let n_src = u64::from_le_bytes(h[8..16].try_into().unwrap());
    let n_dst = u64::from_le_bytes(h[16..24].try_into().unwrap());
    let square = h[24] == 1;
    let n_edges = u64::from_le_bytes(h[25..33].try_into().unwrap());
    let spec = if square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    (spec, n_edges)
}

/// Check that an `SGGEDGE1` header's edge count matches the file's
/// actual size — a corrupt or truncated header must not drive
/// `with_capacity` or a silent short read.
fn validate_file_len(path: &Path, actual: u64, n_edges: u64) -> Result<()> {
    let expected = n_edges
        .checked_mul(16)
        .and_then(|b| b.checked_add(HEADER_LEN as u64))
        .ok_or_else(|| {
            Error::Data(format!(
                "{}: header edge count {n_edges} overflows the file size",
                path.display()
            ))
        })?;
    if actual != expected {
        return Err(Error::Data(format!(
            "{}: header claims {n_edges} edges ({expected} bytes) but file is {actual} bytes",
            path.display()
        )));
    }
    Ok(())
}

/// Open a shard, auto-detect its format from the magic, and validate
/// the header against the file size — the shared prelude of every
/// binary read path. The returned handle is positioned at the first
/// payload byte. A recognized `SGGEDGE` family magic with an unknown
/// version byte is an [`Error::ShardIo`] at offset 7 (a format this
/// build cannot read is shard-level corruption from its point of view);
/// a foreign magic stays the classic `bad magic` data error.
fn open_validated(path: &Path) -> Result<(std::fs::File, RawHeader)> {
    let mut f = std::fs::File::open(path).map_err(shard_io(path, 0))?;
    let actual = f.metadata().map_err(shard_io(path, 0))?.len();
    if actual < 8 {
        return Err(Error::Data(format!(
            "{}: {actual} bytes is shorter than the {HEADER_LEN}-byte header",
            path.display()
        )));
    }
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).map_err(shard_io(path, 0))?;
    if &magic == MAGIC {
        if (actual as usize) < HEADER_LEN {
            return Err(Error::Data(format!(
                "{}: {actual} bytes is shorter than the {HEADER_LEN}-byte header",
                path.display()
            )));
        }
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(&magic);
        f.read_exact(&mut h[8..]).map_err(shard_io(path, 8))?;
        let (spec, n_edges) = parse_spec_fields(&h);
        validate_file_len(path, actual, n_edges)?;
        let header = RawHeader {
            format: ShardFormat::Edge1,
            spec,
            n_edges,
            payload_len: n_edges * 16,
            payload_fnv: 0,
        };
        return Ok((f, header));
    }
    if &magic == MAGIC2 {
        if (actual as usize) < HEADER2_LEN {
            return Err(shard_corrupt(
                path,
                actual,
                format!("{actual} bytes is shorter than the {HEADER2_LEN}-byte SGGEDGE2 header"),
            ));
        }
        let mut h = [0u8; HEADER2_LEN];
        h[0..8].copy_from_slice(&magic);
        f.read_exact(&mut h[8..]).map_err(shard_io(path, 8))?;
        let (spec, n_edges) = parse_spec_fields(&h);
        let payload_len = u64::from_le_bytes(h[33..41].try_into().unwrap());
        let payload_fnv = u64::from_le_bytes(h[41..49].try_into().unwrap());
        let expected = payload_len.checked_add(HEADER2_LEN as u64).ok_or_else(|| {
            shard_corrupt(
                path,
                33,
                format!("header payload length {payload_len} overflows the file size"),
            )
        })?;
        if actual != expected {
            return Err(shard_corrupt(
                path,
                actual.min(expected),
                format!(
                    "header claims a {payload_len}-byte payload ({expected} bytes) \
                     but file is {actual} bytes"
                ),
            ));
        }
        // Each edge takes at least two varint bytes, so an inflated edge
        // count is rejected before it drives any allocation.
        let min_payload = n_edges.checked_mul(2).ok_or_else(|| {
            shard_corrupt(
                path,
                25,
                format!("header edge count {n_edges} overflows the payload size"),
            )
        })?;
        if payload_len < min_payload {
            return Err(shard_corrupt(
                path,
                25,
                format!("header claims {n_edges} edges but the payload is only {payload_len} bytes"),
            ));
        }
        let header =
            RawHeader { format: ShardFormat::Edge2, spec, n_edges, payload_len, payload_fnv };
        return Ok((f, header));
    }
    if magic.starts_with(b"SGGEDGE") {
        return Err(shard_corrupt(
            path,
            7,
            format!(
                "unsupported shard format version `{}` (expected SGGEDGE1 or SGGEDGE2)",
                magic[7].escape_ascii()
            ),
        ));
    }
    Err(Error::Data(format!("{}: bad magic", path.display())))
}

/// Read and validate only the header of a binary shard (either format):
/// its partite spec and edge count, checked against the file size.
pub fn read_binary_header(path: &Path) -> Result<(PartiteSpec, u64)> {
    let (_f, h) = open_validated(path)?;
    Ok((h.spec, h.n_edges))
}

/// Read and validate only the header of a binary shard, including which
/// on-disk format it uses.
pub fn read_shard_header(path: &Path) -> Result<ShardHeader> {
    let (_f, h) = open_validated(path)?;
    Ok(ShardHeader { spec: h.spec, n_edges: h.n_edges, format: h.format })
}

/// Read a binary shard in either format (auto-detected from the magic).
/// The header is validated against the file size before it is trusted
/// (no blind `with_capacity`, no silent truncation). `SGGEDGE1` records
/// stream through a reusable ~1 MiB batch buffer; `SGGEDGE2` payloads
/// are checksum-verified and then strictly decoded.
pub fn read_binary(path: &Path) -> Result<EdgeList> {
    let mut out = EdgeList::new(PartiteSpec::square(1));
    read_binary_into(path, &mut Vec::new(), &mut out)?;
    Ok(out)
}

/// [`read_binary`] into caller-owned buffers: `scratch` is the reusable
/// byte staging buffer (the `SGGEDGE2` payload / `SGGEDGE1` record
/// batch), `out` is reset to the shard's spec and filled with its
/// edges. Parallel decode partitions hold one `(scratch, out)` pair per
/// thread, so a whole-directory scan allocates nothing per shard.
pub fn read_binary_into(path: &Path, scratch: &mut Vec<u8>, out: &mut EdgeList) -> Result<()> {
    let (f, h) = open_validated(path)?;
    out.reset(h.spec);
    out.reserve(h.n_edges as usize);
    match h.format {
        ShardFormat::Edge1 => read_body1(f, &h, path, scratch, out),
        ShardFormat::Edge2 => read_body2(f, &h, path, scratch, out),
    }
}

/// Read the fixed-width `SGGEDGE1` body.
fn read_body1(
    mut f: std::fs::File,
    h: &RawHeader,
    path: &Path,
    scratch: &mut Vec<u8>,
    edges: &mut EdgeList,
) -> Result<()> {
    let n_edges = h.n_edges as usize;
    let batch = n_edges.clamp(1, IO_BATCH_EDGES) * 16;
    if scratch.len() < batch {
        scratch.resize(batch, 0);
    }
    let mut remaining = n_edges;
    while remaining > 0 {
        let take = remaining.min(IO_BATCH_EDGES);
        let bytes = &mut scratch[..take * 16];
        let offset = (HEADER_LEN + (n_edges - remaining) * 16) as u64;
        f.read_exact(bytes).map_err(shard_io(path, offset))?;
        for rec in bytes.chunks_exact(16) {
            let s = u64::from_le_bytes(rec[0..8].try_into().unwrap());
            let d = u64::from_le_bytes(rec[8..16].try_into().unwrap());
            edges.push(s, d);
        }
        remaining -= take;
    }
    Ok(())
}

/// Read and strictly decode the `SGGEDGE2` body: the payload must hash
/// to the header checksum, yield exactly `n_edges` edges, and be
/// consumed to the last byte. Every violation is an [`Error::ShardIo`]
/// at the offending byte offset.
fn read_body2(
    mut f: std::fs::File,
    h: &RawHeader,
    path: &Path,
    scratch: &mut Vec<u8>,
    edges: &mut EdgeList,
) -> Result<()> {
    // `open_validated` checked the file really holds `payload_len`
    // bytes, so this resize is bounded by the actual file size
    scratch.clear();
    scratch.resize(h.payload_len as usize, 0);
    let payload: &mut [u8] = scratch;
    f.read_exact(payload).map_err(shard_io(path, HEADER2_LEN as u64))?;
    let got = fnv1a_bytes(payload);
    if got != h.payload_fnv {
        return Err(shard_corrupt(
            path,
            HEADER2_LEN as u64,
            format!(
                "payload checksum mismatch: header says {:#018x}, payload hashes to {got:#018x}",
                h.payload_fnv
            ),
        ));
    }
    let n_edges = h.n_edges as usize;
    let mut pos = 0usize;
    let (mut prev_s, mut prev_d) = (0u64, 0u64);
    for i in 0..n_edges {
        let at = (HEADER2_LEN + pos) as u64;
        let ds = read_varint(payload, &mut pos).ok_or_else(|| {
            shard_corrupt(path, at, format!("edge {i}: truncated or malformed src varint"))
        })?;
        let s = prev_s.checked_add(ds).ok_or_else(|| {
            shard_corrupt(path, at, format!("edge {i}: source delta overflows u64"))
        })?;
        let at = (HEADER2_LEN + pos) as u64;
        let dd = read_varint(payload, &mut pos).ok_or_else(|| {
            shard_corrupt(path, at, format!("edge {i}: truncated or malformed dst varint"))
        })?;
        let d = if ds == 0 {
            prev_d.checked_add(dd).ok_or_else(|| {
                shard_corrupt(path, at, format!("edge {i}: destination delta overflows u64"))
            })?
        } else {
            dd
        };
        edges.push(s, d);
        prev_s = s;
        prev_d = d;
    }
    if pos != payload.len() {
        return Err(shard_corrupt(
            path,
            (HEADER2_LEN + pos) as u64,
            format!("{} trailing payload bytes after {n_edges} edges", payload.len() - pos),
        ));
    }
    Ok(())
}

/// Validated header of one shard in a [`ShardReader`] directory.
#[derive(Clone, Copy, Debug)]
pub struct ShardHeader {
    /// Partite layout declared by the shard.
    pub spec: PartiteSpec,
    /// Edge count declared by the shard (verified against its size).
    pub n_edges: u64,
    /// On-disk encoding, auto-detected from the magic. A directory may
    /// mix formats; only the partite spec must agree.
    pub format: ShardFormat,
}

/// A `ShardSink` output directory opened for chunk-by-chunk reading:
/// all `*.sgg` shards in path order, every header validated (magic,
/// size, and cross-shard spec consistency) before any body is read.
/// Reading one shard at a time keeps the resident set bounded by the
/// largest shard — the substrate of streamed evaluation.
pub struct ShardReader {
    paths: Vec<PathBuf>,
    headers: Vec<ShardHeader>,
    spec: PartiteSpec,
    /// Reusable decode scratch for the sequential [`ShardReader::read`]
    /// path, hoisted here so a whole-directory scan allocates the
    /// payload buffer once instead of once per shard. Parallel decode
    /// never touches this lock — each partition owns its own scratch
    /// via [`ShardReader::read_into`].
    scratch: std::sync::Mutex<Vec<u8>>,
}

impl ShardReader {
    /// Open a shard directory. Errors if the directory holds no `.sgg`
    /// files, any header is invalid, or the shards disagree on the
    /// partite spec.
    pub fn open(dir: &Path) -> Result<ShardReader> {
        ShardReader::open_dirs(std::slice::from_ref(&dir.to_path_buf()))
    }

    /// Open several shard directories as one logical graph — the
    /// unmerged output of a distributed run, where each host's directory
    /// holds a disjoint slice of the canonical `shard-NNNNN.sgg` series.
    /// Shards are ordered by file *name* across all directories (the
    /// zero-padded names make lexical order equal chunk-index order
    /// regardless of which directory a shard lives in), so the combined
    /// read order matches a merged single-directory run exactly.
    /// Duplicate shard names across directories are rejected: two hosts
    /// claiming the same chunk is a partitioning error, not an input to
    /// silently prefer one side of.
    pub fn open_dirs(dirs: &[PathBuf]) -> Result<ShardReader> {
        let mut paths: Vec<PathBuf> = Vec::new();
        for dir in dirs {
            for entry in std::fs::read_dir(dir)? {
                let p = entry?.path();
                if p.extension().map(|x| x == "sgg").unwrap_or(false) {
                    paths.push(p);
                }
            }
        }
        paths.sort_by(|a, b| a.file_name().cmp(&b.file_name()).then_with(|| a.cmp(b)));
        for w in paths.windows(2) {
            if w[0].file_name() == w[1].file_name() {
                return Err(Error::Data(format!(
                    "duplicate shard `{}` appears in more than one directory ({} and {})",
                    w[0].file_name().unwrap_or_default().to_string_lossy(),
                    w[0].display(),
                    w[1].display()
                )));
            }
        }
        if paths.is_empty() {
            let names: Vec<String> = dirs.iter().map(|d| d.display().to_string()).collect();
            return Err(Error::Data(format!("no shards in {}", names.join(", "))));
        }
        let mut headers = Vec::with_capacity(paths.len());
        for p in &paths {
            headers.push(read_shard_header(p)?);
        }
        let spec = headers[0].spec;
        for (h, p) in headers.iter().zip(&paths) {
            if h.spec != spec {
                return Err(Error::Data(format!(
                    "{}: shard spec {:?} differs from the directory's first shard {:?}",
                    p.display(),
                    h.spec,
                    spec
                )));
            }
        }
        Ok(ShardReader { paths, headers, spec, scratch: std::sync::Mutex::new(Vec::new()) })
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.paths.len()
    }

    /// True when the reader holds no shards (never, by construction —
    /// [`ShardReader::open`] rejects empty directories).
    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// The partite spec shared by every shard.
    pub fn spec(&self) -> PartiteSpec {
        self.spec
    }

    /// Total edges across all shards (from the validated headers).
    pub fn total_edges(&self) -> u64 {
        self.headers.iter().map(|h| h.n_edges).sum()
    }

    /// Largest single shard's edge count.
    pub fn max_shard_edges(&self) -> u64 {
        self.headers.iter().map(|h| h.n_edges).max().unwrap_or(0)
    }

    /// Validated header of shard `i`.
    pub fn header(&self, i: usize) -> &ShardHeader {
        &self.headers[i]
    }

    /// Path of shard `i`.
    pub fn path(&self, i: usize) -> &Path {
        &self.paths[i]
    }

    /// Read shard `i` into memory through the reader's shared decode
    /// scratch (one staging buffer for the whole sequential scan).
    pub fn read(&self, i: usize) -> Result<EdgeList> {
        let mut out = EdgeList::new(self.spec);
        let mut scratch = self.scratch.lock().unwrap();
        read_binary_into(&self.paths[i], &mut scratch, &mut out)?;
        Ok(out)
    }

    /// Read shard `i` into caller-owned buffers — the lock-free path
    /// parallel decode partitions use, one `(scratch, out)` pair per
    /// thread (see [`read_binary_into`]).
    pub fn read_into(&self, i: usize, scratch: &mut Vec<u8>, out: &mut EdgeList) -> Result<()> {
        read_binary_into(&self.paths[i], scratch, out)
    }

    /// Decode every shard across `workers` threads and reassemble them
    /// in shard order, also returning the wrapping sum of per-shard
    /// [`decoded_checksum`]s (the order-invariant edge-multiset pin the
    /// conformance harness records). Partitions are contiguous shard
    /// ranges with per-thread reused scratch, so the result — edges and
    /// checksum both — is identical at any worker count.
    pub fn read_all_checksummed(&self, workers: usize) -> Result<(EdgeList, u64)> {
        let runner = crate::pipeline::parallel::ParallelChunkRunner::new(workers.max(1), 1);
        let partials = runner.fold_indices(
            self.len(),
            |_| (EdgeList::new(self.spec), 0u64, Vec::new(), EdgeList::new(self.spec)),
            |(acc, sum, scratch, buf), i| {
                self.read_into(i, scratch, buf)?;
                *sum = sum.wrapping_add(decoded_checksum(buf));
                acc.extend_from(buf);
                Ok(())
            },
        )?;
        let mut all = EdgeList::with_capacity(self.spec, self.total_edges() as usize);
        let mut sum = 0u64;
        for (part, s, _, _) in partials {
            all.extend_from(&part);
            sum = sum.wrapping_add(s);
        }
        Ok((all, sum))
    }

    /// Decode every shard across `workers` threads and reassemble them
    /// in shard order (see [`ShardReader::read_all_checksummed`]).
    pub fn read_all(&self, workers: usize) -> Result<EdgeList> {
        Ok(self.read_all_checksummed(workers)?.0)
    }
}

/// Write TSV: header `# n_src n_dst square` then `src\tdst` lines.
pub fn write_tsv(path: &Path, edges: &EdgeList) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# {} {} {}",
        edges.spec.n_src, edges.spec.n_dst, edges.spec.square as u8
    )?;
    for (s, d) in edges.iter() {
        writeln!(w, "{s}\t{d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read the TSV format written by [`write_tsv`].
pub fn read_tsv(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Data("empty tsv".into()))??;
    let parts: Vec<&str> = header.trim_start_matches('#').split_whitespace().collect();
    if parts.len() != 3 {
        return Err(Error::Data(format!("bad tsv header `{header}`")));
    }
    let n_src: u64 = parts[0].parse().map_err(|_| Error::Data("bad n_src".into()))?;
    let n_dst: u64 = parts[1].parse().map_err(|_| Error::Data("bad n_dst".into()))?;
    let square = parts[2] == "1";
    let spec = if square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut edges = EdgeList::new(spec);
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let s: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        let d: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::Data(format!("bad edge line `{line}`")))?;
        edges.push(s, d);
    }
    Ok(edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EdgeList {
        EdgeList::from_pairs(PartiteSpec::bipartite(10, 20), &[(0, 19), (9, 0), (5, 5)])
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sgg_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn binary_roundtrip() {
        let path = tmp("bin");
        let e = sample();
        write_binary(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        assert_eq!(r.dst, e.dst);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_roundtrip_across_batch_boundary() {
        // more edges than one IO batch, with a ragged tail
        let path = tmp("batch");
        let n = IO_BATCH_EDGES * 2 + 17;
        let mut e = EdgeList::with_capacity(PartiteSpec::square(1 << 20), n);
        for i in 0..n as u64 {
            e.push(i % (1 << 20), (i * 7) % (1 << 20));
        }
        write_binary(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.src, e.src);
        assert_eq!(r.dst, e.dst);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn tsv_roundtrip() {
        let path = tmp("tsv");
        let e = sample();
        write_tsv(&path, &e).unwrap();
        let r = read_tsv(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        assert_eq!(r.src, e.src);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_size_mismatch() {
        let path = tmp("sizemismatch");
        let e = sample();
        write_binary(&path, &e).unwrap();
        // truncate the body: header still claims 3 edges
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
        // inflate the header's edge count without growing the file
        let mut forged = bytes.clone();
        forged[25..33].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &forged).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // a plausible but wrong count is also rejected (no huge
        // allocation, no short read)
        forged[25..33].copy_from_slice(&1_000u64.to_le_bytes());
        std::fs::write(&path, &forged).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(err.to_string().contains("1000 edges"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn atomic_write_roundtrips_and_leaves_no_tmp() {
        let path = tmp("atomic");
        let e = sample();
        write_binary_atomic(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.src, e.src);
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "stale .tmp left behind");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn io_errors_carry_shard_path_context() {
        let path = tmp("does_not_exist");
        std::fs::remove_file(&path).ok();
        let err = read_binary(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("shard io error"), "{msg}");
        assert!(msg.contains("does_not_exist"), "{msg}");
        assert!(msg.contains("byte 0"), "{msg}");
    }

    #[test]
    fn header_only_read_validates() {
        let path = tmp("hdr");
        let e = sample();
        write_binary(&path, &e).unwrap();
        let (spec, n) = read_binary_header(&path).unwrap();
        assert_eq!(spec, e.spec);
        assert_eq!(n, 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shard_reader_opens_and_validates() {
        let dir = tmp("shdir");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let e = sample();
        write_binary(&dir.join("shard-00000.sgg"), &e).unwrap();
        write_binary(&dir.join("shard-00001.sgg"), &e).unwrap();
        let r = ShardReader::open(&dir).unwrap();
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        assert_eq!(r.spec(), e.spec);
        assert_eq!(r.total_edges(), 6);
        assert_eq!(r.max_shard_edges(), 3);
        assert_eq!(r.header(0).n_edges, 3);
        assert!(r.path(1).ends_with("shard-00001.sgg"));
        assert_eq!(r.read(0).unwrap().src, e.src);
        // a shard with a different spec is rejected at open
        let other = EdgeList::from_pairs(PartiteSpec::square(4), &[(0, 1)]);
        write_binary(&dir.join("shard-00002.sgg"), &other).unwrap();
        assert!(ShardReader::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_reader_spans_directories_in_name_order() {
        let (a, b) = (tmp("multi_a"), tmp("multi_b"));
        for d in [&a, &b] {
            std::fs::remove_dir_all(d).ok();
            std::fs::create_dir_all(d).unwrap();
        }
        let e = sample();
        // global chunk indices split across the two dirs, out of order
        write_binary(&a.join("shard-00002.sgg"), &e).unwrap();
        write_binary(&b.join("shard-00000.sgg"), &e).unwrap();
        write_binary(&b.join("shard-00001.sgg"), &e).unwrap();
        let r = ShardReader::open_dirs(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(r.len(), 3);
        assert!(r.path(0).ends_with("shard-00000.sgg"));
        assert!(r.path(2).ends_with("shard-00002.sgg"));
        // the same shard name in two dirs is a partitioning error
        write_binary(&a.join("shard-00001.sgg"), &e).unwrap();
        let err = ShardReader::open_dirs(&[a.clone(), b.clone()]).unwrap_err();
        assert!(err.to_string().contains("duplicate shard"), "{err}");
        for d in [&a, &b] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn varint_roundtrips_across_the_u64_range() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX - 1, u64::MAX];
        for &v in &values {
            push_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(read_varint(&buf, &mut pos), Some(v));
        }
        assert_eq!(pos, buf.len());
        // an 11-byte continuation chain overflows
        let over = vec![0x80u8; 10];
        let mut pos = 0;
        assert_eq!(read_varint(&over, &mut pos), None);
        // stray high bits in the 10th byte overflow too
        let mut stray = vec![0x80u8; 9];
        stray.push(0x02);
        let mut pos = 0;
        assert_eq!(read_varint(&stray, &mut pos), None);
    }

    #[test]
    fn binary2_roundtrip_is_sorted_multiset() {
        let path = tmp("bin2");
        // deliberately unsorted input with a duplicate
        let e = EdgeList::from_pairs(
            PartiteSpec::bipartite(10, 20),
            &[(9, 0), (0, 19), (5, 5), (0, 3), (5, 5)],
        );
        write_binary2(&path, &e).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(r.spec, e.spec);
        let pairs: Vec<_> = r.iter().collect();
        assert_eq!(pairs, vec![(0, 3), (0, 19), (5, 5), (5, 5), (9, 0)]);
        assert_eq!(decoded_checksum(&r), decoded_checksum(&e));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary2_roundtrips_edge_cases() {
        // zero edges, one edge, and extreme ids (u64::MAX endpoints)
        let path = tmp("bin2_edge");
        let huge = PartiteSpec::square(u64::MAX);
        for pairs in [
            vec![],
            vec![(0u64, 0u64)],
            vec![(u64::MAX - 1, u64::MAX), (u64::MAX - 1, 0), (0, u64::MAX)],
        ] {
            let e = EdgeList::from_pairs(huge, &pairs);
            write_binary2(&path, &e).unwrap();
            let r = read_binary(&path).unwrap();
            let mut sorted = e.clone();
            sorted.sort_within();
            assert_eq!(r.src, sorted.src);
            assert_eq!(r.dst, sorted.dst);
            let header = read_shard_header(&path).unwrap();
            assert_eq!(header.n_edges, pairs.len() as u64);
            assert_eq!(header.format, ShardFormat::Edge2);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary2_is_smaller_than_binary1() {
        let path1 = tmp("size1");
        let path2 = tmp("size2");
        let mut e = EdgeList::with_capacity(PartiteSpec::square(1 << 16), 4096);
        for i in 0..4096u64 {
            e.push((i * 37) % (1 << 16), (i * 101) % (1 << 16));
        }
        write_binary(&path1, &e).unwrap();
        write_binary2(&path2, &e).unwrap();
        let s1 = std::fs::metadata(&path1).unwrap().len();
        let s2 = std::fs::metadata(&path2).unwrap().len();
        assert!(s2 * 2 <= s1, "SGGEDGE2 {s2} B not 2x smaller than SGGEDGE1 {s1} B");
        std::fs::remove_file(path1).ok();
        std::fs::remove_file(path2).ok();
    }

    #[test]
    fn binary2_rejects_corruption_with_shard_io() {
        let path = tmp("bin2_corrupt");
        let e = sample();
        write_binary2(&path, &e).unwrap();
        let good = std::fs::read(&path).unwrap();

        // truncated payload: header/file size disagree
        std::fs::write(&path, &good[..good.len() - 1]).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(matches!(err, Error::ShardIo { .. }), "{err}");

        // flip a payload bit: checksum mismatch
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(matches!(err, Error::ShardIo { .. }), "{err}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");

        // unknown future version in the magic
        let mut vers = good.clone();
        vers[7] = b'9';
        std::fs::write(&path, &vers).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(matches!(err, Error::ShardIo { offset: 7, .. }), "{err}");
        assert!(err.to_string().contains("unsupported shard format version"), "{err}");

        // inflated edge count cannot drive an allocation
        let mut forged = good.clone();
        forged[25..33].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &forged).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(matches!(err, Error::ShardIo { .. }), "{err}");

        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary2_rejects_trailing_payload_bytes() {
        // a payload that is longer than its edges decode to, with a
        // matching checksum and file size, is still rejected
        let path = tmp("bin2_trailing");
        let spec = PartiteSpec::bipartite(4, 4);
        let mut payload = Vec::new();
        push_varint(&mut payload, 1); // edge 0: src 1
        push_varint(&mut payload, 2); //         dst 2
        push_varint(&mut payload, 0); // trailing garbage
        let mut head = Vec::new();
        head.extend_from_slice(MAGIC2);
        head.extend_from_slice(&spec.n_src.to_le_bytes());
        head.extend_from_slice(&spec.n_dst.to_le_bytes());
        head.push(0);
        head.extend_from_slice(&1u64.to_le_bytes());
        head.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        head.extend_from_slice(&fnv1a_bytes(&payload).to_le_bytes());
        head.extend_from_slice(&payload);
        std::fs::write(&path, &head).unwrap();
        let err = read_binary(&path).unwrap_err();
        assert!(matches!(err, Error::ShardIo { .. }), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn decoded_checksum_is_order_invariant_and_multiset_sensitive() {
        let spec = PartiteSpec::bipartite(10, 10);
        let a = EdgeList::from_pairs(spec, &[(1, 2), (3, 4), (1, 2)]);
        let b = EdgeList::from_pairs(spec, &[(3, 4), (1, 2), (1, 2)]);
        assert_eq!(decoded_checksum(&a), decoded_checksum(&b));
        // dropping a duplicate changes the multiset, so the checksum moves
        let c = EdgeList::from_pairs(spec, &[(3, 4), (1, 2)]);
        assert_ne!(decoded_checksum(&a), decoded_checksum(&c));
        // swapping src/dst of an edge moves it too (direction matters)
        let d = EdgeList::from_pairs(spec, &[(2, 1), (4, 3), (2, 1)]);
        assert_ne!(decoded_checksum(&a), decoded_checksum(&d));
        assert_eq!(decoded_checksum(&EdgeList::new(spec)), 0);
    }

    #[test]
    fn shard_decoded_checksum_matches_across_formats() {
        let p1 = tmp("dc1");
        let p2 = tmp("dc2");
        let e = sample();
        write_binary(&p1, &e).unwrap();
        write_binary2(&p2, &e).unwrap();
        assert_eq!(
            shard_decoded_checksum(&p1).unwrap(),
            shard_decoded_checksum(&p2).unwrap()
        );
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn shard_reader_tolerates_mixed_formats() {
        let dir = tmp("mixdir");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let e = sample();
        write_binary(&dir.join("shard-00000.sgg"), &e).unwrap();
        write_binary2(&dir.join("shard-00001.sgg"), &e).unwrap();
        let r = ShardReader::open(&dir).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.total_edges(), 6);
        assert_eq!(r.header(0).format, ShardFormat::Edge1);
        assert_eq!(r.header(1).format, ShardFormat::Edge2);
        assert_eq!(
            decoded_checksum(&r.read(0).unwrap()),
            decoded_checksum(&r.read(1).unwrap())
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn encode_chunk_matches_file_writers_byte_for_byte() {
        let path = tmp("enc");
        let mut e = EdgeList::with_capacity(PartiteSpec::square(1 << 12), 2048);
        for i in 0..2048u64 {
            e.push((i * 37) % (1 << 12), (i * 101) % (1 << 12));
        }
        let mut out = vec![0xAAu8; 7]; // dirty buffer: encode must clear it
        for format in [ShardFormat::Edge1, ShardFormat::Edge2] {
            write_shard(&path, &e, format).unwrap();
            encode_chunk(&e, format, &mut out);
            assert_eq!(out, std::fs::read(&path).unwrap(), "{format}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn write_encoded_atomic_roundtrips_and_leaves_no_tmp() {
        let path = tmp("enc_atomic");
        let e = sample();
        let mut bytes = Vec::new();
        encode_chunk(&e, ShardFormat::Edge2, &mut bytes);
        write_encoded_atomic(&path, &bytes).unwrap();
        let r = read_binary(&path).unwrap();
        assert_eq!(decoded_checksum(&r), decoded_checksum(&e));
        assert_eq!(read_shard_header(&path).unwrap().format, ShardFormat::Edge2);
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "stale .tmp left behind");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_binary_into_reuses_buffers_across_formats() {
        let (p1, p2) = (tmp("into1"), tmp("into2"));
        let e = sample();
        write_binary(&p1, &e).unwrap();
        write_binary2(&p2, &e).unwrap();
        let mut scratch = Vec::new();
        let mut out = EdgeList::new(PartiteSpec::square(1));
        read_binary_into(&p1, &mut scratch, &mut out).unwrap();
        assert_eq!(out.src, e.src);
        assert_eq!(out.spec, e.spec);
        // second read resets `out` rather than appending, reusing both
        // the staging scratch and the edge buffers
        read_binary_into(&p2, &mut scratch, &mut out).unwrap();
        assert_eq!(out.len(), e.len());
        assert_eq!(decoded_checksum(&out), decoded_checksum(&e));
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn parallel_read_all_matches_sequential_at_any_worker_count() {
        let dir = tmp("par_read");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spec = PartiteSpec::square(1 << 10);
        for i in 0..7u64 {
            let mut e = EdgeList::new(spec);
            for j in 0..(50 + i * 13) {
                e.push((i * 131 + j) % (1 << 10), (j * 17) % (1 << 10));
            }
            let fmt = if i % 2 == 0 { ShardFormat::Edge1 } else { ShardFormat::Edge2 };
            write_shard(&dir.join(format!("shard-{i:05}.sgg")), &e, fmt).unwrap();
        }
        let r = ShardReader::open(&dir).unwrap();
        let mut seq = EdgeList::with_capacity(spec, r.total_edges() as usize);
        let mut sum = 0u64;
        for i in 0..r.len() {
            let e = r.read(i).unwrap();
            sum = sum.wrapping_add(decoded_checksum(&e));
            seq.extend_from(&e);
        }
        for workers in [1usize, 2, 4] {
            let (all, csum) = r.read_all_checksummed(workers).unwrap();
            assert_eq!(all.src, seq.src, "workers={workers}");
            assert_eq!(all.dst, seq.dst, "workers={workers}");
            assert_eq!(csum, sum, "workers={workers}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_format_parses_spec_names() {
        assert_eq!(ShardFormat::parse("sggedge1"), Some(ShardFormat::Edge1));
        assert_eq!(ShardFormat::parse("edge2"), Some(ShardFormat::Edge2));
        assert_eq!(ShardFormat::parse("parquet"), None);
        assert_eq!(ShardFormat::Edge2.name(), "sggedge2");
        assert_eq!(ShardFormat::default(), ShardFormat::Edge1);
    }
}
