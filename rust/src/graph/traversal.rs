//! BFS-based traversals: single-source shortest hop counts, connected
//! components, and sampled pair reachability used by the hop-plot metric.

use super::csr::Csr;

/// BFS hop distances from `source` (u32::MAX = unreachable).
pub fn bfs_distances(csr: &Csr, source: u64) -> Vec<u32> {
    let n = csr.n_nodes as usize;
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    dist[source as usize] = 0;
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        let dv = dist[v as usize];
        for &w in csr.neighbors(v) {
            if dist[w as usize] == u32::MAX {
                dist[w as usize] = dv + 1;
                queue.push_back(w);
            }
        }
    }
    dist
}

/// Connected components (undirected view expected): returns (labels,
/// component count). Labels are in [0, count).
pub fn connected_components(csr: &Csr) -> (Vec<u32>, usize) {
    let n = csr.n_nodes as usize;
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut stack = Vec::new();
    for start in 0..n {
        if label[start] != u32::MAX {
            continue;
        }
        label[start] = next;
        stack.push(start as u64);
        while let Some(v) = stack.pop() {
            for &w in csr.neighbors(v) {
                if label[w as usize] == u32::MAX {
                    label[w as usize] = next;
                    stack.push(w);
                }
            }
        }
        next += 1;
    }
    (label, next as usize)
}

/// Size of the largest connected component.
pub fn largest_component(csr: &Csr) -> usize {
    let (labels, count) = connected_components(csr);
    let mut sizes = vec![0usize; count];
    for l in labels {
        sizes[l as usize] += 1;
    }
    sizes.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeList, PartiteSpec};

    fn two_components() -> Csr {
        let e = EdgeList::from_pairs(
            PartiteSpec::square(6),
            &[(0, 1), (1, 2), (3, 4)],
        );
        Csr::undirected(&e)
    }

    #[test]
    fn bfs_distances_chain() {
        let csr = two_components();
        let d = bfs_distances(&csr, 0);
        assert_eq!(d[0], 0);
        assert_eq!(d[1], 1);
        assert_eq!(d[2], 2);
        assert_eq!(d[3], u32::MAX);
    }

    #[test]
    fn components_counted() {
        let csr = two_components();
        let (labels, count) = connected_components(&csr);
        assert_eq!(count, 3); // {0,1,2}, {3,4}, {5}
        assert_eq!(labels[0], labels[2]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn largest_component_size() {
        let csr = two_components();
        assert_eq!(largest_component(&csr), 3);
    }
}
