//! PJRT-backed GAN compute: implements [`crate::featgen::gan::GanBackend`]
//! over the `gan_train_w{W}` / `gan_sample_w{W}` artifacts.
//!
//! The encoded feature width is padded into the smallest artifact bucket;
//! α slots and one-hots are zero-padded (decode ignores the padding).
//! Training runs `epochs` passes of minibatch Adam steps entirely from
//! Rust — each step is one PJRT execution of the fused train-step HLO.

use super::literal::{f32_scalar, f32_tensor, to_f32_scalar, to_f32_vec};
use super::{ParamSpec, Runtime};
use crate::error::{Error, Result};
use crate::featgen::gan::GanBackend;
use crate::util::rng::Pcg64;
use crate::xla;
use std::rc::Rc;

/// Training hyper-parameters (paper §12: Adam, lr 1e-3, ~5 epochs
/// suffices for most datasets).
#[derive(Clone, Copy, Debug)]
pub struct GanTrainConfig {
    /// Training epochs.
    pub epochs: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Cap on train steps (keeps big sweeps bounded).
    pub max_steps: usize,
}

impl Default for GanTrainConfig {
    fn default() -> Self {
        GanTrainConfig { epochs: 5, lr: 1e-3, max_steps: 400 }
    }
}

/// PJRT GAN backend over a shared [`Runtime`].
pub struct PjrtGanBackend {
    rt: Rc<Runtime>,
    cfg: GanTrainConfig,
    widths: Vec<usize>,
    batch: usize,
    z_dim: usize,
    /// fitted state
    bucket: usize,
    manifest: Vec<ParamSpec>,
    g_len: usize,
    params: Vec<Vec<f32>>,
    /// training losses per step (d_loss, g_loss) for diagnostics
    pub loss_history: Vec<(f32, f32)>,
}

impl PjrtGanBackend {
    /// Create over a runtime; reads bucket constants from artifacts.json.
    pub fn new(rt: Rc<Runtime>, cfg: GanTrainConfig) -> Result<Self> {
        let consts = rt.constants()?;
        let widths: Vec<usize> = consts
            .get("gan_widths")
            .and_then(|w| w.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as usize).collect())
            .unwrap_or_else(|| vec![128, 256]);
        let batch = consts.get("gan_batch").and_then(|x| x.as_f64()).unwrap_or(256.0) as usize;
        let z_dim = consts.get("gan_z_dim").and_then(|x| x.as_f64()).unwrap_or(64.0) as usize;
        Ok(PjrtGanBackend {
            rt,
            cfg,
            widths,
            batch,
            z_dim,
            bucket: 0,
            manifest: Vec::new(),
            g_len: 0,
            params: Vec::new(),
            loss_history: Vec::new(),
        })
    }

    /// Smallest bucket ≥ width.
    fn pick_bucket(&self, width: usize) -> Result<usize> {
        self.widths
            .iter()
            .copied()
            .filter(|&b| b >= width)
            .min()
            .ok_or_else(|| {
                Error::Config(format!(
                    "encoded width {width} exceeds largest GAN bucket {:?}",
                    self.widths
                ))
            })
    }

    fn pad_rows(&self, encoded: &[f32], n_rows: usize, width: usize, bucket: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n_rows * bucket];
        for r in 0..n_rows {
            out[r * bucket..r * bucket + width]
                .copy_from_slice(&encoded[r * width..(r + 1) * width]);
        }
        out
    }
}

impl GanBackend for PjrtGanBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train(&mut self, encoded: &[f32], n_rows: usize, width: usize, seed: u64) -> Result<()> {
        let bucket = self.pick_bucket(width)?;
        let name = format!("gan_train_w{bucket}");
        let exe = self.rt.executable(&name)?;
        let manifest = self.rt.manifest(&name)?;
        let mut params = self.rt.init_params(&name, &manifest)?;
        let mut m: Vec<Vec<f32>> = manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let mut v: Vec<Vec<f32>> = manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let padded = self.pad_rows(encoded, n_rows, width, bucket);
        let mut rng = Pcg64::new(seed);
        let steps_per_epoch = (n_rows / self.batch).max(1);
        let total_steps = (self.cfg.epochs * steps_per_epoch).min(self.cfg.max_steps).max(1);
        self.loss_history.clear();

        let mut real = vec![0.0f32; self.batch * bucket];
        let mut z = vec![0.0f32; self.batch * self.z_dim];
        for t in 0..total_steps {
            // minibatch with replacement
            for b in 0..self.batch {
                let r = rng.below_usize(n_rows);
                real[b * bucket..(b + 1) * bucket]
                    .copy_from_slice(&padded[r * bucket..(r + 1) * bucket]);
            }
            for zi in z.iter_mut() {
                *zi = rng.normal() as f32;
            }
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * manifest.len() + 4);
            for (spec, p) in manifest.iter().zip(&params) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in manifest.iter().zip(&m) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in manifest.iter().zip(&v) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            inputs.push(f32_scalar(t as f32));
            inputs.push(f32_tensor(&real, &[self.batch, bucket])?);
            inputs.push(f32_tensor(&z, &[self.batch, self.z_dim])?);
            inputs.push(f32_scalar(self.cfg.lr));
            let out = self.rt.run(&exe, &inputs)?;
            let k = manifest.len();
            for i in 0..k {
                params[i] = to_f32_vec(&out[i])?;
                m[i] = to_f32_vec(&out[k + i])?;
                v[i] = to_f32_vec(&out[2 * k + i])?;
            }
            let d_loss = to_f32_scalar(&out[3 * k])?;
            let g_loss = to_f32_scalar(&out[3 * k + 1])?;
            self.loss_history.push((d_loss, g_loss));
        }
        self.bucket = bucket;
        self.g_len = manifest.iter().filter(|p| p.name.starts_with("g_")).count();
        self.manifest = manifest;
        self.params = params;
        Ok(())
    }

    fn sample(&self, n: usize, width: usize, seed: u64) -> Result<Vec<f32>> {
        if self.params.is_empty() {
            return Err(Error::NotFitted("PjrtGanBackend".into()));
        }
        let bucket = self.bucket;
        let exe = self.rt.executable(&format!("gan_sample_w{bucket}"))?;
        let mut rng = Pcg64::new(seed);
        let mut out = vec![0.0f32; n * width];
        let mut produced = 0usize;
        let mut z = vec![0.0f32; self.batch * self.z_dim];
        while produced < n {
            for zi in z.iter_mut() {
                *zi = rng.normal() as f32;
            }
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.g_len + 1);
            for i in 0..self.g_len {
                inputs.push(f32_tensor(&self.params[i], &self.manifest[i].shape)?);
            }
            inputs.push(f32_tensor(&z, &[self.batch, self.z_dim])?);
            let res = self.rt.run(&exe, &inputs)?;
            let fake = to_f32_vec(&res[0])?;
            let take = (n - produced).min(self.batch);
            for r in 0..take {
                out[(produced + r) * width..(produced + r + 1) * width]
                    .copy_from_slice(&fake[r * bucket..r * bucket + width]);
            }
            produced += take;
        }
        Ok(out)
    }
}
