//! PJRT runtime (L3 ↔ L1/L2 boundary).
//!
//! Loads the HLO-text artifacts produced by ``make artifacts``
//! (`python/compile/aot.py`), compiles them once on the PJRT CPU client,
//! and exposes typed executors: [`gan_exec::PjrtGanBackend`] for the
//! feature GAN and [`gnn_exec`] for the downstream GNN experiments.
//! Python never runs at generation time — the Rust binary is
//! self-contained once `artifacts/` exists.

pub mod gan_exec;
pub mod gnn_exec;
pub mod literal;

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::xla;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::cell::RefCell;

/// A parameter manifest entry (name + shape) mirrored from the python
/// side (`*.manifest.json`).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// Parameter name.
    pub name: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Artifact directory resolution: `SGG_ARTIFACTS` env var, else
/// `./artifacts` relative to the working directory.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SGG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if the AOT artifacts are present (runtime-dependent experiments
/// are skipped gracefully when not).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("artifacts.json").exists()
}

/// Per-thread shared runtime handle. The `xla` crate's PJRT client is
/// `Rc`-based (not `Send`), so the runtime is thread-local: all PJRT
/// execution in SGG happens on the coordinator thread, which matches the
/// single-device CPU setup.
pub fn global() -> Result<std::rc::Rc<Runtime>> {
    thread_local! {
        static GLOBAL: std::cell::RefCell<Option<std::rc::Rc<Runtime>>> =
            const { std::cell::RefCell::new(None) };
    }
    GLOBAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        if let Some(rt) = slot.as_ref() {
            return Ok(rt.clone());
        }
        let rt = std::rc::Rc::new(Runtime::cpu()?);
        *slot = Some(rt.clone());
        Ok(rt)
    })
}

/// Shared PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over the default artifact directory.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir: artifacts_dir(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Artifact directory in use.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile (or fetch cached) an artifact by stem name.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::MissingArtifact(name.to_string()));
        }
        crate::info!("compiling artifact `{name}`");
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Run an executable on literal inputs; outputs are the decomposed
    /// top-level tuple (jax lowering uses `return_tuple=True`).
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Load a parameter manifest.
    pub fn manifest(&self, name: &str) -> Result<Vec<ParamSpec>> {
        let path = self.dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|_| Error::MissingArtifact(format!("{name}.manifest.json")))?;
        let v = Json::parse(&text).map_err(Error::Data)?;
        let params = v
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| Error::Data("manifest missing params".into()))?;
        params
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| Error::Data("param missing name".into()))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| Error::Data("param missing shape".into()))?
                    .iter()
                    .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                    .collect();
                Ok(ParamSpec { name, shape })
            })
            .collect()
    }

    /// Load the initial parameter pack (`*.init.bin`, f32 LE, manifest
    /// order) and split it into per-parameter vectors.
    pub fn init_params(&self, name: &str, manifest: &[ParamSpec]) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(format!("{name}.init.bin"));
        let bytes = std::fs::read(&path)
            .map_err(|_| Error::MissingArtifact(format!("{name}.init.bin")))?;
        let total: usize = manifest.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(Error::Data(format!(
                "{name}.init.bin: {} bytes, manifest wants {}",
                bytes.len(),
                total * 4
            )));
        }
        let mut flat = Vec::with_capacity(total);
        for c in bytes.chunks_exact(4) {
            flat.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut out = Vec::with_capacity(manifest.len());
        let mut off = 0usize;
        for p in manifest {
            let n = p.numel();
            out.push(flat[off..off + n].to_vec());
            off += n;
        }
        Ok(out)
    }

    /// Global constants emitted by aot.py (`artifacts.json`).
    pub fn constants(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.dir.join("artifacts.json"))
            .map_err(|_| Error::MissingArtifact("artifacts.json".into()))?;
        Json::parse(&text).map_err(Error::Data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ (integration)
    // so `cargo test --lib` stays artifact-free.

    #[test]
    fn param_spec_numel() {
        let p = ParamSpec { name: "w".into(), shape: vec![3, 4] };
        assert_eq!(p.numel(), 12);
        let s = ParamSpec { name: "b".into(), shape: vec![] };
        assert_eq!(s.numel(), 1);
    }
}
