//! Literal packing helpers: Rust buffers ↔ XLA literals.

use crate::error::Result;
use crate::xla;

/// f32 tensor literal from a flat slice + shape.
pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let numel: usize = shape.iter().product::<usize>().max(1);
    debug_assert_eq!(numel, data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// f32 scalar literal.
pub fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// i32 vector literal.
pub fn i32_vector(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an f32 scalar.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    Ok(v.first().copied().unwrap_or(f32::NAN))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let lit = f32_tensor(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = f32_scalar(2.5);
        assert_eq!(to_f32_scalar(&lit).unwrap(), 2.5);
    }

    #[test]
    fn empty_shape_is_scalar() {
        let lit = f32_tensor(&[7.0], &[]).unwrap();
        assert_eq!(to_f32_scalar(&lit).unwrap(), 7.0);
    }
}
