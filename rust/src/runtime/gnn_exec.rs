//! GNN executors over the AOT artifacts: full-batch node classification
//! (GCN/GAT, Tables 4/7, Figure 4) and edge classification (IEEE-Fraud).
//!
//! Graph prep (dense normalized adjacency, padding into the artifact's
//! node bucket, masks) happens here in Rust; each train epoch is one PJRT
//! execution.

use super::literal::{f32_scalar, f32_tensor, i32_vector, to_f32_scalar, to_f32_vec};
use super::{ParamSpec, Runtime};
use crate::error::{Error, Result};
use crate::graph::{Csr, EdgeList};
use crate::util::rng::Pcg64;
use crate::xla;
use std::rc::Rc;

/// Feature width / class count compiled into the GNN artifacts.
pub const FEAT: usize = 32;
/// Class count compiled into the node-classification artifacts.
pub const CLASSES: usize = 8;
/// Edge-feature width compiled into the edge-classifier artifacts.
pub const EDGE_FEAT: usize = 16;

/// Which node-classification model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GnnKind {
    /// Graph convolutional network.
    Gcn,
    /// Graph attention network.
    Gat,
}

impl GnnKind {
    /// Registry/artifact name (`"gcn"` / `"gat"`).
    pub fn name(&self) -> &'static str {
        match self {
            GnnKind::Gcn => "gcn",
            GnnKind::Gat => "gat",
        }
    }
}

/// A padded dense graph ready for the node-classification artifacts.
pub struct DenseGraph {
    /// Padded node count (artifact bucket).
    pub n: usize,
    /// Real node count.
    pub n_real: usize,
    /// Dense adjacency: normalized Â for GCN, 0/1 mask (+self loops) for GAT.
    pub a_gcn: Vec<f32>,
    /// 0/1 adjacency mask (+ self loops) for GAT attention.
    pub a_mask: Vec<f32>,
    /// Node features (n × FEAT).
    pub x: Vec<f32>,
    /// One-hot labels (n × CLASSES).
    pub y: Vec<f32>,
    /// Train/val masks.
    pub train_mask: Vec<f32>,
    /// Validation mask.
    pub val_mask: Vec<f32>,
}

/// Build a padded dense graph from an edge list + node features/labels.
/// Features wider than FEAT are truncated, narrower zero-padded. The
/// train/val split is a seeded 50/50 over real nodes.
pub fn prepare_dense(
    edges: &EdgeList,
    node_features: &[Vec<f64>],
    labels: &[u32],
    bucket: usize,
    seed: u64,
) -> Result<DenseGraph> {
    let csr = Csr::undirected(edges);
    let n_real = csr.n_nodes as usize;
    if n_real > bucket {
        return Err(Error::Config(format!(
            "graph has {n_real} nodes > bucket {bucket}"
        )));
    }
    let n = bucket;
    let mut a_mask = vec![0.0f32; n * n];
    for v in 0..n_real {
        a_mask[v * n + v] = 1.0; // self loops
        for &w in csr.neighbors(v as u64) {
            a_mask[v * n + w as usize] = 1.0;
            a_mask[w as usize * n + v] = 1.0;
        }
    }
    // symmetric normalization D^-1/2 (A+I) D^-1/2
    let mut deg = vec![0.0f32; n];
    for v in 0..n {
        let mut d = 0.0;
        for w in 0..n {
            d += a_mask[v * n + w];
        }
        deg[v] = d.max(1.0);
    }
    let mut a_gcn = vec![0.0f32; n * n];
    for v in 0..n {
        for w in 0..n {
            if a_mask[v * n + w] > 0.0 {
                a_gcn[v * n + w] = 1.0 / (deg[v].sqrt() * deg[w].sqrt());
            }
        }
    }
    let mut x = vec![0.0f32; n * FEAT];
    for v in 0..n_real.min(node_features.len()) {
        for (f, &val) in node_features[v].iter().take(FEAT).enumerate() {
            x[v * FEAT + f] = val as f32;
        }
    }
    let mut y = vec![0.0f32; n * CLASSES];
    for v in 0..n_real.min(labels.len()) {
        y[v * CLASSES + (labels[v] as usize % CLASSES)] = 1.0;
    }
    let mut rng = Pcg64::new(seed);
    let mut train_mask = vec![0.0f32; n];
    let mut val_mask = vec![0.0f32; n];
    for v in 0..n_real {
        if rng.bool(0.5) {
            train_mask[v] = 1.0;
        } else {
            val_mask[v] = 1.0;
        }
    }
    Ok(DenseGraph { n, n_real, a_gcn, a_mask, x, y, train_mask, val_mask })
}

/// Result of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    /// Final training loss.
    pub loss: f32,
    /// Final train accuracy.
    pub train_acc: f32,
    /// Final validation accuracy.
    pub val_acc: f32,
    /// Seconds per epoch (mean over epochs) — the Table 4 measurement.
    pub secs_per_epoch: f64,
    /// Epochs actually executed.
    pub epochs_run: usize,
}

/// Full-batch node-classification trainer.
pub struct NodeClfRunner {
    rt: Rc<Runtime>,
    kind: GnnKind,
    bucket: usize,
    manifest: Vec<ParamSpec>,
    params: Vec<Vec<f32>>,
}

impl NodeClfRunner {
    /// Create; loads the artifact for the given padding bucket.
    pub fn new(rt: Rc<Runtime>, kind: GnnKind, bucket: usize) -> Result<Self> {
        let name = format!("{}_full_n{bucket}", kind.name());
        let manifest = rt.manifest(&name)?;
        let params = rt.init_params(&name, &manifest)?;
        Ok(NodeClfRunner { rt, kind, bucket, manifest, params })
    }

    /// Reset parameters to the artifact's initialization.
    pub fn reset(&mut self) -> Result<()> {
        let name = format!("{}_full_n{}", self.kind.name(), self.bucket);
        self.params = self.rt.init_params(&name, &self.manifest)?;
        Ok(())
    }

    /// Train `epochs` full-batch steps (paper: Adam, lr 0.01, early stop
    /// after `patience` epochs without val improvement; patience=0
    /// disables).
    pub fn train(
        &mut self,
        g: &DenseGraph,
        epochs: usize,
        lr: f32,
        patience: usize,
    ) -> Result<TrainResult> {
        let name = format!("{}_full_n{}", self.kind.name(), self.bucket);
        let exe = self.rt.executable(&name)?;
        let k = self.manifest.len();
        let mut m: Vec<Vec<f32>> = self.manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let mut v: Vec<Vec<f32>> = self.manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let adj = match self.kind {
            GnnKind::Gcn => &g.a_gcn,
            GnnKind::Gat => &g.a_mask,
        };
        let n = g.n;
        let mut best_val = 0.0f32;
        let mut since_best = 0usize;
        let mut result = TrainResult::default();
        let t0 = std::time::Instant::now();
        let mut epochs_run = 0usize;
        for t in 0..epochs {
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * k + 7);
            for (spec, p) in self.manifest.iter().zip(&self.params) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in self.manifest.iter().zip(&m) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in self.manifest.iter().zip(&v) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            inputs.push(f32_scalar(t as f32));
            inputs.push(f32_tensor(adj, &[n, n])?);
            inputs.push(f32_tensor(&g.x, &[n, FEAT])?);
            inputs.push(f32_tensor(&g.y, &[n, CLASSES])?);
            inputs.push(f32_tensor(&g.train_mask, &[n])?);
            inputs.push(f32_tensor(&g.val_mask, &[n])?);
            inputs.push(f32_scalar(lr));
            let out = self.rt.run(&exe, &inputs)?;
            for i in 0..k {
                self.params[i] = to_f32_vec(&out[i])?;
                m[i] = to_f32_vec(&out[k + i])?;
                v[i] = to_f32_vec(&out[2 * k + i])?;
            }
            result.loss = to_f32_scalar(&out[3 * k])?;
            result.train_acc = to_f32_scalar(&out[3 * k + 1])?;
            result.val_acc = to_f32_scalar(&out[3 * k + 2])?;
            epochs_run += 1;
            if patience > 0 {
                if result.val_acc > best_val {
                    best_val = result.val_acc;
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= patience {
                        break;
                    }
                }
            }
        }
        result.val_acc = result.val_acc.max(best_val);
        result.epochs_run = epochs_run;
        result.secs_per_epoch = t0.elapsed().as_secs_f64() / epochs_run.max(1) as f64;
        Ok(result)
    }
}

/// Edge-classification trainer (fixed bucket from artifacts.json).
pub struct EdgeClfRunner {
    rt: Rc<Runtime>,
    name: String,
    n: usize,
    e: usize,
    manifest: Vec<ParamSpec>,
    params: Vec<Vec<f32>>,
}

/// Inputs for the edge classifier, padded to (n, e).
pub struct EdgeTask {
    /// Normalized dense adjacency (n x n).
    pub a_gcn: Vec<f32>,
    /// Node features (n x FEAT).
    pub x: Vec<f32>,
    /// Edge source indices (padded to e).
    pub src: Vec<i32>,
    /// Edge destination indices (padded to e).
    pub dst: Vec<i32>,
    /// Edge features (e x EDGE_FEAT).
    pub edge_feat: Vec<f32>,
    /// One-hot edge labels.
    pub y: Vec<f32>,
    /// Train mask over edges.
    pub train_mask: Vec<f32>,
    /// Validation mask over edges.
    pub val_mask: Vec<f32>,
}

impl EdgeClfRunner {
    /// Build from the runtime's edge-classifier artifacts.
    pub fn new(rt: Rc<Runtime>) -> Result<Self> {
        let consts = rt.constants()?;
        let n = consts
            .get("edge_clf")
            .and_then(|e| e.get("n"))
            .and_then(|x| x.as_f64())
            .unwrap_or(2048.0) as usize;
        let e = consts
            .get("edge_clf")
            .and_then(|c| c.get("e"))
            .and_then(|x| x.as_f64())
            .unwrap_or(32768.0) as usize;
        let name = format!("edge_clf_n{n}_e{e}");
        let manifest = rt.manifest(&name)?;
        let params = rt.init_params(&name, &manifest)?;
        Ok(EdgeClfRunner { rt, name, n, e, manifest, params })
    }

    /// (node, edge) padding buckets of the compiled artifacts.
    pub fn buckets(&self) -> (usize, usize) {
        (self.n, self.e)
    }

    /// Build the padded edge task from a dataset. Node features are
    /// degree-based (the IEEE graph carries edge features only).
    pub fn prepare(
        &self,
        edges: &EdgeList,
        edge_features: &crate::featgen::FeatureTable,
        edge_labels: &[u32],
        seed: u64,
    ) -> Result<EdgeTask> {
        let csr = Csr::undirected(edges);
        let n_real = csr.n_nodes as usize;
        if n_real > self.n {
            return Err(Error::Config(format!("{n_real} nodes > bucket {}", self.n)));
        }
        // dense normalized adjacency (same recipe as prepare_dense)
        let mut a = vec![0.0f32; self.n * self.n];
        for v in 0..n_real {
            a[v * self.n + v] = 1.0;
            for &w in csr.neighbors(v as u64) {
                a[v * self.n + w as usize] = 1.0;
                a[w as usize * self.n + v] = 1.0;
            }
        }
        let mut deg = vec![0.0f32; self.n];
        for v in 0..self.n {
            deg[v] = (0..self.n).map(|w| a[v * self.n + w]).sum::<f32>().max(1.0);
        }
        for v in 0..self.n {
            for w in 0..self.n {
                if a[v * self.n + w] > 0.0 {
                    a[v * self.n + w] = 1.0 / (deg[v].sqrt() * deg[w].sqrt());
                }
            }
        }
        // degree-profile node features
        let mut x = vec![0.0f32; self.n * FEAT];
        for v in 0..n_real {
            let d = csr.degree(v as u64) as f32;
            x[v * FEAT] = (d + 1.0).ln();
            x[v * FEAT + 1] = d;
            x[v * FEAT + 2] = if (v as u64) < edges.spec.n_src { 1.0 } else { 0.0 };
        }
        let e_real = edges.len().min(self.e);
        let mut src = vec![0i32; self.e];
        let mut dst = vec![0i32; self.e];
        let mut ef = vec![0.0f32; self.e * EDGE_FEAT];
        let mut y = vec![0.0f32; self.e * 2];
        let mut train_mask = vec![0.0f32; self.e];
        let mut val_mask = vec![0.0f32; self.e];
        let mut rng = Pcg64::new(seed);
        // continuous columns standardized into the first EDGE_FEAT slots
        let (cont_idx, _) = edge_features.split_indices();
        let cols: Vec<(&[f64], f64, f64)> = cont_idx
            .iter()
            .take(EDGE_FEAT)
            .map(|&ci| {
                let v = edge_features.columns[ci].as_continuous();
                let m = crate::util::stats::mean(v);
                let s = crate::util::stats::std_dev(v).max(1e-9);
                (v, m, s)
            })
            .collect();
        for (i, (s, d)) in edges.iter().take(e_real).enumerate() {
            src[i] = edges.spec.src_global(s) as i32;
            dst[i] = edges.spec.dst_global(d) as i32;
            for (f, (col, m, sd)) in cols.iter().enumerate() {
                ef[i * EDGE_FEAT + f] = ((col[i] - m) / sd) as f32;
            }
            y[i * 2 + (edge_labels[i] as usize % 2)] = 1.0;
            if rng.bool(0.5) {
                train_mask[i] = 1.0;
            } else {
                val_mask[i] = 1.0;
            }
        }
        Ok(EdgeTask { a_gcn: a, x, src, dst, edge_feat: ef, y, train_mask, val_mask })
    }

    /// Re-initialize parameters for a fresh training run.
    pub fn reset(&mut self) -> Result<()> {
        self.params = self.rt.init_params(&self.name, &self.manifest)?;
        Ok(())
    }

    /// Train `epochs` steps; returns final metrics + timing.
    pub fn train(&mut self, task: &EdgeTask, epochs: usize, lr: f32) -> Result<TrainResult> {
        let exe = self.rt.executable(&self.name)?;
        let k = self.manifest.len();
        let mut m: Vec<Vec<f32>> = self.manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let mut v: Vec<Vec<f32>> = self.manifest.iter().map(|p| vec![0.0; p.numel()]).collect();
        let mut result = TrainResult::default();
        let t0 = std::time::Instant::now();
        for t in 0..epochs {
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * k + 10);
            for (spec, p) in self.manifest.iter().zip(&self.params) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in self.manifest.iter().zip(&m) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            for (spec, p) in self.manifest.iter().zip(&v) {
                inputs.push(f32_tensor(p, &spec.shape)?);
            }
            inputs.push(f32_scalar(t as f32));
            inputs.push(f32_tensor(&task.a_gcn, &[self.n, self.n])?);
            inputs.push(f32_tensor(&task.x, &[self.n, FEAT])?);
            inputs.push(i32_vector(&task.src));
            inputs.push(i32_vector(&task.dst));
            inputs.push(f32_tensor(&task.edge_feat, &[self.e, EDGE_FEAT])?);
            inputs.push(f32_tensor(&task.y, &[self.e, 2])?);
            inputs.push(f32_tensor(&task.train_mask, &[self.e])?);
            inputs.push(f32_tensor(&task.val_mask, &[self.e])?);
            inputs.push(f32_scalar(lr));
            let out = self.rt.run(&exe, &inputs)?;
            for i in 0..k {
                self.params[i] = to_f32_vec(&out[i])?;
                m[i] = to_f32_vec(&out[k + i])?;
                v[i] = to_f32_vec(&out[2 * k + i])?;
            }
            result.loss = to_f32_scalar(&out[3 * k])?;
            result.train_acc = to_f32_scalar(&out[3 * k + 1])?;
            result.val_acc = to_f32_scalar(&out[3 * k + 2])?;
            result.epochs_run = t + 1;
        }
        result.secs_per_epoch = t0.elapsed().as_secs_f64() / result.epochs_run.max(1) as f64;
        Ok(result)
    }
}
