//! Fitting the Kronecker seed to an input graph (paper §3.2.3).
//!
//! Two estimators combine:
//!
//! 1. **Quadrant-mass MLE for the ratios a/b and a/c** — R-MAT fixes
//!    a/b = a/c = 3, which the paper found violated by real datasets.
//!    Instead we count, at every recursion level, which quadrant each
//!    observed edge's (source-bit, destination-bit) pair falls into; the
//!    MLE of θ under a multinomial likelihood is the normalized count
//!    vector, from which the ratios follow.
//! 2. **Degree-distribution objective over the marginals** (eq. 6–8) —
//!    the expected number of nodes with (in/out-)degree k under the model
//!    has the closed form of eq. 7/8; J(θ_S) is minimized over p (out) and
//!    q (in) independently by golden-section search.
//!
//! The seed is then reassembled from (p, q, a/b, a/c) via
//! [`ThetaS::from_marginals`].

use super::kronecker::KroneckerGen;
use super::theta::ThetaS;
use crate::graph::EdgeList;

/// Natural log of the gamma function (Lanczos approximation, |err|<1e-10).
pub fn ln_gamma(x: f64) -> f64 {
    // g=7, n=9 Lanczos coefficients
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = COEF[0];
    for (i, c) in COEF.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// ln C(n, k).
pub fn ln_choose(n: f64, k: f64) -> f64 {
    if k < 0.0 || k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma(n + 1.0) - ln_gamma(k + 1.0) - ln_gamma(n - k + 1.0)
}

/// Expected degree histogram under the Kronecker model (paper eq. 7/8).
///
/// `bits` address bits on this side, `marg` the per-bit probability of a
/// 0-bit (p for out-degrees, q for in-degrees), `e` total edges. Returns
/// c̃_k for k in 0..=kmax: the expected number of nodes with degree k,
/// c̃_k = Σ_{i=0}^{bits} C(bits, i) · Binom(E, π_i)(k),  π_i = marg^{bits−i}(1−marg)^i.
pub fn expected_degree_hist(bits: u32, marg: f64, e: u64, kmax: usize) -> Vec<f64> {
    let marg = marg.clamp(1e-9, 1.0 - 1e-9);
    let e_f = e as f64;
    let mut hist = vec![0.0f64; kmax + 1];
    for i in 0..=bits {
        let ln_pi = (bits - i) as f64 * marg.ln() + i as f64 * (1.0 - marg).ln();
        let pi: f64 = ln_pi.exp();
        let ln_count = ln_choose(bits as f64, i as f64); // # nodes with i one-bits
        let ln_1mpi = if pi < 1e-12 { -pi } else { (1.0 - pi).ln() };
        // Binomial(E, pi) over k, in log space; skip negligible tails
        for (k, h) in hist.iter_mut().enumerate() {
            let ln_pmf =
                ln_choose(e_f, k as f64) + k as f64 * ln_pi + (e_f - k as f64) * ln_1mpi;
            let contrib = (ln_count + ln_pmf).exp();
            *h += contrib;
        }
    }
    hist
}

/// Observed degree histogram: counts[k] = #nodes with degree k (k ≤ kmax;
/// larger degrees are clamped into the last bin).
pub fn degree_histogram(degrees: &[u32], kmax: usize) -> Vec<f64> {
    let mut h = vec![0.0; kmax + 1];
    for &d in degrees {
        h[(d as usize).min(kmax)] += 1.0;
    }
    h
}

/// Squared-error degree-distribution objective (one side of eq. 6).
fn objective(observed: &[f64], expected: &[f64]) -> f64 {
    observed
        .iter()
        .zip(expected)
        .map(|(o, e)| (o - e) * (o - e))
        .sum()
}

/// Golden-section minimization of a unimodal 1-D function on [lo, hi].
pub fn golden_section<F: Fn(f64) -> f64>(f: F, lo: f64, hi: f64, iters: usize) -> f64 {
    let phi = (5.0f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (lo, hi);
    let mut c = b - phi * (b - a);
    let mut d = a + phi * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    for _ in 0..iters {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = f(d);
        }
    }
    0.5 * (a + b)
}

/// Per-level quadrant counts of the observed edges: for each square level
/// the (src-bit, dst-bit) pair selects one of the 4 quadrants.
pub fn quadrant_counts(edges: &EdgeList) -> [f64; 4] {
    let (rb, db) = KroneckerGen::bits(edges.spec.n_src, edges.spec.n_dst);
    let shared = rb.min(db);
    let mut counts = [0.0f64; 4];
    if shared == 0 {
        return [1.0, 1.0, 1.0, 1.0];
    }
    for (s, d) in edges.iter() {
        for l in 0..shared {
            // most-significant shared bit first, matching the sampler
            let sb = (s >> (rb - 1 - l)) & 1;
            let db_ = (d >> (db - 1 - l)) & 1;
            counts[(sb * 2 + db_) as usize] += 1.0;
        }
    }
    counts
}

/// Cap on the degree histogram length used in the objective.
const KMAX_CAP: usize = 512;

/// Fit a [`KroneckerGen`] to an input graph (paper §3.2.3).
pub fn fit_kronecker(edges: &EdgeList) -> KroneckerGen {
    let (rb, db) = KroneckerGen::bits(edges.spec.n_src, edges.spec.n_dst);
    let e = edges.len() as u64;

    // 1. ratio MLE from quadrant masses
    let counts = quadrant_counts(edges);
    let eps = 1.0;
    let (ca, cb, cc, _cd) = (counts[0] + eps, counts[1] + eps, counts[2] + eps, counts[3] + eps);
    let r_b = ca / cb;
    let r_c = ca / cc;

    // 2. marginal fit against observed degree histograms (eq. 6-8)
    let out_deg = edges.out_degrees();
    let in_deg = edges.in_degrees();
    let kmax_out = (out_deg.iter().copied().max().unwrap_or(1) as usize).clamp(4, KMAX_CAP);
    let kmax_in = (in_deg.iter().copied().max().unwrap_or(1) as usize).clamp(4, KMAX_CAP);
    let obs_out = degree_histogram(&out_deg, kmax_out);
    let obs_in = degree_histogram(&in_deg, kmax_in);

    let p = if rb == 0 {
        0.5
    } else {
        golden_section(
            |p| objective(&obs_out, &expected_degree_hist(rb, p, e, kmax_out)),
            0.5,
            0.999,
            40,
        )
    };
    let q = if db == 0 {
        0.5
    } else {
        golden_section(
            |q| objective(&obs_in, &expected_degree_hist(db, q, e, kmax_in)),
            0.5,
            0.999,
            40,
        )
    };

    let theta = ThetaS::from_marginals(p, q, r_b, r_c);
    KroneckerGen::new(theta, edges.spec, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::StructureGenerator;
    use crate::util::rng::Pcg64;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24
        assert!(ln_gamma(1.0).abs() < 1e-9);
        assert!(ln_gamma(2.0).abs() < 1e-9);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
    }

    #[test]
    fn ln_choose_known() {
        assert!((ln_choose(5.0, 2.0) - 10.0f64.ln()).abs() < 1e-9);
        assert!((ln_choose(10.0, 0.0)).abs() < 1e-9);
        assert_eq!(ln_choose(3.0, 4.0), f64::NEG_INFINITY);
    }

    #[test]
    fn expected_hist_mass_sums_to_nodes() {
        // Σ_k c̃_k should equal the number of padded nodes 2^bits
        let bits = 6;
        let e = 500u64;
        let h = expected_degree_hist(bits, 0.7, e, e as usize);
        let total: f64 = h.iter().sum();
        assert!((total - 64.0).abs() < 0.5, "total={total}");
    }

    #[test]
    fn golden_section_finds_minimum() {
        let x = golden_section(|x| (x - 0.3) * (x - 0.3), 0.0, 1.0, 60);
        assert!((x - 0.3).abs() < 1e-6);
    }

    #[test]
    fn quadrant_counts_skew() {
        // all edges at (0,0) -> all mass in quadrant a
        let e = EdgeList::from_pairs(PartiteSpec::square(8), &[(0, 0), (0, 0), (1, 1)]);
        let c = quadrant_counts(&e);
        assert!(c[0] > c[3]);
        assert_eq!(c[1], 0.0);
    }

    #[test]
    fn fit_recovers_skewed_theta() {
        // generate from a known theta, fit, check recovered parameters
        let truth = ThetaS::new(0.6, 0.18, 0.15, 0.07);
        let gen = KroneckerGen::new(truth, PartiteSpec::square(1 << 12), 60_000);
        let g = gen.generate(1, 123).unwrap();
        let fitted = fit_kronecker(&g);
        let t = fitted.theta;
        assert!((t.p() - truth.p()).abs() < 0.05, "p {} vs {}", t.p(), truth.p());
        assert!((t.q() - truth.q()).abs() < 0.05, "q {} vs {}", t.q(), truth.q());
        assert!((t.a - truth.a).abs() < 0.08, "a {} vs {}", t.a, truth.a);
    }

    #[test]
    fn fit_then_generate_matches_degree_shape() {
        let truth = ThetaS::new(0.55, 0.2, 0.18, 0.07);
        let gen = KroneckerGen::new(truth, PartiteSpec::square(1 << 10), 20_000);
        let original = gen.generate(1, 9).unwrap();
        let fitted = fit_kronecker(&original);
        let synth = fitted.generate(1, 77).unwrap();
        // heavy-head comparison: max degree within 2x
        let mo = *original.out_degrees().iter().max().unwrap() as f64;
        let ms = *synth.out_degrees().iter().max().unwrap() as f64;
        assert!(ms / mo < 2.0 && mo / ms < 2.0, "mo={mo} ms={ms}");
    }

    #[test]
    fn fit_uniform_graph_near_uniform_theta() {
        let mut rng = Pcg64::new(5);
        let spec = PartiteSpec::square(1 << 10);
        let mut e = EdgeList::new(spec);
        for _ in 0..20_000 {
            e.push(rng.below(1 << 10), rng.below(1 << 10));
        }
        let fitted = fit_kronecker(&e);
        let t = fitted.theta;
        assert!((t.p() - 0.5).abs() < 0.05, "p={}", t.p());
        assert!((t.q() - 0.5).abs() < 0.05, "q={}", t.q());
    }
}
