//! TrillionG-style recursive-vector generator (Park & Kim 2017, baseline
//! in paper Table 6 / Fig. 8).
//!
//! TrillionG's key departure from edge-iid R-MAT is node-centric
//! generation with a *recursive vector* model: each source node's
//! out-degree is drawn from the model's marginal, then its destinations
//! are sampled from the column distribution conditioned on the source's
//! recursion path. This keeps O(V/p + E/p) memory per worker. We implement
//! that scheme faithfully at the algorithmic level: out-degrees are
//! multinomial over the per-source probabilities implied by θ, and
//! destination descent reuses the source's quadrant path conditioning.

use super::kronecker::KroneckerGen;
use super::theta::ThetaS;
use super::StructureGenerator;
use crate::error::{Error, Result};
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::rng::Pcg64;

/// TrillionG-style generator with a fitted (or default R-MAT) seed.
#[derive(Clone, Copy, Debug)]
pub struct TrillionG {
    /// Seed matrix.
    pub theta: ThetaS,
    /// Partite sizes of the original graph.
    pub spec: PartiteSpec,
    /// Edge count of the original graph.
    pub edges: u64,
}

impl TrillionG {
    /// Fit: reuse the Kronecker ratio/marginal fit for the seed.
    pub fn fit(edges: &EdgeList) -> Self {
        let k = super::fit::fit_kronecker(edges);
        TrillionG { theta: k.theta, spec: edges.spec, edges: edges.len() as u64 }
    }

    /// Default seed (original TrillionG evaluation uses R-MAT parameters).
    pub fn with_default_seed(spec: PartiteSpec, edges: u64) -> Self {
        TrillionG { theta: ThetaS::rmat_default(), spec, edges }
    }
}

impl StructureGenerator for TrillionG {
    fn name(&self) -> &'static str {
        "trilliong"
    }

    fn base(&self) -> (PartiteSpec, u64) {
        (self.spec, self.edges)
    }

    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList> {
        if n_src == 0 || n_dst == 0 {
            return Err(Error::Config("empty partite".into()));
        }
        let (rb, db) = KroneckerGen::bits(n_src, n_dst);
        let p = self.theta.p(); // P(source bit = 0)
        let q = self.theta.q();
        let mut rng = Pcg64::new(seed);
        let spec = if self.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        };
        let mut out = EdgeList::with_capacity(spec, edges as usize);

        // Node-centric pass: walk source nodes; expected out-degree of u is
        // E * pi_u with pi_u = prod over bits. Draw Binomial via Poisson
        // approximation (exact for the sparse regime TrillionG targets),
        // then sample destinations conditioned on u's path: per square
        // level, P(dst bit = 0 | src bit) = a/(a+b) or c/(c+d).
        let t = self.theta;
        let cond0 = t.a / (t.a + t.b); // src bit 0
        let cond1 = t.c / (t.c + t.d); // src bit 1
        let mut remaining = edges;
        for u in 0..n_src {
            if remaining == 0 {
                break;
            }
            // pi_u from the bits of u
            let ones = (u & ((1u64 << rb) - 1)).count_ones() as f64;
            let zeros = rb as f64 - ones;
            let ln_pi = zeros * p.ln() + ones * (1.0 - p).ln();
            let lambda = edges as f64 * ln_pi.exp();
            let mut d_u = rng.poisson(lambda).min(remaining);
            if u == n_src - 1 {
                d_u = remaining; // exact total edge count
            }
            for _ in 0..d_u {
                // destination descent conditioned on u's source bits
                let mut v = 0u64;
                let shared = rb.min(db);
                for l in 0..shared {
                    let sb = (u >> (rb - 1 - l)) & 1;
                    let c = if sb == 0 { cond0 } else { cond1 };
                    let bit = (rng.f64() >= c) as u64;
                    v = (v << 1) | bit;
                }
                for _ in rb..db {
                    let bit = (rng.f64() >= q) as u64;
                    v = (v << 1) | bit;
                }
                if v >= n_dst {
                    v = rng.below(n_dst);
                }
                out.push(u, v);
            }
            remaining -= d_u;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_edge_count() {
        let g = TrillionG::with_default_seed(PartiteSpec::square(1 << 10), 20_000);
        let e = g.generate(1, 3).unwrap();
        assert_eq!(e.len(), 20_000);
        assert!(e.validate().is_ok());
    }

    #[test]
    fn degree_skew_present() {
        let g = TrillionG::with_default_seed(PartiteSpec::square(1 << 10), 20_000);
        let e = g.generate(1, 7).unwrap();
        let deg = e.out_degrees();
        let mean = 20_000.0 / 1024.0;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max > 5.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn node_centric_sources_sorted() {
        // node-centric generation emits edges grouped by source
        let g = TrillionG::with_default_seed(PartiteSpec::square(256), 2_000);
        let e = g.generate(1, 1).unwrap();
        let mut sorted = e.src.clone();
        sorted.sort_unstable();
        assert_eq!(e.src, sorted);
    }

    #[test]
    fn fit_runs_on_generated_graph() {
        let base = TrillionG::with_default_seed(PartiteSpec::square(512), 8_000);
        let e = base.generate(1, 2).unwrap();
        let fitted = TrillionG::fit(&e);
        assert!(fitted.theta.p() > 0.5);
        let g2 = fitted.generate(1, 4).unwrap();
        assert_eq!(g2.len(), 8_000);
    }
}
