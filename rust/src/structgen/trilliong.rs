//! TrillionG-style recursive-vector generator (Park & Kim 2017, baseline
//! in paper Table 6 / Fig. 8).
//!
//! TrillionG's key departure from edge-iid R-MAT is node-centric
//! generation with a *recursive vector* model: each source node's
//! out-degree is drawn from the model's marginal, then its destinations
//! are sampled from the column distribution conditioned on the source's
//! recursion path. This keeps O(V/p + E/p) memory per worker. We implement
//! that scheme faithfully at the algorithmic level: out-degrees are
//! multinomial over the per-source probabilities implied by θ, and
//! destination descent reuses the source's quadrant path conditioning.

use super::kronecker::KroneckerGen;
use super::theta::ThetaS;
use super::StructureGenerator;
use crate::error::{Error, Result};
use crate::graph::{EdgeList, PartiteSpec};
use crate::pipeline::parallel::{apportion, ChunkPlan};
use crate::util::json::Json;
use crate::util::rng::{BlockRng, Pcg64, RandomSource};

/// TrillionG-style generator with a fitted (or default R-MAT) seed.
#[derive(Clone, Copy, Debug)]
pub struct TrillionG {
    /// Seed matrix.
    pub theta: ThetaS,
    /// Partite sizes of the original graph.
    pub spec: PartiteSpec,
    /// Edge count of the original graph.
    pub edges: u64,
}

impl TrillionG {
    /// Fit: reuse the Kronecker ratio/marginal fit for the seed.
    pub fn fit(edges: &EdgeList) -> Self {
        let k = super::fit::fit_kronecker(edges);
        TrillionG { theta: k.theta, spec: edges.spec, edges: edges.len() as u64 }
    }

    /// Default seed (original TrillionG evaluation uses R-MAT parameters).
    pub fn with_default_seed(spec: PartiteSpec, edges: u64) -> Self {
        TrillionG { theta: ThetaS::rmat_default(), spec, edges }
    }

    /// Reconstruct from a `.sggm` artifact state (θ restored verbatim).
    pub fn from_state(state: &Json) -> Result<TrillionG> {
        let t = state.req("theta")?;
        Ok(TrillionG {
            theta: ThetaS {
                a: t.req_f64("a")?,
                b: t.req_f64("b")?,
                c: t.req_f64("c")?,
                d: t.req_f64("d")?,
            },
            spec: PartiteSpec::from_json(state.req("spec")?)?,
            edges: state.req_u64("edges")?,
        })
    }

    /// Output partite spec for the requested sizes.
    fn out_spec(&self, n_src: u64, n_dst: u64) -> PartiteSpec {
        if self.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        }
    }

    /// Node-centric sampling over the source range `[lo, hi)` with an
    /// exact `budget` edge count: expected out-degree of u is
    /// `total_edges · π_u` with `π_u` a product over u's address bits;
    /// out-degrees are Poisson draws clamped to the range budget, the
    /// range's last node absorbs the remainder, and destinations descend
    /// the column distribution conditioned on u's bits. Both the one-shot
    /// path (`lo = 0`, `hi = n_src`) and the chunked plan share this loop,
    /// so chunked output at one chunk equals the sequential output.
    ///
    /// Generic over [`RandomSource`]: the hot paths run it on a
    /// block-buffered [`BlockRng`] (the per-node draw count is
    /// data-dependent — Poisson degrees, bounded-rejection fallbacks —
    /// so a fixed-stride draw buffer can't be sized up front), and a
    /// bare [`Pcg64`] produces the identical edge stream for tests.
    #[allow(clippy::too_many_arguments)]
    fn sample_range<R: RandomSource>(
        &self,
        rb: u32,
        db: u32,
        n_dst: u64,
        lo: u64,
        hi: u64,
        budget: u64,
        total_edges: u64,
        rng: &mut R,
        out: &mut EdgeList,
    ) {
        let p = self.theta.p(); // P(source bit = 0)
        let q = self.theta.q();
        let t = self.theta;
        let cond0 = t.a / (t.a + t.b); // src bit 0
        let cond1 = t.c / (t.c + t.d); // src bit 1
        let mut remaining = budget;
        for u in lo..hi {
            if remaining == 0 {
                break;
            }
            // pi_u from the bits of u
            let ones = (u & ((1u64 << rb) - 1)).count_ones() as f64;
            let zeros = rb as f64 - ones;
            let ln_pi = zeros * p.ln() + ones * (1.0 - p).ln();
            let lambda = total_edges as f64 * ln_pi.exp();
            let mut d_u = rng.poisson(lambda).min(remaining);
            if u == hi - 1 {
                d_u = remaining; // exact edge count for this range
            }
            for _ in 0..d_u {
                // destination descent conditioned on u's source bits
                let mut v = 0u64;
                let shared = rb.min(db);
                for l in 0..shared {
                    let sb = (u >> (rb - 1 - l)) & 1;
                    let c = if sb == 0 { cond0 } else { cond1 };
                    let bit = (rng.f64() >= c) as u64;
                    v = (v << 1) | bit;
                }
                for _ in rb..db {
                    let bit = (rng.f64() >= q) as u64;
                    v = (v << 1) | bit;
                }
                if v >= n_dst {
                    v = rng.below(n_dst);
                }
                out.push(u, v);
            }
            remaining -= d_u;
        }
    }
}

/// TrillionG's chunk decomposition: the source-id space is partitioned by
/// its top `pb` address bits into `2^pb` contiguous ranges (so chunk
/// concatenation stays source-sorted, like the sequential node walk), and
/// the edge budget is apportioned by each range's closed-form expected
/// mass `p^zeros(c) · (1-p)^ones(c)`. Each chunk samples its range on its
/// own PRNG stream.
struct TrillionGChunkPlan {
    gen: TrillionG,
    spec: PartiteSpec,
    budgets: Vec<u64>,
    rb: u32,
    db: u32,
    /// Source address bits left to the suffix (range width = 2^suf_bits).
    suf_bits: u32,
    n_src: u64,
    n_dst: u64,
    total_edges: u64,
    seed: u64,
}

impl ChunkPlan for TrillionGChunkPlan {
    fn n_chunks(&self) -> usize {
        self.budgets.len()
    }

    fn sample(&self, ci: usize) -> Result<EdgeList> {
        let budget = self.budgets[ci];
        let lo = (ci as u64) << self.suf_bits;
        let hi = ((ci as u64 + 1) << self.suf_bits).min(self.n_src);
        let mut out = EdgeList::with_capacity(self.spec, budget as usize);
        if budget == 0 || lo >= self.n_src {
            return Ok(out);
        }
        // a single-chunk plan degenerates to the raw job seed so that
        // `generate_into` at `prefix_levels = 0` reproduces
        // `generate_sized` exactly (same contract as `SplitPlan::even`)
        let mut rng = BlockRng::new(if self.budgets.len() == 1 {
            Pcg64::new(self.seed)
        } else {
            Pcg64::with_stream(self.seed, ci as u64 + 1)
        });
        self.gen.sample_range(
            self.rb,
            self.db,
            self.n_dst,
            lo,
            hi,
            budget,
            self.total_edges,
            &mut rng,
            &mut out,
        );
        Ok(out)
    }
}

impl StructureGenerator for TrillionG {
    fn name(&self) -> &'static str {
        "trilliong"
    }

    fn base(&self) -> (PartiteSpec, u64) {
        (self.spec, self.edges)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            (
                "theta",
                Json::obj(vec![
                    ("a", Json::from(self.theta.a)),
                    ("b", Json::from(self.theta.b)),
                    ("c", Json::from(self.theta.c)),
                    ("d", Json::from(self.theta.d)),
                ]),
            ),
            ("spec", self.spec.to_json()),
            ("edges", Json::u64_exact(self.edges)),
        ]))
    }

    /// Node-centric pass over all source nodes (see
    /// `TrillionG::sample_range` for the per-node Poisson out-degree +
    /// conditioned destination descent).
    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList> {
        if n_src == 0 || n_dst == 0 {
            return Err(Error::Config("empty partite".into()));
        }
        let (rb, db) = KroneckerGen::bits(n_src, n_dst);
        let mut rng = BlockRng::new(Pcg64::new(seed));
        let mut out = EdgeList::with_capacity(self.out_spec(n_src, n_dst), edges as usize);
        self.sample_range(rb, db, n_dst, 0, n_src, edges, edges, &mut rng, &mut out);
        Ok(out)
    }

    /// Out-of-core override: node-centric chunking. The source space is
    /// partitioned into contiguous bit-prefix ranges (TrillionG's
    /// "recursive vector" workers own disjoint node ranges), each sampled
    /// independently on its own PRNG stream. Chunk concatenation stays
    /// source-sorted and the output is bit-identical for any worker count.
    fn chunk_plan<'a>(
        &'a self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
        prefix_levels: u32,
    ) -> Result<Box<dyn ChunkPlan + 'a>> {
        if n_src == 0 || n_dst == 0 {
            return Err(Error::Config("empty partite".into()));
        }
        let (rb, db) = KroneckerGen::bits(n_src, n_dst);
        // two source bits per prefix level matches the 4^levels chunk
        // count of the Kronecker prefix scheme
        let pb = (2 * prefix_levels).min(rb);
        let n_chunks = 1usize << pb;
        let suf_bits = rb - pb;
        let p = self.theta.p();
        let weights: Vec<f64> = (0..n_chunks)
            .map(|c| {
                if (c as u64) << suf_bits >= n_src {
                    return 0.0; // range entirely above the id space
                }
                let ones = (c as u64).count_ones();
                p.powi((pb - ones) as i32) * (1.0 - p).powi(ones as i32)
            })
            .collect();
        Ok(Box::new(TrillionGChunkPlan {
            gen: *self,
            spec: self.out_spec(n_src, n_dst),
            budgets: apportion(&weights, edges),
            rb,
            db,
            suf_bits,
            n_src,
            n_dst,
            total_edges: edges,
            seed,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structgen::chunked::ChunkConfig;

    #[test]
    fn exact_edge_count() {
        let g = TrillionG::with_default_seed(PartiteSpec::square(1 << 10), 20_000);
        let e = g.generate(1, 3).unwrap();
        assert_eq!(e.len(), 20_000);
        assert!(e.validate().is_ok());
    }

    #[test]
    fn degree_skew_present() {
        let g = TrillionG::with_default_seed(PartiteSpec::square(1 << 10), 20_000);
        let e = g.generate(1, 7).unwrap();
        let deg = e.out_degrees();
        let mean = 20_000.0 / 1024.0;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max > 5.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn node_centric_sources_sorted() {
        // node-centric generation emits edges grouped by source
        let g = TrillionG::with_default_seed(PartiteSpec::square(256), 2_000);
        let e = g.generate(1, 1).unwrap();
        let mut sorted = e.src.clone();
        sorted.sort_unstable();
        assert_eq!(e.src, sorted);
    }

    #[test]
    fn block_buffered_sampling_matches_bare_pcg() {
        // sample_range over BlockRng (the production path) must emit the
        // identical edge stream as a bare Pcg64 on the same seed — the
        // batched-equals-scalar contract for the variable-draw sampler.
        let g = TrillionG::with_default_seed(PartiteSpec::bipartite(1 << 9, 1 << 7), 10_000);
        let (rb, db) = KroneckerGen::bits(1 << 9, 1 << 7);
        let spec = PartiteSpec::bipartite(1 << 9, 1 << 7);
        let mut scalar = EdgeList::new(spec);
        let mut srng = Pcg64::new(21);
        g.sample_range(rb, db, 1 << 7, 0, 1 << 9, 10_000, 10_000, &mut srng, &mut scalar);
        let mut batched = EdgeList::new(spec);
        let mut brng = BlockRng::new(Pcg64::new(21));
        g.sample_range(rb, db, 1 << 7, 0, 1 << 9, 10_000, 10_000, &mut brng, &mut batched);
        assert_eq!(scalar.src, batched.src);
        assert_eq!(scalar.dst, batched.dst);
        assert_eq!(batched.len(), 10_000);
    }

    #[test]
    fn fit_runs_on_generated_graph() {
        let base = TrillionG::with_default_seed(PartiteSpec::square(512), 8_000);
        let e = base.generate(1, 2).unwrap();
        let fitted = TrillionG::fit(&e);
        assert!(fitted.theta.p() > 0.5);
        let g2 = fitted.generate(1, 4).unwrap();
        assert_eq!(g2.len(), 8_000);
    }

    #[test]
    fn generate_into_is_worker_count_invariant() {
        let g = TrillionG::with_default_seed(PartiteSpec::square(1 << 10), 20_000);
        let collect = |workers: usize| {
            let cfg = ChunkConfig { prefix_levels: 2, workers, queue_capacity: 2, ..ChunkConfig::default() };
            let mut out = EdgeList::new(PartiteSpec::square(1 << 10));
            let total = g
                .generate_into(1 << 10, 1 << 10, 20_000, 11, cfg, &mut |c| {
                    out.extend_from(&c.edges);
                    Ok(())
                })
                .unwrap();
            assert_eq!(total, 20_000);
            out
        };
        let seq = collect(1);
        assert_eq!(seq.len(), 20_000);
        // a single-chunk plan (prefix_levels = 0) reproduces the
        // one-shot sequential path exactly
        let one_chunk_cfg = ChunkConfig { prefix_levels: 0, workers: 1, queue_capacity: 2, ..ChunkConfig::default() };
        let mut one = EdgeList::new(PartiteSpec::square(1 << 10));
        g.generate_into(1 << 10, 1 << 10, 20_000, 11, one_chunk_cfg, &mut |c| {
            one.extend_from(&c.edges);
            Ok(())
        })
        .unwrap();
        let direct = g.generate_sized(1 << 10, 1 << 10, 20_000, 11).unwrap();
        assert_eq!(one.src, direct.src);
        assert_eq!(one.dst, direct.dst);
        // node-range chunking keeps the concatenation source-sorted,
        // like the sequential node walk
        let mut sorted = seq.src.clone();
        sorted.sort_unstable();
        assert_eq!(seq.src, sorted);
        for workers in [2, 4] {
            let par = collect(workers);
            assert_eq!(seq.src, par.src, "workers={workers}");
            assert_eq!(seq.dst, par.dst, "workers={workers}");
        }
    }
}
