//! Per-level noise on the Kronecker cascade (paper §9, eq. 23–25).
//!
//! A pure Kronecker power produces oscillations in the degree distribution
//! (Seshadhri et al. [37]). The paper's fix: at each recursion level i use
//! a perturbed seed θ_{S,i} = θ_S + N_i where N_i has zero element-sum and
//! preserves row/column structure. The exemplary form of eq. 25 moves mass
//! `n_f` between the off-diagonal entries and compensates on the diagonal
//! so that all marginals stay valid; `n_f ~ U[0, min((a+d)/2, b, c))`
//! scaled by a user amplitude.

use super::theta::ThetaS;
use crate::util::rng::Pcg64;

/// Noise configuration: `amplitude` ∈ [0,1] scales the maximal admissible
/// `n_f` of eq. 25 (0 = no noise, 1 = full range).
#[derive(Clone, Copy, Debug)]
pub struct NoiseConfig {
    /// Fraction of the maximal admissible noise range to use.
    pub amplitude: f64,
}

impl NoiseConfig {
    /// Draw a noisy seed θ_{S,i} for one level (eq. 24–25).
    pub fn perturb(&self, t: ThetaS, rng: &mut Pcg64) -> ThetaS {
        let bound = ((t.a + t.d) / 2.0).min(t.b).min(t.c) * self.amplitude.clamp(0.0, 1.0);
        if bound <= 0.0 {
            return t;
        }
        // symmetric U[-bound, bound): zero mean across levels
        let nf = rng.range(-bound, bound);
        // eq. 25: diagonal compensation keeps the element sum at zero
        let ad = t.a + t.d;
        let da = if ad > 0.0 { -2.0 * nf * t.a / ad } else { 0.0 };
        let dd = if ad > 0.0 { 2.0 * nf * t.a / ad } else { 0.0 };
        ThetaS::new(t.a + da, t.b + nf, t.c + nf, t.d + dd - 2.0 * nf)
    }

    /// Perturb a scalar marginal used on Row/Col levels.
    pub fn perturb_marginal(&self, p: f64, rng: &mut Pcg64) -> f64 {
        let bound = p.min(1.0 - p) * 0.5 * self.amplitude.clamp(0.0, 1.0);
        (p + rng.range(-bound, bound)).clamp(1e-6, 1.0 - 1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perturbed_seed_is_valid_distribution() {
        let cfg = NoiseConfig { amplitude: 1.0 };
        let t = ThetaS::rmat_default();
        let mut rng = Pcg64::new(1);
        for _ in 0..1000 {
            let n = cfg.perturb(t, &mut rng);
            let sum = n.a + n.b + n.c + n.d;
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(n.a > 0.0 && n.b > 0.0 && n.c > 0.0 && n.d > 0.0);
        }
    }

    #[test]
    fn zero_amplitude_is_identity() {
        let cfg = NoiseConfig { amplitude: 0.0 };
        let t = ThetaS::rmat_default();
        let mut rng = Pcg64::new(2);
        let n = cfg.perturb(t, &mut rng);
        assert_eq!(n, t);
    }

    #[test]
    fn noise_mean_is_small() {
        let cfg = NoiseConfig { amplitude: 1.0 };
        let t = ThetaS::rmat_default();
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mut sum_b = 0.0;
        for _ in 0..n {
            sum_b += cfg.perturb(t, &mut rng).b;
        }
        let mean_b = sum_b / n as f64;
        assert!((mean_b - t.b).abs() < 0.01, "mean_b={mean_b} b={}", t.b);
    }

    #[test]
    fn marginal_stays_in_unit_interval() {
        let cfg = NoiseConfig { amplitude: 1.0 };
        let mut rng = Pcg64::new(4);
        for &p in &[0.05, 0.5, 0.95] {
            for _ in 0..1000 {
                let x = cfg.perturb_marginal(p, &mut rng);
                assert!(x > 0.0 && x < 1.0);
            }
        }
    }
}
