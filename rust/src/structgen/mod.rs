//! Structure generation (paper §3.2).
//!
//! The centerpiece is the **generalized stochastic Kronecker generator**
//! ([`kronecker`]): eq. 1 builds the edge-probability distribution
//! `θ = θ_S^⊗min(m,n) ⊗ θ_H^⊗max(0,n−m) ⊗ θ_V^⊗max(0,m−n)` over a possibly
//! non-square 2ⁿ×2ᵐ adjacency, which reduces to R-MAT when n = m (eq. 5).
//! θ is never materialized — each of the E sampled edges performs one
//! recursive bit-descent per level.
//!
//! [`fit`] recovers θ_S from an input graph: quadrant-mass MLE for the
//! a/b and a/c ratios (replacing R-MAT's fixed 3:1 assumption, §3.2.3)
//! plus a closed-form degree-distribution objective (eq. 6–8) minimized
//! over the marginals p = a+b and q = a+c.
//!
//! [`noise`] implements the per-level zero-sum noise of paper §9 that
//! smooths the oscillations a pure Kronecker power produces, and
//! [`chunked`] the §10 prefix-partitioned generation scheme that bounds
//! memory and parallelizes across shared-nothing workers.
//!
//! Baselines: [`erdos_renyi`] (the paper's "random"), [`sbm`]
//! (degree-corrected SBM standing in for GraphWorld, with the fitting step
//! the paper adds), and [`trilliong`] (recursive-vector model).

pub mod chunked;
pub mod erdos_renyi;
pub mod fit;
pub mod kronecker;
pub mod noise;
pub mod sbm;
pub mod theta;
pub mod trilliong;

use crate::graph::EdgeList;
use crate::Result;

/// A fitted structure generator that can produce a graph at any scale.
///
/// `scale` multiplies each partite's node count linearly; the edge count is
/// scaled by `scale²` to preserve density (paper eq. 22 / Table 5 note).
pub trait StructureGenerator: Send + Sync {
    /// Human-readable name used in experiment tables.
    fn name(&self) -> &'static str;

    /// Generate a graph at integer `scale` (1 = same size as the input).
    fn generate(&self, scale: u64, seed: u64) -> Result<EdgeList>;

    /// Generate with explicit node/edge targets (used by the chunked
    /// pipeline and the scaling studies with non-integer factors).
    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList>;
}

/// Which structural generator to use in a pipeline (ablation axis of
/// paper Table 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StructKind {
    /// The paper's fitted Kronecker generator ("ours").
    Kronecker,
    /// Kronecker with per-level noise ("ours with noise", Table 10).
    KroneckerNoisy,
    /// Erdős–Rényi ("random").
    Random,
    /// Degree-corrected SBM ("graphworld", with fitting).
    Sbm,
    /// TrillionG-style recursive vector model.
    TrillionG,
}

impl std::str::FromStr for StructKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "kronecker" | "ours" => Ok(StructKind::Kronecker),
            "kronecker-noisy" | "ours-noisy" => Ok(StructKind::KroneckerNoisy),
            "random" | "er" | "erdos-renyi" => Ok(StructKind::Random),
            "sbm" | "graphworld" => Ok(StructKind::Sbm),
            "trilliong" => Ok(StructKind::TrillionG),
            other => Err(format!("unknown struct generator `{other}`")),
        }
    }
}
