//! Structure generation (paper §3.2).
//!
//! The centerpiece is the **generalized stochastic Kronecker generator**
//! ([`kronecker`]): eq. 1 builds the edge-probability distribution
//! `θ = θ_S^⊗min(m,n) ⊗ θ_H^⊗max(0,n−m) ⊗ θ_V^⊗max(0,m−n)` over a possibly
//! non-square 2ⁿ×2ᵐ adjacency, which reduces to R-MAT when n = m (eq. 5).
//! θ is never materialized — each of the E sampled edges performs one
//! recursive bit-descent per level.
//!
//! [`fit`] recovers θ_S from an input graph: quadrant-mass MLE for the
//! a/b and a/c ratios (replacing R-MAT's fixed 3:1 assumption, §3.2.3)
//! plus a closed-form degree-distribution objective (eq. 6–8) minimized
//! over the marginals p = a+b and q = a+c.
//!
//! [`noise`] implements the per-level zero-sum noise of paper §9 that
//! smooths the oscillations a pure Kronecker power produces, and
//! [`chunked`] the §10 prefix-partitioned generation scheme that bounds
//! memory and parallelizes across shared-nothing workers.
//!
//! Baselines: [`erdos_renyi`] (the paper's "random"), [`sbm`]
//! (degree-corrected SBM standing in for GraphWorld, with the fitting step
//! the paper adds), and [`trilliong`] (recursive-vector model).
//!
//! Backends register in the pipeline's structure [`Registry`] via
//! [`register_builtins`]; [`StructureGeneratorFactory`] is the plug-in
//! point for new ones.

pub mod chunked;
pub mod erdos_renyi;
pub mod fit;
pub mod kronecker;
pub mod noise;
pub mod sbm;
pub mod theta;
pub mod trilliong;

use crate::graph::{EdgeList, PartiteSpec};
use crate::pipeline::parallel::{ChunkPlan, ParallelChunkRunner, SplitPlan};
use crate::pipeline::registry::Registry;
use crate::pipeline::spec::Params;
use crate::util::json::Json;
use crate::Result;
use chunked::{Chunk, ChunkConfig};

/// A fitted structure generator that can produce a graph at any scale.
///
/// `scale` multiplies each partite's node count linearly; the edge count is
/// scaled by `scale²` to preserve density (paper eq. 22 / Table 5 note).
pub trait StructureGenerator: Send + Sync {
    /// Human-readable name used in experiment tables.
    fn name(&self) -> &'static str;

    /// The fitted reference frame: scale-1 partite spec and edge count.
    /// [`Self::generate`] and the streaming planner derive every scaled
    /// size from this.
    fn base(&self) -> (PartiteSpec, u64);

    /// Generate with explicit node/edge targets (used by the chunked
    /// pipeline and the scaling studies with non-integer factors).
    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList>;

    /// Resolve integer `scale` into explicit `(n_src, n_dst, edges)`
    /// targets (nodes linear, edges quadratic, density preserved).
    fn scaled_size(&self, scale: u64) -> (u64, u64, u64) {
        let (spec, edges) = self.base();
        let scaled = spec.scaled(scale);
        (scaled.n_src, scaled.n_dst, spec.density_preserving_edges(edges, scale))
    }

    /// Generate a graph at integer `scale` (1 = same size as the input).
    fn generate(&self, scale: u64, seed: u64) -> Result<EdgeList> {
        let (n_src, n_dst, edges) = self.scaled_size(scale);
        self.generate_sized(n_src, n_dst, edges, seed)
    }

    /// The deterministic chunk decomposition this backend uses for a
    /// job of the given sizes/seed — the single source of truth for
    /// chunk counts, budgets, and per-chunk PRNG streams shared by
    /// in-process streaming ([`Self::generate_into`]) and distributed
    /// planning ([`crate::pipeline::distrib`], which must count chunks
    /// exactly as execution will).
    ///
    /// The default decomposition splits the edge budget into
    /// `4^prefix_levels` near-equal chunks, each sampled independently by
    /// [`Self::generate_sized`] on its own
    /// [`chunk_seed`](crate::pipeline::parallel::chunk_seed) stream. This
    /// even split is only distribution-faithful for edge-i.i.d. samplers;
    /// generators with sequential structure override it (Kronecker uses
    /// the §10 prefix partition, TrillionG partitions the source-node
    /// space). With `prefix_levels = 0` the plan has a single chunk on
    /// the raw seed — exactly the old one-shot `generate_sized`
    /// behaviour.
    fn chunk_plan<'a>(
        &'a self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
        prefix_levels: u32,
    ) -> Result<Box<dyn ChunkPlan + 'a>> {
        Ok(Box::new(SplitPlan::even(
            edges,
            prefix_levels,
            seed,
            move |_i, budget, seed| self.generate_sized(n_src, n_dst, budget, seed),
        )))
    }

    /// Stream generation into `sink` chunk by chunk, returning the total
    /// edge count. A sink error aborts generation and propagates.
    ///
    /// Decomposes the job with [`Self::chunk_plan`] and executes it on
    /// the shared [`ParallelChunkRunner`] — so every backend parallelizes
    /// when `chunks.workers > 1`, with output bit-identical across worker
    /// counts.
    fn generate_into(
        &self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
        chunks: ChunkConfig,
        sink: &mut dyn FnMut(&mut Chunk) -> Result<()>,
    ) -> Result<u64> {
        let plan = self.chunk_plan(n_src, n_dst, edges, seed, chunks.prefix_levels)?;
        ParallelChunkRunner::from_config(chunks).run(plan.as_ref(), sink)
    }

    /// Serialize the fitted state for a `.sggm` model artifact (the
    /// [`ModelState`](crate::pipeline::artifact) capability). The state
    /// loader registered under this generator's [`Self::name`] must
    /// reconstruct a generator whose sampling is bit-identical to this
    /// one for every seed.
    fn save_state(&self) -> Result<Json>;
}

/// Everything a structure factory sees at fit time.
pub struct StructureFitContext<'a> {
    /// The source graph to fit on.
    pub edges: &'a EdgeList,
    /// Backend parameters from the scenario spec / builder.
    pub params: &'a Params,
    /// Fitting seed.
    pub seed: u64,
}

/// Factory signature for registry-registered structure backends.
pub type StructureGeneratorFactory =
    fn(&StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>>;

fn make_kronecker(ctx: &StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>> {
    let noise = ctx.params.f64_or("noise", 0.0)?;
    let fitted = fit::fit_kronecker(ctx.edges);
    if noise > 0.0 {
        Ok(Box::new(fitted.with_noise(noise)))
    } else {
        Ok(Box::new(fitted))
    }
}

fn make_kronecker_noisy(ctx: &StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>> {
    // paper §9 default amplitude when the spec doesn't pick one
    let noise = ctx.params.f64_or("noise", 0.3)?.max(1e-6);
    Ok(Box::new(fit::fit_kronecker(ctx.edges).with_noise(noise)))
}

fn make_erdos_renyi(ctx: &StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(erdos_renyi::ErdosRenyi::fit(ctx.edges)))
}

fn make_sbm(ctx: &StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>> {
    let blocks = ctx.params.usize_or("blocks", 16)?.max(1);
    Ok(Box::new(sbm::DcSbm::fit(ctx.edges, blocks)))
}

fn make_trilliong(ctx: &StructureFitContext<'_>) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(trilliong::TrillionG::fit(ctx.edges)))
}

/// Register every built-in structure backend (plus the historical CLI
/// aliases) into `reg`.
pub fn register_builtins(reg: &mut Registry<StructureGeneratorFactory>) {
    reg.register("kronecker", make_kronecker);
    reg.register("kronecker-noisy", make_kronecker_noisy);
    reg.register("erdos-renyi", make_erdos_renyi);
    reg.register("sbm", make_sbm);
    reg.register("trilliong", make_trilliong);
    reg.alias("ours", "kronecker");
    reg.alias("rmat", "kronecker");
    reg.alias("ours-noisy", "kronecker-noisy");
    reg.alias("random", "erdos-renyi");
    reg.alias("er", "erdos-renyi");
    reg.alias("graphworld", "sbm");
}

/// Loader signature for `.sggm` artifact state: the inverse of
/// [`StructureGenerator::save_state`], keyed by backend name.
pub type StructureStateLoader = fn(&Json) -> Result<Box<dyn StructureGenerator>>;

fn load_kronecker(state: &Json) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(kronecker::KroneckerGen::from_state(state)?))
}

fn load_erdos_renyi(state: &Json) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(erdos_renyi::ErdosRenyi::from_state(state)?))
}

fn load_sbm(state: &Json) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(sbm::DcSbm::from_state(state)?))
}

fn load_trilliong(state: &Json) -> Result<Box<dyn StructureGenerator>> {
    Ok(Box::new(trilliong::TrillionG::from_state(state)?))
}

/// Register every built-in structure state loader. Keys mirror
/// [`register_builtins`] (including the aliases), so the `backend` name a
/// [`StructureGenerator::name`] writes into an artifact — `random` for
/// Erdős–Rényi, `graphworld` for the DC-SBM — resolves here too.
pub fn register_state_loaders(reg: &mut Registry<StructureStateLoader>) {
    reg.register("kronecker", load_kronecker);
    reg.register("kronecker-noisy", load_kronecker);
    reg.register("erdos-renyi", load_erdos_renyi);
    reg.register("sbm", load_sbm);
    reg.register("trilliong", load_trilliong);
    reg.alias("ours", "kronecker");
    reg.alias("rmat", "kronecker");
    reg.alias("ours-noisy", "kronecker-noisy");
    reg.alias("random", "erdos-renyi");
    reg.alias("er", "erdos-renyi");
    reg.alias("graphworld", "sbm");
}

/// Which structural generator to use in a pipeline (ablation axis of
/// paper Table 6). Legacy closed enum — new code names backends by
/// registry string instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StructKind {
    /// The paper's fitted Kronecker generator ("ours").
    Kronecker,
    /// Kronecker with per-level noise ("ours with noise", Table 10).
    KroneckerNoisy,
    /// Erdős–Rényi ("random").
    Random,
    /// Degree-corrected SBM ("graphworld", with fitting).
    Sbm,
    /// TrillionG-style recursive vector model.
    TrillionG,
}

impl StructKind {
    /// Canonical registry name of this kind.
    pub fn registry_name(&self) -> &'static str {
        match self {
            StructKind::Kronecker => "kronecker",
            StructKind::KroneckerNoisy => "kronecker-noisy",
            StructKind::Random => "erdos-renyi",
            StructKind::Sbm => "sbm",
            StructKind::TrillionG => "trilliong",
        }
    }
}

impl std::str::FromStr for StructKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "kronecker" | "ours" => Ok(StructKind::Kronecker),
            "kronecker-noisy" | "ours-noisy" => Ok(StructKind::KroneckerNoisy),
            "random" | "er" | "erdos-renyi" => Ok(StructKind::Random),
            "sbm" | "graphworld" => Ok(StructKind::Sbm),
            "trilliong" => Ok(StructKind::TrillionG),
            other => Err(format!("unknown struct generator `{other}`")),
        }
    }
}
