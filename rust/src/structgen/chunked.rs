//! Chunked, shared-nothing parallel generation (paper §10 / Appendix 10).
//!
//! For graphs that don't fit in memory, θ is factored as
//! `θ_pref ⊗ θ_gen`: the first `prefix_levels` square levels form a prefix
//! distribution over 4^prefix_levels chunks. Each chunk i receives
//! `E_i = E · E[θ_pref]_i` edges (expected value replaces sampling the
//! prefix, as in the paper), samples them independently from θ_gen with
//! its own PRNG stream, and prepends the chunk's (src, dst) prefix bits —
//! so chunk id spaces never overlap and the final graph is the
//! concatenation of the chunks.
//!
//! Workers push finished chunks into a bounded channel ([`crate::util::
//! threadpool::Bounded`]); a slow consumer (e.g. a disk writer) therefore
//! back-pressures generation, bounding peak memory at
//! `capacity × chunk_size` edges.

use super::kronecker::KroneckerGen;
use super::theta::Level;
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::rng::Pcg64;
use crate::util::threadpool::Bounded;
use crate::Result;

/// One generated chunk: edges whose ids already include the prefix.
#[derive(Debug)]
pub struct Chunk {
    /// Chunk index in [0, 4^prefix_levels).
    pub index: usize,
    /// Edges of this chunk (global ids).
    pub edges: EdgeList,
}

/// Configuration for chunked generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkConfig {
    /// Number of square levels consumed by the prefix (chunks = 4^levels).
    pub prefix_levels: u32,
    /// Worker thread count.
    pub workers: usize,
    /// Bounded channel capacity (chunks in flight) — the backpressure knob.
    pub queue_capacity: usize,
}

impl Default for ChunkConfig {
    fn default() -> Self {
        ChunkConfig {
            prefix_levels: 2,
            workers: crate::util::threadpool::default_threads(),
            queue_capacity: 4,
        }
    }
}

/// Expected edge share of every prefix chunk: the Kronecker product of the
/// per-level quadrant distributions restricted to the prefix levels.
pub fn prefix_weights(levels: &[Level], prefix_levels: u32) -> Vec<f64> {
    let mut weights = vec![1.0f64];
    for level in levels.iter().take(prefix_levels as usize) {
        let probs: [f64; 4] = match level {
            Level::Square { cum } => [cum[0], cum[1] - cum[0], cum[2] - cum[1], 1.0 - cum[2]],
            // marginal levels would make 2-way chunks; we restrict the
            // prefix to square levels so this branch stays uniform
            _ => [0.25, 0.25, 0.25, 0.25],
        };
        let mut next = Vec::with_capacity(weights.len() * 4);
        for w in &weights {
            for p in probs {
                next.push(w * p);
            }
        }
        weights = next;
    }
    weights
}

/// Run chunked generation, streaming chunks into `sink`. Returns the total
/// number of edges produced. The sink runs on the caller thread; workers
/// block when `queue_capacity` chunks are waiting (backpressure).
///
/// A sink error aborts generation early: in-flight workers stop at their
/// next chunk boundary, remaining chunks are never sampled, and the error
/// is returned.
pub fn generate_chunked<F>(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    total_edges: u64,
    seed: u64,
    cfg: ChunkConfig,
    mut sink: F,
) -> Result<u64>
where
    F: FnMut(Chunk) -> Result<()>,
{
    let (rb, db) = KroneckerGen::bits(n_src, n_dst);
    let shared = rb.min(db);
    let prefix_levels = cfg.prefix_levels.min(shared);
    let mut level_rng = Pcg64::new(seed);
    let levels = gen.levels(rb, db, &mut level_rng);
    let weights = prefix_weights(&levels, prefix_levels);
    let n_chunks = weights.len();

    // integer edge budget per chunk: floor + largest-remainder correction
    let mut budgets: Vec<u64> = weights
        .iter()
        .map(|w| (w * total_edges as f64).floor() as u64)
        .collect();
    let assigned: u64 = budgets.iter().sum();
    let mut remainder = total_edges - assigned;
    let mut order: Vec<usize> = (0..n_chunks).collect();
    order.sort_by(|&i, &j| {
        let fi = weights[i] * total_edges as f64 - budgets[i] as f64;
        let fj = weights[j] * total_edges as f64 - budgets[j] as f64;
        fj.partial_cmp(&fi).unwrap()
    });
    for &i in &order {
        if remainder == 0 {
            break;
        }
        budgets[i] += 1;
        remainder -= 1;
    }

    let spec = if gen.spec.square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let suffix_levels: Vec<Level> = levels.iter().skip(prefix_levels as usize).copied().collect();
    let chan: Bounded<Chunk> = Bounded::new(cfg.queue_capacity.max(1));
    let next = std::sync::atomic::AtomicUsize::new(0);
    let total_out = std::sync::atomic::AtomicU64::new(0);
    let abort = std::sync::atomic::AtomicBool::new(false);
    let mut sink_err: Option<crate::Error> = None;

    // suffix space: chunk-local ids before the prefix is prepended
    let suf_rb = rb - prefix_levels;
    let suf_db = db - prefix_levels;

    std::thread::scope(|s| {
        for _ in 0..cfg.workers.max(1) {
            let tx = chan.clone();
            let budgets = &budgets;
            let suffix_levels = &suffix_levels;
            let next = &next;
            let total_out = &total_out;
            let abort = &abort;
            s.spawn(move || {
                loop {
                    let ci = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if ci >= n_chunks || abort.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let count = budgets[ci];
                    if count == 0 {
                        continue;
                    }
                    // prefix bits of this chunk: pairs of (src,dst) bits,
                    // most significant first
                    let mut pre_s = 0u64;
                    let mut pre_d = 0u64;
                    for l in 0..prefix_levels {
                        let quad = (ci >> (2 * (prefix_levels - 1 - l))) & 3;
                        pre_s = (pre_s << 1) | (quad >> 1) as u64;
                        pre_d = (pre_d << 1) | (quad & 1) as u64;
                    }
                    let mut rng = Pcg64::with_stream(seed, ci as u64 + 1);
                    let mut edges = EdgeList::with_capacity(spec, count as usize);
                    let plan = KroneckerGen::plan(suffix_levels);
                    // sample in chunk-local suffix space, then prepend prefix
                    let mut produced = 0u64;
                    let max_attempts = count.saturating_mul(64).max(1024);
                    let mut attempts = 0u64;
                    while produced < count && attempts < max_attempts {
                        attempts += 1;
                        let (su, sv) = plan.sample(&mut rng);
                        let u = (pre_s << suf_rb) | su;
                        let v = (pre_d << suf_db) | sv;
                        if u < n_src && v < n_dst {
                            edges.push(u, v);
                            produced += 1;
                        }
                    }
                    // pathological rejection: fill uniformly inside the
                    // chunk's own id range so prefixes never collide
                    while produced < count {
                        let u = ((pre_s << suf_rb) | rng.below(1u64 << suf_rb)).min(n_src - 1);
                        let v = ((pre_d << suf_db) | rng.below(1u64 << suf_db)).min(n_dst - 1);
                        edges.push(u, v);
                        produced += 1;
                    }
                    total_out.fetch_add(produced, std::sync::atomic::Ordering::Relaxed);
                    if tx.send(Chunk { index: ci, edges }).is_err() {
                        break;
                    }
                }
            });
        }
        // consume on the caller thread; completion is detected by counting
        // chunks (workers send exactly one chunk per nonzero budget)
        let consumer_chan = chan.clone();
        let mut consumed = 0usize;
        let expected: usize = budgets.iter().filter(|&&b| b > 0).count();
        while consumed < expected {
            match consumer_chan.recv() {
                Some(chunk) => {
                    consumed += 1;
                    if let Err(e) = sink(chunk) {
                        // abort early: stop workers at their next chunk
                        // boundary instead of sampling the rest into a void
                        sink_err = Some(e);
                        abort.store(true, std::sync::atomic::Ordering::Relaxed);
                        break;
                    }
                }
                None => break,
            }
        }
        chan.close();
    });

    if let Some(e) = sink_err {
        return Err(e);
    }
    Ok(total_out.load(std::sync::atomic::Ordering::Relaxed))
}

/// Convenience: chunked generation collected into a single [`EdgeList`].
pub fn generate_chunked_collect(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    total_edges: u64,
    seed: u64,
    cfg: ChunkConfig,
) -> Result<EdgeList> {
    let spec = if gen.spec.square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut out = EdgeList::with_capacity(spec, total_edges as usize);
    generate_chunked(gen, n_src, n_dst, total_edges, seed, cfg, |chunk| {
        out.extend_from(&chunk.edges);
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structgen::theta::ThetaS;

    fn gen() -> KroneckerGen {
        KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 10_000)
    }

    #[test]
    fn prefix_weights_sum_to_one() {
        let g = gen();
        let mut rng = Pcg64::new(1);
        let levels = g.levels(10, 10, &mut rng);
        for pl in 0..4 {
            let w = prefix_weights(&levels, pl);
            assert_eq!(w.len(), 4usize.pow(pl));
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "pl={pl} sum={sum}");
        }
    }

    #[test]
    fn chunked_produces_exact_count() {
        let g = gen();
        let cfg = ChunkConfig { prefix_levels: 2, workers: 4, queue_capacity: 2 };
        let out = generate_chunked_collect(&g, 1 << 10, 1 << 10, 10_000, 42, cfg).unwrap();
        assert_eq!(out.len(), 10_000);
        assert!(out.validate().is_ok());
    }

    #[test]
    fn chunk_id_spaces_do_not_overlap() {
        let g = gen();
        let cfg = ChunkConfig { prefix_levels: 1, workers: 2, queue_capacity: 8 };
        let mut seen_prefix: std::collections::HashMap<usize, (u64, u64)> =
            std::collections::HashMap::new();
        generate_chunked(&g, 1 << 10, 1 << 10, 5_000, 7, cfg, |chunk| {
            // all edges in a chunk must share the chunk's top (src,dst) bits
            for (s, d) in chunk.edges.iter() {
                let key = (s >> 9, d >> 9);
                let entry = seen_prefix.entry(chunk.index).or_insert(key);
                assert_eq!(*entry, key, "chunk {} mixes prefixes", chunk.index);
            }
            Ok(())
        })
        .unwrap();
        // distinct chunks have distinct prefixes
        let prefixes: std::collections::HashSet<_> = seen_prefix.values().collect();
        assert_eq!(prefixes.len(), seen_prefix.len());
    }

    #[test]
    fn chunked_matches_unchunked_distribution() {
        // Degree head should be statistically similar between chunked and
        // direct sampling from the same theta.
        let g = gen();
        let direct = {
            use crate::structgen::StructureGenerator;
            g.generate_sized(1 << 10, 1 << 10, 40_000, 5).unwrap()
        };
        let cfg = ChunkConfig { prefix_levels: 3, workers: 8, queue_capacity: 4 };
        let chunked = generate_chunked_collect(&g, 1 << 10, 1 << 10, 40_000, 5, cfg).unwrap();
        let md = *direct.out_degrees().iter().max().unwrap() as f64;
        let mc = *chunked.out_degrees().iter().max().unwrap() as f64;
        assert!(mc / md < 1.7 && md / mc < 1.7, "md={md} mc={mc}");
    }

    #[test]
    fn sink_error_aborts_early() {
        let g = gen();
        // many small chunks so the abort has room to cut generation short
        let cfg = ChunkConfig { prefix_levels: 3, workers: 2, queue_capacity: 1 };
        let mut seen = 0usize;
        let err = generate_chunked(&g, 1 << 10, 1 << 10, 50_000, 11, cfg, |_chunk| {
            seen += 1;
            if seen == 2 {
                Err(crate::Error::Data("sink full".into()))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        // consumer stopped right after the failing chunk
        assert_eq!(seen, 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen();
        let cfg = ChunkConfig { prefix_levels: 2, workers: 4, queue_capacity: 2 };
        let mut a = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, cfg).unwrap();
        let mut b = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, cfg).unwrap();
        // chunk arrival order may differ; compare as sorted sets
        a.sort_dedup();
        b.sort_dedup();
        assert_eq!(a.src, b.src);
        assert_eq!(a.dst, b.dst);
    }
}
