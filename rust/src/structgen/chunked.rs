//! Chunked, shared-nothing Kronecker generation (paper §10 / Appendix 10).
//!
//! For graphs that don't fit in memory, θ is factored as
//! `θ_pref ⊗ θ_gen`: the first `prefix_levels` square levels form a prefix
//! distribution over 4^prefix_levels chunks. Each chunk i receives
//! `E_i = E · E[θ_pref]_i` edges (expected value replaces sampling the
//! prefix, as in the paper), samples them independently from θ_gen with
//! its own PRNG stream, and prepends the chunk's (src, dst) prefix bits —
//! so chunk id spaces never overlap and the final graph is the
//! concatenation of the chunks.
//!
//! The decomposition lives in [`KroneckerChunkPlan`]; execution —
//! worker pool, bounded-channel backpressure, in-order delivery, error
//! cancellation — is the shared
//! [`ParallelChunkRunner`](crate::pipeline::parallel::ParallelChunkRunner)
//! engine. Output is bit-identical for any worker count.

use super::kronecker::{KroneckerGen, SamplerPlan};
use super::theta::Level;
use crate::graph::{EdgeList, PartiteSpec};
use crate::pipeline::parallel::{apportion, ChunkPlan, ParallelChunkRunner};
use crate::util::rng::Pcg64;
use crate::Result;

/// One generated chunk: edges whose ids already include the prefix, plus
/// provenance the streaming report aggregates. Sinks receive chunks by
/// `&mut` (see [`crate::pipeline::Sink::edges`]): streaming sinks borrow
/// the edges and leave the buffer for the runner to recycle into its
/// arena, while ownership-taking sinks `std::mem::take` them.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Chunk index in [0, 4^prefix_levels).
    pub index: usize,
    /// Pool worker that sampled this chunk (0 on the sequential path).
    pub worker: usize,
    /// Wall-clock seconds the worker spent sampling this chunk; feeds the
    /// per-worker timing in [`crate::pipeline::StreamReport`].
    pub sample_secs: f64,
    /// Wall-clock seconds the worker spent encoding this chunk into
    /// `encoded` (0 when encoding was left to the sink).
    pub encode_secs: f64,
    /// Edges of this chunk (global ids).
    pub edges: EdgeList,
    /// The chunk's final shard wire bytes, when [`ChunkConfig::encode`]
    /// moved encoding onto the sampling worker. `edges` stays populated
    /// either way — taps and in-memory sinks keep observing decoded
    /// edges; shard sinks take these bytes instead of re-encoding.
    pub encoded: Option<crate::graph::io::EncodedChunk>,
}

/// Configuration for chunked generation. Construct with functional
/// update over [`ChunkConfig::default`] (`ChunkConfig { workers: 4,
/// ..ChunkConfig::default() }`) so new robustness knobs pick up their
/// defaults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkConfig {
    /// Number of square levels consumed by the prefix (chunks = 4^levels).
    pub prefix_levels: u32,
    /// Worker thread count (1 = sequential on the caller thread).
    pub workers: usize,
    /// Bounded channel capacity (chunks in flight) — the backpressure knob.
    pub queue_capacity: usize,
    /// Bounded retry for transient sample/sink/reader faults
    /// (deterministic backoff; the default never sleeps).
    pub retry: crate::pipeline::fault::RetryPolicy,
    /// Resume watermark: chunks below this index were already persisted
    /// by an interrupted run and are skipped (counted for ordering,
    /// never re-sampled, never forwarded to the sink).
    pub resume_from: usize,
    /// Exclusive upper bound on chunks this process samples: chunks at or
    /// above it are skipped exactly like resumed chunks. `None` runs the
    /// plan to its end. Together with `resume_from`, this restricts one
    /// run to the half-open chunk range `[resume_from, stop_before)` —
    /// the unit of distributed work ([`crate::pipeline::distrib`]).
    pub stop_before: Option<usize>,
    /// Deterministic fault-injection schedule (harness / tests); `None`
    /// in production runs.
    pub faults: Option<crate::pipeline::fault::FaultPlan>,
    /// On-disk shard encoding used when this run streams to a
    /// `ShardSink` (`sggedge1` fixed-width or `sggedge2` varint-delta).
    /// Ignored by in-memory sinks. Decoded edges are identical either
    /// way — only the bytes differ.
    pub format: crate::graph::io::ShardFormat,
    /// Encode each chunk into its final shard wire bytes on the sampling
    /// worker (cache-hot, fully parallel) instead of on the writer
    /// thread. Shard-sink runs enable this; in-memory sinks ignore the
    /// bytes, so it defaults off.
    pub encode: bool,
}

impl Default for ChunkConfig {
    fn default() -> Self {
        ChunkConfig {
            prefix_levels: 2,
            workers: crate::util::threadpool::default_threads(),
            queue_capacity: 4,
            retry: crate::pipeline::fault::RetryPolicy::default(),
            resume_from: 0,
            stop_before: None,
            faults: None,
            format: crate::graph::io::ShardFormat::Edge1,
            encode: false,
        }
    }
}

/// Expected edge share of every prefix chunk: the Kronecker product of the
/// per-level quadrant distributions restricted to the prefix levels.
pub fn prefix_weights(levels: &[Level], prefix_levels: u32) -> Vec<f64> {
    let mut weights = vec![1.0f64];
    for level in levels.iter().take(prefix_levels as usize) {
        let probs: [f64; 4] = match level {
            Level::Square { cum } => [cum[0], cum[1] - cum[0], cum[2] - cum[1], 1.0 - cum[2]],
            // marginal levels would make 2-way chunks; we restrict the
            // prefix to square levels so this branch stays uniform
            _ => [0.25, 0.25, 0.25, 0.25],
        };
        let mut next = Vec::with_capacity(weights.len() * 4);
        for w in &weights {
            for p in probs {
                next.push(w * p);
            }
        }
        weights = next;
    }
    weights
}

/// The Kronecker prefix decomposition as a [`ChunkPlan`]: per-chunk
/// integer edge budgets (largest-remainder apportionment of the prefix
/// weights), the compiled suffix sampler shared by every chunk, and the
/// per-chunk prefix bits. Each chunk samples on its own PRNG stream
/// (`Pcg64::with_stream(seed, index + 1)`), so the plan is deterministic
/// in the seed regardless of scheduling.
pub struct KroneckerChunkPlan {
    spec: PartiteSpec,
    budgets: Vec<u64>,
    sampler: SamplerPlan,
    prefix_levels: u32,
    /// Suffix (chunk-local) source / destination address bits.
    suf_rb: u32,
    suf_db: u32,
    n_src: u64,
    n_dst: u64,
    seed: u64,
}

impl KroneckerChunkPlan {
    /// Build the decomposition for `total_edges` edges over an
    /// `n_src × n_dst` id space. `prefix_levels` is clamped to the shared
    /// (square) levels of the cascade.
    pub fn new(
        gen: &KroneckerGen,
        n_src: u64,
        n_dst: u64,
        total_edges: u64,
        seed: u64,
        prefix_levels: u32,
    ) -> KroneckerChunkPlan {
        let (rb, db) = KroneckerGen::bits(n_src, n_dst);
        let shared = rb.min(db);
        let prefix_levels = prefix_levels.min(shared);
        let mut level_rng = Pcg64::new(seed);
        let levels = gen.levels(rb, db, &mut level_rng);
        let weights = prefix_weights(&levels, prefix_levels);
        let budgets = apportion(&weights, total_edges);
        let suffix_levels: Vec<Level> =
            levels.iter().skip(prefix_levels as usize).copied().collect();
        let spec = if gen.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        };
        KroneckerChunkPlan {
            spec,
            budgets,
            sampler: KroneckerGen::plan(&suffix_levels),
            prefix_levels,
            suf_rb: rb - prefix_levels,
            suf_db: db - prefix_levels,
            n_src,
            n_dst,
            seed,
        }
    }
}

impl ChunkPlan for KroneckerChunkPlan {
    fn n_chunks(&self) -> usize {
        self.budgets.len()
    }

    fn sample(&self, ci: usize) -> Result<EdgeList> {
        let mut edges = EdgeList::new(self.spec);
        self.sample_into(ci, &mut edges)?;
        Ok(edges)
    }

    /// Arena-friendly sampling: `edges` is reset (spec overwritten,
    /// capacity kept) and refilled, so the runner's recycled chunk
    /// buffers avoid a fresh allocation per chunk. Attempts run through
    /// the batched draw-buffer path — identical edges to the scalar
    /// descent, including the PRNG state entering the uniform fallback.
    fn sample_into(&self, ci: usize, edges: &mut EdgeList) -> Result<()> {
        let count = self.budgets[ci];
        edges.reset(self.spec);
        edges.reserve(count as usize);
        if count == 0 {
            return Ok(());
        }
        // prefix bits of this chunk: pairs of (src, dst) bits, most
        // significant first
        let mut pre_s = 0u64;
        let mut pre_d = 0u64;
        for l in 0..self.prefix_levels {
            let quad = (ci >> (2 * (self.prefix_levels - 1 - l))) & 3;
            pre_s = (pre_s << 1) | (quad >> 1) as u64;
            pre_d = (pre_d << 1) | (quad & 1) as u64;
        }
        let mut rng = Pcg64::with_stream(self.seed, ci as u64 + 1);
        // sample in chunk-local suffix space, then prepend the prefix
        let (suf_rb, suf_db) = (self.suf_rb, self.suf_db);
        let (n_src, n_dst) = (self.n_src, self.n_dst);
        let mut draws = Vec::new();
        let mut produced = self.sampler.sample_rejection_batched(
            count,
            KroneckerGen::max_attempts(count),
            &mut rng,
            &mut draws,
            |su, sv| {
                let u = (pre_s << suf_rb) | su;
                let v = (pre_d << suf_db) | sv;
                if u < n_src && v < n_dst {
                    edges.push(u, v);
                    true
                } else {
                    false
                }
            },
        );
        // pathological rejection: fill uniformly inside the chunk's own
        // id range so prefixes never collide
        while produced < count {
            let u = ((pre_s << self.suf_rb) | rng.below(1u64 << self.suf_rb))
                .min(self.n_src - 1);
            let v = ((pre_d << self.suf_db) | rng.below(1u64 << self.suf_db))
                .min(self.n_dst - 1);
            edges.push(u, v);
            produced += 1;
        }
        Ok(())
    }
}

/// Run chunked generation, streaming chunks into `sink` in chunk-index
/// order. Returns the total number of edges produced. With
/// `cfg.workers > 1` chunks are sampled concurrently on a worker pool;
/// the output is bit-identical to `workers == 1` because every chunk has
/// its own PRNG stream and the writer re-orders delivery.
///
/// A sink error aborts generation early: in-flight workers stop at their
/// next chunk boundary, remaining chunks are never sampled, and the error
/// is returned.
pub fn generate_chunked<F>(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    total_edges: u64,
    seed: u64,
    cfg: ChunkConfig,
    mut sink: F,
) -> Result<u64>
where
    F: FnMut(&mut Chunk) -> Result<()>,
{
    let plan = KroneckerChunkPlan::new(gen, n_src, n_dst, total_edges, seed, cfg.prefix_levels);
    ParallelChunkRunner::from_config(cfg).run(&plan, &mut sink)
}

/// Convenience: chunked generation collected into a single [`EdgeList`].
pub fn generate_chunked_collect(
    gen: &KroneckerGen,
    n_src: u64,
    n_dst: u64,
    total_edges: u64,
    seed: u64,
    cfg: ChunkConfig,
) -> Result<EdgeList> {
    let spec = if gen.spec.square {
        PartiteSpec::square(n_src)
    } else {
        PartiteSpec::bipartite(n_src, n_dst)
    };
    let mut out = EdgeList::with_capacity(spec, total_edges as usize);
    generate_chunked(gen, n_src, n_dst, total_edges, seed, cfg, |chunk| {
        out.extend_from(&chunk.edges);
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structgen::theta::ThetaS;

    fn gen() -> KroneckerGen {
        KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1 << 10), 10_000)
    }

    #[test]
    fn prefix_weights_sum_to_one() {
        let g = gen();
        let mut rng = Pcg64::new(1);
        let levels = g.levels(10, 10, &mut rng);
        for pl in 0..4 {
            let w = prefix_weights(&levels, pl);
            assert_eq!(w.len(), 4usize.pow(pl));
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "pl={pl} sum={sum}");
        }
    }

    #[test]
    fn chunked_produces_exact_count() {
        let g = gen();
        let cfg = ChunkConfig {
            prefix_levels: 2,
            workers: 4,
            queue_capacity: 2,
            ..ChunkConfig::default()
        };
        let out = generate_chunked_collect(&g, 1 << 10, 1 << 10, 10_000, 42, cfg).unwrap();
        assert_eq!(out.len(), 10_000);
        assert!(out.validate().is_ok());
    }

    #[test]
    fn chunk_id_spaces_do_not_overlap() {
        let g = gen();
        let cfg = ChunkConfig {
            prefix_levels: 1,
            workers: 2,
            queue_capacity: 8,
            ..ChunkConfig::default()
        };
        let mut seen_prefix: std::collections::HashMap<usize, (u64, u64)> =
            std::collections::HashMap::new();
        generate_chunked(&g, 1 << 10, 1 << 10, 5_000, 7, cfg, |chunk| {
            // all edges in a chunk must share the chunk's top (src,dst) bits
            for (s, d) in chunk.edges.iter() {
                let key = (s >> 9, d >> 9);
                let entry = seen_prefix.entry(chunk.index).or_insert(key);
                assert_eq!(*entry, key, "chunk {} mixes prefixes", chunk.index);
            }
            Ok(())
        })
        .unwrap();
        // distinct chunks have distinct prefixes
        let prefixes: std::collections::HashSet<_> = seen_prefix.values().collect();
        assert_eq!(prefixes.len(), seen_prefix.len());
    }

    #[test]
    fn chunked_matches_unchunked_distribution() {
        // Degree head should be statistically similar between chunked and
        // direct sampling from the same theta.
        let g = gen();
        let direct = {
            use crate::structgen::StructureGenerator;
            g.generate_sized(1 << 10, 1 << 10, 40_000, 5).unwrap()
        };
        let cfg = ChunkConfig {
            prefix_levels: 3,
            workers: 8,
            queue_capacity: 4,
            ..ChunkConfig::default()
        };
        let chunked = generate_chunked_collect(&g, 1 << 10, 1 << 10, 40_000, 5, cfg).unwrap();
        let md = *direct.out_degrees().iter().max().unwrap() as f64;
        let mc = *chunked.out_degrees().iter().max().unwrap() as f64;
        assert!(mc / md < 1.7 && md / mc < 1.7, "md={md} mc={mc}");
    }

    #[test]
    fn sink_error_aborts_early() {
        let g = gen();
        // many small chunks so the abort has room to cut generation short
        let cfg = ChunkConfig {
            prefix_levels: 3,
            workers: 2,
            queue_capacity: 1,
            ..ChunkConfig::default()
        };
        let mut seen = 0usize;
        let err = generate_chunked(&g, 1 << 10, 1 << 10, 50_000, 11, cfg, |_chunk| {
            seen += 1;
            if seen == 2 {
                Err(crate::Error::Data("sink full".into()))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        // consumer stopped right after the failing chunk
        assert_eq!(seen, 2);
    }

    #[test]
    fn deterministic_given_seed_and_in_order() {
        let g = gen();
        let cfg = ChunkConfig {
            prefix_levels: 2,
            workers: 4,
            queue_capacity: 2,
            ..ChunkConfig::default()
        };
        let a = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, cfg).unwrap();
        let b = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, cfg).unwrap();
        // the runner delivers chunks in index order, so runs are equal
        // edge-for-edge — no multiset normalization needed
        assert_eq!(a.src, b.src);
        assert_eq!(a.dst, b.dst);
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let g = gen();
        let base = ChunkConfig {
            prefix_levels: 2,
            workers: 1,
            queue_capacity: 2,
            ..ChunkConfig::default()
        };
        let seq = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, base).unwrap();
        for workers in [2, 4, 8] {
            let cfg = ChunkConfig { workers, ..base };
            let par = generate_chunked_collect(&g, 1 << 10, 1 << 10, 8_000, 9, cfg).unwrap();
            assert_eq!(seq.src, par.src, "workers={workers}");
            assert_eq!(seq.dst, par.dst, "workers={workers}");
        }
    }
}
