//! The seed matrix θ_S and its marginals (paper eq. 2–4).

/// 2×2 stochastic Kronecker seed `[[a, b], [c, d]]`, a+b+c+d = 1.
///
/// `a` is the probability mass of the top-left quadrant at each recursion
/// level; `p = a+b` (row marginal, paper θ_V) and `q = a+c` (column
/// marginal, θ_H).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThetaS {
    /// Top-left quadrant mass.
    pub a: f64,
    /// Top-right quadrant mass.
    pub b: f64,
    /// Bottom-left quadrant mass.
    pub c: f64,
    /// Bottom-right quadrant mass.
    pub d: f64,
}

impl ThetaS {
    /// Construct, renormalizing to the probability simplex and clamping
    /// tiny/negative entries.
    pub fn new(a: f64, b: f64, c: f64, d: f64) -> ThetaS {
        let mut t = ThetaS { a, b, c, d };
        t.normalize();
        t
    }

    /// The R-MAT default seed from Chakrabarti et al. (a/b = a/c = 3).
    pub fn rmat_default() -> ThetaS {
        ThetaS::new(0.57, 0.19, 0.19, 0.05)
    }

    /// Clamp entries to [1e-9, 1] and renormalize to sum 1.
    pub fn normalize(&mut self) {
        self.a = self.a.max(1e-9);
        self.b = self.b.max(1e-9);
        self.c = self.c.max(1e-9);
        self.d = self.d.max(1e-9);
        let s = self.a + self.b + self.c + self.d;
        self.a /= s;
        self.b /= s;
        self.c /= s;
        self.d /= s;
    }

    /// Row marginal p = a + b (paper eq. 4): probability a destination bit
    /// is 0.
    #[inline]
    pub fn p(&self) -> f64 {
        self.a + self.b
    }

    /// Column marginal q = a + c: probability a source bit is 0.
    #[inline]
    pub fn q(&self) -> f64 {
        self.a + self.c
    }

    /// Build a ThetaS from marginals (p, q) and the ratios r_b = a/b,
    /// r_c = a/c estimated by MLE (paper §3.2.3: the system in eq. 6 is
    /// under-determined, the ratios close it).
    pub fn from_marginals(p: f64, q: f64, r_b: f64, r_c: f64) -> ThetaS {
        let p = p.clamp(1e-6, 1.0 - 1e-6);
        let q = q.clamp(1e-6, 1.0 - 1e-6);
        let r_b = r_b.max(1e-6);
        let r_c = r_c.max(1e-6);
        // a from each marginal equation, then reconciled
        let a_p = p * r_b / (1.0 + r_b);
        let a_q = q * r_c / (1.0 + r_c);
        let a = 0.5 * (a_p + a_q);
        let b = (p - a).max(1e-9);
        let c = (q - a).max(1e-9);
        let d = (1.0 - a - b - c).max(1e-9);
        ThetaS::new(a, b, c, d)
    }

    /// Cumulative quadrant thresholds (a, a+b, a+b+c) for fast sampling.
    #[inline]
    pub fn cumulative(&self) -> [f64; 3] {
        [self.a, self.a + self.b, self.a + self.b + self.c]
    }

    /// [`ThetaS::cumulative`] in u32 fixed point — the compiled form the
    /// branch-free descent sampler compares raw PRNG bits against.
    #[inline]
    pub fn cumulative_u32(&self) -> [u32; 3] {
        let c = self.cumulative();
        [u32_threshold(c[0]), u32_threshold(c[1]), u32_threshold(c[2])]
    }

    /// Log-likelihood of observed quadrant counts under this seed.
    pub fn log_likelihood(&self, counts: &[f64; 4]) -> f64 {
        counts[0] * self.a.ln()
            + counts[1] * self.b.ln()
            + counts[2] * self.c.ln()
            + counts[3] * self.d.ln()
    }
}

impl Default for ThetaS {
    fn default() -> Self {
        ThetaS::rmat_default()
    }
}

/// Map a probability to the 32-bit fixed-point threshold the compiled
/// samplers compare raw PRNG halves against: a level decision becomes a
/// single branch-free `bits >= threshold` instead of an f64 compare.
/// Shared by the scalar and batched descent loops so both paths test
/// against bit-identical thresholds.
#[inline]
pub fn u32_threshold(p: f64) -> u32 {
    (p.clamp(0.0, 1.0) * u32::MAX as f64) as u32
}

/// One recursion level of the (possibly noisy) Kronecker cascade. Square
/// levels consume one source bit and one destination bit; Row/Col levels
/// consume a single bit of the longer dimension (paper θ_H / θ_V).
#[derive(Clone, Copy, Debug)]
pub enum Level {
    /// Full 2×2 quadrant choice with cumulative thresholds.
    Square { cum: [f64; 3] },
    /// Only a destination bit remains: P(bit = 0) = p.
    Row { p: f64 },
    /// Only a source bit remains: P(bit = 0) = q.
    Col { q: f64 },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_simplex() {
        let t = ThetaS::new(3.0, 1.0, 1.0, 1.0);
        assert!((t.a + t.b + t.c + t.d - 1.0).abs() < 1e-12);
        assert!((t.a - 0.5).abs() < 1e-12);
    }

    #[test]
    fn marginals() {
        let t = ThetaS::rmat_default();
        assert!((t.p() - 0.76).abs() < 1e-9);
        assert!((t.q() - 0.76).abs() < 1e-9);
    }

    #[test]
    fn from_marginals_recovers() {
        let t0 = ThetaS::rmat_default();
        let t = ThetaS::from_marginals(t0.p(), t0.q(), t0.a / t0.b, t0.a / t0.c);
        assert!((t.a - t0.a).abs() < 1e-6, "{t:?}");
        assert!((t.d - t0.d).abs() < 1e-6);
    }

    #[test]
    fn from_marginals_asymmetric() {
        // a=0.5 b=0.3 c=0.1 d=0.1 -> p=0.8 q=0.6, r_b=5/3, r_c=5
        let t = ThetaS::from_marginals(0.8, 0.6, 5.0 / 3.0, 5.0);
        assert!((t.a - 0.5).abs() < 1e-6, "{t:?}");
        assert!((t.b - 0.3).abs() < 1e-6);
        assert!((t.c - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cumulative_monotone() {
        let t = ThetaS::rmat_default();
        let c = t.cumulative();
        assert!(c[0] < c[1] && c[1] < c[2] && c[2] < 1.0);
    }

    #[test]
    fn u32_thresholds_are_monotone_and_clamped() {
        assert_eq!(u32_threshold(0.0), 0);
        assert_eq!(u32_threshold(-1.0), 0);
        assert_eq!(u32_threshold(1.0), u32::MAX);
        assert_eq!(u32_threshold(2.0), u32::MAX);
        let t = ThetaS::rmat_default();
        let c = t.cumulative_u32();
        assert!(c[0] < c[1] && c[1] < c[2] && c[2] < u32::MAX);
        // fixed point agrees with the f64 cumulative to one ulp of u32
        for (fx, fl) in c.iter().zip(t.cumulative()) {
            assert_eq!(*fx, (fl * u32::MAX as f64) as u32);
        }
    }

    #[test]
    fn loglik_prefers_true_seed() {
        let truth = ThetaS::new(0.6, 0.2, 0.15, 0.05);
        let counts = [600.0, 200.0, 150.0, 50.0];
        let ll_true = truth.log_likelihood(&counts);
        let ll_other = ThetaS::rmat_default().log_likelihood(&counts);
        assert!(ll_true > ll_other);
    }
}
