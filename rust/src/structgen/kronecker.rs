//! Generalized stochastic Kronecker edge sampler (paper eq. 1–5).
//!
//! θ is never materialized: each edge performs one bit-descent per
//! recursion level. `min(rb, db)` levels are full 2×2 quadrant choices
//! (θ_S); the remaining `|rb − db|` levels consume a single bit of the
//! longer dimension using the appropriate marginal (θ_H / θ_V, eq. 2).
//! With `rb == db` this is exactly R-MAT (eq. 5).

use super::theta::{u32_threshold, Level, ThetaS};
use super::{noise::NoiseConfig, StructureGenerator};
use crate::error::{Error, Result};
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::json::Json;
use crate::util::rng::{Pcg64, RNG_BLOCK};

/// Fitted generalized-Kronecker structure generator.
#[derive(Clone, Debug)]
pub struct KroneckerGen {
    /// Seed matrix (fitted by [`super::fit::fit_kronecker`] or set manually).
    pub theta: ThetaS,
    /// Partite sizes of the *original* graph (scale 1).
    pub spec: PartiteSpec,
    /// Edge count of the original graph.
    pub edges: u64,
    /// Optional per-level noise (paper §9). `None` = pure Kronecker power.
    pub noise: Option<NoiseConfig>,
}

impl KroneckerGen {
    /// Construct from an explicit seed matrix.
    pub fn new(theta: ThetaS, spec: PartiteSpec, edges: u64) -> Self {
        KroneckerGen { theta, spec, edges, noise: None }
    }

    /// Enable per-level noise with the given amplitude scale in [0,1]
    /// (fraction of the maximal admissible n_f from paper eq. 25).
    pub fn with_noise(mut self, amplitude: f64) -> Self {
        self.noise = Some(NoiseConfig { amplitude });
        self
    }

    /// Reconstruct from a `.sggm` artifact state (inverse of
    /// [`StructureGenerator::save_state`]). θ entries are restored
    /// verbatim — no renormalization — so sampling is bit-identical to
    /// the generator that was saved.
    pub fn from_state(state: &Json) -> Result<KroneckerGen> {
        let t = state.req("theta")?;
        let theta = ThetaS {
            a: t.req_f64("a")?,
            b: t.req_f64("b")?,
            c: t.req_f64("c")?,
            d: t.req_f64("d")?,
        };
        let noise = match state.opt("noise") {
            None => None,
            Some(v) => Some(NoiseConfig {
                amplitude: v
                    .as_f64()
                    .ok_or_else(|| Error::Data("artifact: `noise` must be a number".into()))?,
            }),
        };
        Ok(KroneckerGen {
            theta,
            spec: PartiteSpec::from_json(state.req("spec")?)?,
            edges: state.req_u64("edges")?,
            noise,
        })
    }

    /// Number of source/destination address bits for given partite sizes.
    pub fn bits(n_src: u64, n_dst: u64) -> (u32, u32) {
        let bits_for = |n: u64| -> u32 {
            if n <= 1 {
                0
            } else {
                64 - (n - 1).leading_zeros()
            }
        };
        (bits_for(n_src), bits_for(n_dst))
    }

    /// Build the per-level cascade for a graph with `rb` source bits and
    /// `db` destination bits, applying noise if configured (paper eq. 23).
    pub fn levels(&self, rb: u32, db: u32, rng: &mut Pcg64) -> Vec<Level> {
        let shared = rb.min(db);
        let mut levels = Vec::with_capacity((rb.max(db)) as usize);
        for _ in 0..shared {
            let t = match &self.noise {
                Some(cfg) => cfg.perturb(self.theta, rng),
                None => self.theta,
            };
            levels.push(Level::Square { cum: t.cumulative() });
        }
        // extra source bits: only the source-bit marginal applies
        for _ in db..rb {
            let mut p0 = self.theta.p();
            if let Some(cfg) = &self.noise {
                p0 = cfg.perturb_marginal(p0, rng);
            }
            levels.push(Level::Col { q: p0 });
        }
        // extra destination bits
        for _ in rb..db {
            let mut q0 = self.theta.q();
            if let Some(cfg) = &self.noise {
                q0 = cfg.perturb_marginal(q0, rng);
            }
            levels.push(Level::Row { p: q0 });
        }
        levels
    }

    /// Compile a level cascade into the branchless integer-threshold
    /// [`SamplerPlan`] used on the hot path (see EXPERIMENTS.md §Perf:
    /// ~5× over the enum-match/f64 descent).
    pub fn plan(levels: &[Level]) -> SamplerPlan {
        let mut square = Vec::new();
        let mut col_q = Vec::new();
        let mut row_p = Vec::new();
        for level in levels {
            match level {
                Level::Square { cum } => {
                    square.push([
                        u32_threshold(cum[0]),
                        u32_threshold(cum[1]),
                        u32_threshold(cum[2]),
                    ]);
                }
                Level::Col { q } => col_q.push(u32_threshold(*q)),
                Level::Row { p } => row_p.push(u32_threshold(*p)),
            }
        }
        SamplerPlan { square, col_q, row_p }
    }

    /// Sample one edge by descending the cascade. Returns raw (src, dst)
    /// in the padded 2^rb × 2^db space.
    #[inline]
    pub fn sample_raw(levels: &[Level], rng: &mut Pcg64) -> (u64, u64) {
        let mut u = 0u64;
        let mut v = 0u64;
        for level in levels {
            match level {
                Level::Square { cum } => {
                    let r = rng.f64();
                    // quadrant: 0 -> (0,0), 1 -> (0,1), 2 -> (1,0), 3 -> (1,1)
                    let (sb, db_) = if r < cum[0] {
                        (0, 0)
                    } else if r < cum[1] {
                        (0, 1)
                    } else if r < cum[2] {
                        (1, 0)
                    } else {
                        (1, 1)
                    };
                    u = (u << 1) | sb;
                    v = (v << 1) | db_;
                }
                Level::Col { q } => {
                    let bit = (rng.f64() >= *q) as u64;
                    u = (u << 1) | bit;
                }
                Level::Row { p } => {
                    let bit = (rng.f64() >= *p) as u64;
                    v = (v << 1) | bit;
                }
            }
        }
        (u, v)
    }

    /// Bounded rejection-attempt budget for `count` requested edges.
    /// Shared by the one-shot and chunked samplers so both enter the
    /// uniform fallback with identical PRNG state.
    #[inline]
    pub fn max_attempts(count: u64) -> u64 {
        count.saturating_mul(64).max(1024)
    }

    /// Sample `count` edges into `out`, rejecting samples that fall outside
    /// the requested partite sizes (the padded space has 2^bits slots).
    /// Attempts run through the batched draw-buffer path of
    /// [`SamplerPlan::sample_rejection_batched`].
    pub fn sample_into(
        levels: &[Level],
        n_src: u64,
        n_dst: u64,
        count: u64,
        rng: &mut Pcg64,
        out: &mut EdgeList,
    ) {
        let plan = Self::plan(levels);
        let mut draws = Vec::new();
        // Bounded rejection: with mass concentrated on low ids the
        // acceptance rate is high; guard against pathological thetas.
        let mut produced =
            plan.sample_rejection_batched(count, Self::max_attempts(count), rng, &mut draws, |u, v| {
                if u < n_src && v < n_dst {
                    out.push(u, v);
                    true
                } else {
                    false
                }
            });
        // If rejection was pathological, fill the remainder uniformly so
        // the requested edge count is always honored.
        while produced < count {
            out.push(rng.below(n_src), rng.below(n_dst));
            produced += 1;
        }
    }
}

/// Branchless hot-path sampler compiled from a level cascade: per square
/// level the quadrant index is the count of thresholds below the random
/// draw (no branches, no f64 math), and one 64-bit RNG output feeds *two*
/// levels via its 32-bit halves. See EXPERIMENTS.md §Perf for the
/// iteration log (enum/f64 descent → u64 thresholds → paired 32-bit
/// draws).
#[derive(Clone, Debug)]
pub struct SamplerPlan {
    /// 32-bit thresholds per square level.
    square: Vec<[u32; 3]>,
    col_q: Vec<u32>,
    row_p: Vec<u32>,
}

impl SamplerPlan {
    /// Sample one raw (src, dst) pair.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> (u64, u64) {
        let mut u = 0u64;
        let mut v = 0u64;
        let mut pairs = self.square.chunks_exact(2);
        for pair in &mut pairs {
            let r = rng.next_u64();
            let (r0, r1) = (r as u32, (r >> 32) as u32);
            let t = &pair[0];
            let quad = (r0 >= t[0]) as u64 + (r0 >= t[1]) as u64 + (r0 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
            let t = &pair[1];
            let quad = (r1 >= t[0]) as u64 + (r1 >= t[1]) as u64 + (r1 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
        }
        for t in pairs.remainder() {
            let r0 = rng.next_u64() as u32;
            let quad = (r0 >= t[0]) as u64 + (r0 >= t[1]) as u64 + (r0 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
        }
        for &t in &self.col_q {
            u = (u << 1) | (rng.next_u64() as u32 >= t) as u64;
        }
        for &t in &self.row_p {
            v = (v << 1) | (rng.next_u64() as u32 >= t) as u64;
        }
        (u, v)
    }

    /// Raw 64-bit draws one attempt consumes: one per square-level pair
    /// (halves feed two levels), one for an odd remainder level, one per
    /// marginal bit. The batched path prefetches in this stride.
    #[inline]
    pub fn draws_per_attempt(&self) -> usize {
        self.square.len().div_ceil(2) + self.col_q.len() + self.row_p.len()
    }

    /// Decode one attempt from a prefetched draw slice (exactly
    /// [`SamplerPlan::draws_per_attempt`] values, consumed in the same
    /// order [`SamplerPlan::sample`] draws them — the two paths return
    /// identical pairs for identical raw streams). The loop body is
    /// pure integer compare/shift arithmetic on an in-cache slice, so
    /// the compiler can unroll and pipeline it without the serial PRNG
    /// dependency chain between levels.
    #[inline]
    pub fn decode(&self, draws: &[u64]) -> (u64, u64) {
        let mut u = 0u64;
        let mut v = 0u64;
        let mut k = 0usize;
        let mut pairs = self.square.chunks_exact(2);
        for pair in &mut pairs {
            let r = draws[k];
            k += 1;
            let (r0, r1) = (r as u32, (r >> 32) as u32);
            let t = &pair[0];
            let quad = (r0 >= t[0]) as u64 + (r0 >= t[1]) as u64 + (r0 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
            let t = &pair[1];
            let quad = (r1 >= t[0]) as u64 + (r1 >= t[1]) as u64 + (r1 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
        }
        for t in pairs.remainder() {
            let r0 = draws[k] as u32;
            k += 1;
            let quad = (r0 >= t[0]) as u64 + (r0 >= t[1]) as u64 + (r0 >= t[2]) as u64;
            u = (u << 1) | (quad >> 1);
            v = (v << 1) | (quad & 1);
        }
        for &t in &self.col_q {
            u = (u << 1) | (draws[k] as u32 >= t) as u64;
            k += 1;
        }
        for &t in &self.row_p {
            v = (v << 1) | (draws[k] as u32 >= t) as u64;
            k += 1;
        }
        debug_assert_eq!(k, self.draws_per_attempt());
        (u, v)
    }

    /// Run the bounded rejection loop in prefetched batches: up to
    /// [`RNG_BLOCK`] raw draws are pulled into `draws` (a reused
    /// caller-owned buffer) per refill, then decoded attempt by attempt
    /// with no PRNG calls inside the decode loop. `accept` is called
    /// once per raw attempt and returns whether the pair was kept; the
    /// loop stops after `count` acceptances or `max_attempts` raw
    /// attempts and returns the acceptances.
    ///
    /// Determinism contract: identical to the scalar
    /// `while { plan.sample(rng) }` loop. The final block is clamped to
    /// the remaining attempt budget, so when the budget exhausts the
    /// generator has consumed *exactly* `max_attempts ×
    /// draws_per_attempt` outputs — a caller's fallback path (uniform
    /// fill) starts from the same PRNG state either way. When `count`
    /// is reached mid-block the generator sits ahead of the served
    /// position, which is unobservable because a satisfied rejection
    /// loop is the last user of its chunk stream.
    pub fn sample_rejection_batched<F: FnMut(u64, u64) -> bool>(
        &self,
        count: u64,
        max_attempts: u64,
        rng: &mut Pcg64,
        draws: &mut Vec<u64>,
        mut accept: F,
    ) -> u64 {
        let dpa = self.draws_per_attempt();
        let mut produced = 0u64;
        let mut attempts = 0u64;
        if dpa == 0 {
            // degenerate 1×1 space: every attempt is (0, 0), no draws
            while produced < count && attempts < max_attempts {
                attempts += 1;
                produced += accept(0, 0) as u64;
            }
            return produced;
        }
        let block_attempts = (RNG_BLOCK / dpa).max(1) as u64;
        'blocks: while produced < count && attempts < max_attempts {
            let take = block_attempts.min(max_attempts - attempts);
            rng.fill_u64(draws, take as usize * dpa);
            attempts += take;
            for a in draws.chunks_exact(dpa) {
                let (u, v) = self.decode(a);
                produced += accept(u, v) as u64;
                if produced == count {
                    break 'blocks;
                }
            }
        }
        produced
    }
}

impl StructureGenerator for KroneckerGen {
    fn name(&self) -> &'static str {
        if self.noise.is_some() {
            "kronecker-noisy"
        } else {
            "kronecker"
        }
    }

    fn base(&self) -> (PartiteSpec, u64) {
        (self.spec, self.edges)
    }

    /// Out-of-core override: the prefix-partitioned decomposition
    /// ([`super::chunked::KroneckerChunkPlan`], paper §10) — bounded
    /// peak memory, and bit-identical output for any worker count.
    fn chunk_plan<'a>(
        &'a self,
        n_src: u64,
        n_dst: u64,
        edges: u64,
        seed: u64,
        prefix_levels: u32,
    ) -> Result<Box<dyn crate::pipeline::parallel::ChunkPlan + 'a>> {
        Ok(Box::new(super::chunked::KroneckerChunkPlan::new(
            self,
            n_src,
            n_dst,
            edges,
            seed,
            prefix_levels,
        )))
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            (
                "theta",
                Json::obj(vec![
                    ("a", Json::from(self.theta.a)),
                    ("b", Json::from(self.theta.b)),
                    ("c", Json::from(self.theta.c)),
                    ("d", Json::from(self.theta.d)),
                ]),
            ),
            ("spec", self.spec.to_json()),
            ("edges", Json::u64_exact(self.edges)),
            (
                "noise",
                match &self.noise {
                    Some(cfg) => Json::from(cfg.amplitude),
                    None => Json::Null,
                },
            ),
        ]))
    }

    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList> {
        if n_src == 0 || n_dst == 0 {
            return Err(Error::Config("empty partite".into()));
        }
        let (rb, db) = Self::bits(n_src, n_dst);
        let mut rng = Pcg64::new(seed);
        let levels = self.levels(rb, db, &mut rng);
        let spec = if self.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        };
        let mut out = EdgeList::with_capacity(spec, edges as usize);
        Self::sample_into(&levels, n_src, n_dst, edges, &mut rng, &mut out);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_computation() {
        assert_eq!(KroneckerGen::bits(1, 1), (0, 0));
        assert_eq!(KroneckerGen::bits(2, 2), (1, 1));
        assert_eq!(KroneckerGen::bits(5, 16), (3, 4));
        assert_eq!(KroneckerGen::bits(1024, 1000), (10, 10));
    }

    #[test]
    fn generates_requested_count_and_bounds() {
        let g = KroneckerGen::new(
            ThetaS::rmat_default(),
            PartiteSpec::bipartite(100, 50),
            1_000,
        );
        let e = g.generate(1, 7).unwrap();
        assert_eq!(e.len(), 1_000);
        assert!(e.validate().is_ok());
    }

    #[test]
    fn square_is_rmat() {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(1024), 10_000);
        let e = g.generate(1, 3).unwrap();
        assert_eq!(e.len(), 10_000);
        // skewed theta -> node 0 is the heaviest hub with high probability
        let deg = e.out_degrees();
        let max_deg = *deg.iter().max().unwrap();
        assert!(deg[0] as f64 >= 0.5 * max_deg as f64, "deg0={} max={}", deg[0], max_deg);
    }

    #[test]
    fn deterministic_per_seed() {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(256), 5_000);
        let a = g.generate(1, 42).unwrap();
        let b = g.generate(1, 42).unwrap();
        assert_eq!(a.src, b.src);
        assert_eq!(a.dst, b.dst);
        let c = g.generate(1, 43).unwrap();
        assert_ne!(a.src, c.src);
    }

    #[test]
    fn scaling_preserves_density() {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(128), 1_000);
        let e2 = g.generate(2, 1).unwrap();
        assert_eq!(e2.spec.n_src, 256);
        assert_eq!(e2.len(), 4_000); // edges scale quadratically
    }

    #[test]
    fn skew_increases_hub_mass() {
        // more skewed theta -> heavier head of degree distribution
        let mild = KroneckerGen::new(ThetaS::new(0.3, 0.25, 0.25, 0.2), PartiteSpec::square(512), 20_000);
        let skew = KroneckerGen::new(ThetaS::new(0.7, 0.15, 0.1, 0.05), PartiteSpec::square(512), 20_000);
        let d_mild = mild.generate(1, 5).unwrap().out_degrees();
        let d_skew = skew.generate(1, 5).unwrap().out_degrees();
        let max_mild = *d_mild.iter().max().unwrap();
        let max_skew = *d_skew.iter().max().unwrap();
        assert!(max_skew > max_mild, "skew {max_skew} <= mild {max_mild}");
    }

    #[test]
    fn uniform_theta_close_to_er() {
        let g = KroneckerGen::new(ThetaS::new(0.25, 0.25, 0.25, 0.25), PartiteSpec::square(256), 50_000);
        let deg = g.generate(1, 11).unwrap().out_degrees();
        // uniform theta: expected degree ~ E/N = 195; max should be modest
        let max_deg = *deg.iter().max().unwrap() as f64;
        let mean = 50_000.0 / 256.0;
        assert!(max_deg < mean * 1.6, "max={max_deg} mean={mean}");
    }

    /// The pre-batching scalar rejection loop, kept verbatim as the
    /// reference the batched path must reproduce draw-for-draw.
    fn scalar_sample_into(
        levels: &[Level],
        n_src: u64,
        n_dst: u64,
        count: u64,
        rng: &mut Pcg64,
        out: &mut EdgeList,
    ) {
        let plan = KroneckerGen::plan(levels);
        let mut produced = 0u64;
        let max_attempts = KroneckerGen::max_attempts(count);
        let mut attempts = 0u64;
        while produced < count && attempts < max_attempts {
            attempts += 1;
            let (u, v) = plan.sample(rng);
            if u < n_src && v < n_dst {
                out.push(u, v);
                produced += 1;
            }
        }
        while produced < count {
            out.push(rng.below(n_src), rng.below(n_dst));
            produced += 1;
        }
    }

    #[test]
    fn batched_sampling_matches_scalar_reference() {
        // square, tall, and wide spaces; rejection active on all three
        for &(n_src, n_dst, count) in
            &[(256u64, 256u64, 5_000u64), (4096, 16, 3_000), (5, 160, 2_000), (1, 1, 64)]
        {
            let g = KroneckerGen::new(
                ThetaS::rmat_default(),
                PartiteSpec::bipartite(n_src, n_dst),
                count,
            );
            let (rb, db) = KroneckerGen::bits(n_src, n_dst);
            let levels = g.levels(rb, db, &mut Pcg64::new(1));
            let spec = PartiteSpec::bipartite(n_src, n_dst);
            let mut scalar = EdgeList::new(spec);
            scalar_sample_into(&levels, n_src, n_dst, count, &mut Pcg64::new(9), &mut scalar);
            let mut batched = EdgeList::new(spec);
            KroneckerGen::sample_into(&levels, n_src, n_dst, count, &mut Pcg64::new(9), &mut batched);
            assert_eq!(scalar.src, batched.src, "{n_src}x{n_dst}");
            assert_eq!(scalar.dst, batched.dst, "{n_src}x{n_dst}");
            assert_eq!(batched.len() as u64, count);
        }
    }

    #[test]
    fn batched_uniform_fallback_matches_scalar_reference() {
        // theta mass pinned to the (1,1) quadrant: every descent lands on
        // the all-ones id, which is >= n_src in a 5-of-8 space, so the
        // attempt budget exhausts and the uniform fallback must start
        // from the same PRNG state on both paths.
        let theta = ThetaS::new(1e-12, 1e-12, 1e-12, 1.0);
        let (n_src, n_dst, count) = (5u64, 5u64, 50u64);
        let (rb, db) = KroneckerGen::bits(n_src, n_dst);
        let g = KroneckerGen::new(theta, PartiteSpec::bipartite(n_src, n_dst), count);
        let levels = g.levels(rb, db, &mut Pcg64::new(1));
        let spec = PartiteSpec::bipartite(n_src, n_dst);
        let mut scalar = EdgeList::new(spec);
        scalar_sample_into(&levels, n_src, n_dst, count, &mut Pcg64::new(3), &mut scalar);
        let mut batched = EdgeList::new(spec);
        KroneckerGen::sample_into(&levels, n_src, n_dst, count, &mut Pcg64::new(3), &mut batched);
        assert_eq!(scalar.src, batched.src);
        assert_eq!(scalar.dst, batched.dst);
        assert_eq!(batched.len() as u64, count);
    }

    #[test]
    fn decode_matches_scalar_sample_draw_for_draw() {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::bipartite(4096, 16), 1);
        let (rb, db) = KroneckerGen::bits(4096, 16);
        let levels = g.levels(rb, db, &mut Pcg64::new(1));
        let plan = KroneckerGen::plan(&levels);
        let dpa = plan.draws_per_attempt();
        assert!(dpa > 0);
        let mut a = Pcg64::new(17);
        let mut b = Pcg64::new(17);
        let mut draws = Vec::new();
        for _ in 0..200 {
            let want = plan.sample(&mut a);
            b.fill_u64(&mut draws, dpa);
            assert_eq!(plan.decode(&draws), want);
        }
    }

    #[test]
    fn marginal_levels_used_for_rectangular() {
        // tall: many sources, few destinations
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::bipartite(4096, 16), 5_000);
        let e = g.generate(1, 9).unwrap();
        assert!(e.validate().is_ok());
        assert!(e.src.iter().any(|&s| s >= 16)); // uses the full tall space
    }
}
