//! Degree-corrected stochastic block model — the GraphWorld baseline
//! (Palowitch et al. 2022) **with the fitting step the paper adds**
//! ("Note**: we improve this method and add a fitting step that fits the
//! model onto the underlying dataset", §4.1).
//!
//! Fitting: nodes are bucketed into B blocks by degree rank (a cheap,
//! deterministic community proxy that captures the degree-corrected part;
//! GraphWorld itself samples SBM parameters rather than fitting them).
//! The block-pair edge mass and per-node degree propensities are estimated
//! from the input graph; generation samples each edge by (block-pair →
//! src-node → dst-node) through alias tables.

use super::StructureGenerator;
use crate::error::{Error, Result};
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::json::Json;
use crate::util::rng::{AliasTable, BlockRng, Pcg64, RandomSource};

/// Fitted degree-corrected SBM.
#[derive(Clone, Debug)]
pub struct DcSbm {
    /// Partite sizes of the original graph.
    pub spec: PartiteSpec,
    /// Edge count of the original graph.
    pub edges: u64,
    /// Number of blocks per side.
    pub blocks: usize,
    /// Block assignment of each source node.
    src_block: Vec<u16>,
    /// Block assignment of each destination node.
    dst_block: Vec<u16>,
    /// Edge mass per (src_block, dst_block), row-major.
    block_mass: Vec<f64>,
    /// Per-block normalized degree propensities of member nodes.
    src_members: Vec<Vec<u64>>,
    src_propensity: Vec<Vec<f64>>,
    dst_members: Vec<Vec<u64>>,
    dst_propensity: Vec<Vec<f64>>,
}

fn assign_blocks(degrees: &[u32], blocks: usize) -> (Vec<u16>, Vec<Vec<u64>>, Vec<Vec<f64>>) {
    let n = degrees.len();
    let mut order: Vec<u64> = (0..n as u64).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(degrees[v as usize]));
    let mut assign = vec![0u16; n];
    let mut members: Vec<Vec<u64>> = vec![Vec::new(); blocks];
    let mut prop: Vec<Vec<f64>> = vec![Vec::new(); blocks];
    let per = n.div_ceil(blocks);
    // GraphWorld fits a *parametric* degree-corrected model, not the exact
    // degree sequence: propensities are sampled from a power law whose
    // exponent is fitted by MLE on the observed degrees (the paper's
    // "added fitting step"). We seed the draw deterministically.
    let alpha = crate::metrics::degree::power_law_alpha(degrees, 1).max(1.5);
    let alpha = if alpha.is_finite() { alpha } else { 2.5 };
    let mut rng = crate::util::rng::Pcg64::new(0x5b3d);
    for (rank, &v) in order.iter().enumerate() {
        let b = (rank / per).min(blocks - 1);
        assign[v as usize] = b as u16;
        members[b].push(v);
        // Pareto(alpha) propensity draw (plus smoothing floor)
        let u: f64 = rng.f64().max(1e-12);
        prop[b].push(u.powf(-1.0 / (alpha - 1.0)).min(1e6) + 1.0);
    }
    (assign, members, prop)
}

impl DcSbm {
    /// Fit a DC-SBM with `blocks` degree-rank blocks per side.
    pub fn fit(edges: &EdgeList, blocks: usize) -> Self {
        let blocks = blocks.max(1);
        let out_deg = edges.out_degrees();
        let in_deg = edges.in_degrees();
        let (src_block, src_members, src_propensity) = assign_blocks(&out_deg, blocks);
        let (dst_block, dst_members, dst_propensity) = assign_blocks(&in_deg, blocks);
        let mut block_mass = vec![0.0f64; blocks * blocks];
        for (s, d) in edges.iter() {
            let bs = src_block[s as usize] as usize;
            let bd = dst_block[d as usize] as usize;
            block_mass[bs * blocks + bd] += 1.0;
        }
        DcSbm {
            spec: edges.spec,
            edges: edges.len() as u64,
            blocks,
            src_block,
            dst_block,
            block_mass,
            src_members,
            src_propensity,
            dst_members,
            dst_propensity,
        }
    }

    /// Reconstruct from a `.sggm` artifact state: every fitted table
    /// (block assignments, block-pair mass, per-block members and
    /// propensities) is restored verbatim.
    pub fn from_state(state: &Json) -> Result<DcSbm> {
        let u16s = |key: &str| -> Result<Vec<u16>> {
            state
                .req_u32s(key)?
                .into_iter()
                .map(|x| {
                    u16::try_from(x).map_err(|_| {
                        Error::Data(format!("artifact: `{key}` entry {x} overflows u16"))
                    })
                })
                .collect()
        };
        let f64_row = |row: &Json, key: &str| -> Result<Vec<f64>> {
            row.as_arr()
                .ok_or_else(|| Error::Data(format!("artifact: `{key}` must hold arrays")))?
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| {
                        Error::Data(format!("artifact: `{key}` must hold numbers"))
                    })
                })
                .collect()
        };
        let u64_mat = |key: &str| -> Result<Vec<Vec<u64>>> {
            state
                .req_arr(key)?
                .iter()
                .map(|row| {
                    f64_row(row, key)?
                        .into_iter()
                        .map(|x| {
                            // strict: negative/fractional/non-finite node
                            // ids are corruption, not data to truncate
                            if x >= 0.0 && x.fract() == 0.0 && x < 9_007_199_254_740_992.0 {
                                Ok(x as u64)
                            } else {
                                Err(Error::Data(format!(
                                    "artifact: `{key}` entry {x} is not a valid node id"
                                )))
                            }
                        })
                        .collect()
                })
                .collect()
        };
        let f64_mat = |key: &str| -> Result<Vec<Vec<f64>>> {
            state.req_arr(key)?.iter().map(|row| f64_row(row, key)).collect()
        };
        let m = DcSbm {
            spec: PartiteSpec::from_json(state.req("spec")?)?,
            edges: state.req_u64("edges")?,
            blocks: state.req_usize("blocks")?,
            src_block: u16s("src_block")?,
            dst_block: u16s("dst_block")?,
            block_mass: state.req_f64s("block_mass")?,
            src_members: u64_mat("src_members")?,
            src_propensity: f64_mat("src_propensity")?,
            dst_members: u64_mat("dst_members")?,
            dst_propensity: f64_mat("dst_propensity")?,
        };
        // cross-field invariants generate_sized indexes by
        let b = m.blocks;
        if b == 0
            || m.block_mass.len() != b * b
            || m.src_members.len() != b
            || m.dst_members.len() != b
            || m.src_propensity.len() != b
            || m.dst_propensity.len() != b
            || m.src_members.iter().zip(&m.src_propensity).any(|(x, p)| x.len() != p.len())
            || m.dst_members.iter().zip(&m.dst_propensity).any(|(x, p)| x.len() != p.len())
            || m.src_block.iter().chain(&m.dst_block).any(|&x| x as usize >= b)
        {
            return Err(Error::Data(
                "artifact: sbm state shapes inconsistent with block count".into(),
            ));
        }
        Ok(m)
    }

    /// Replicate a membership list to a scaled node count: node v in the
    /// original becomes nodes {v, v + N, v + 2N, ...} in the scaled graph,
    /// inheriting v's block and propensity.
    fn scaled_members(
        members: &[Vec<u64>],
        propensity: &[Vec<f64>],
        orig_n: u64,
        new_n: u64,
    ) -> (Vec<Vec<u64>>, Vec<Vec<f64>>) {
        let copies = new_n.div_ceil(orig_n);
        let mut m2: Vec<Vec<u64>> = vec![Vec::new(); members.len()];
        let mut p2: Vec<Vec<f64>> = vec![Vec::new(); members.len()];
        for b in 0..members.len() {
            for (i, &v) in members[b].iter().enumerate() {
                for c in 0..copies {
                    let nv = v + c * orig_n;
                    if nv < new_n {
                        m2[b].push(nv);
                        p2[b].push(propensity[b][i]);
                    }
                }
            }
        }
        (m2, p2)
    }
}

impl StructureGenerator for DcSbm {
    fn name(&self) -> &'static str {
        "graphworld"
    }

    fn base(&self) -> (PartiteSpec, u64) {
        (self.spec, self.edges)
    }

    fn save_state(&self) -> Result<Json> {
        let u64_mat = |m: &[Vec<u64>]| {
            Json::Arr(m.iter().map(|row| Json::from(row.clone())).collect())
        };
        let f64_mat = |m: &[Vec<f64>]| {
            Json::Arr(m.iter().map(|row| Json::from(row.clone())).collect())
        };
        Ok(Json::obj(vec![
            ("spec", self.spec.to_json()),
            ("edges", Json::u64_exact(self.edges)),
            ("blocks", Json::from(self.blocks)),
            ("src_block", Json::from(self.src_block.clone())),
            ("dst_block", Json::from(self.dst_block.clone())),
            ("block_mass", Json::from(self.block_mass.clone())),
            ("src_members", u64_mat(&self.src_members)),
            ("src_propensity", f64_mat(&self.src_propensity)),
            ("dst_members", u64_mat(&self.dst_members)),
            ("dst_propensity", f64_mat(&self.dst_propensity)),
        ]))
    }

    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList> {
        if self.src_members.iter().all(|m| m.is_empty()) {
            return Err(Error::NotFitted("DcSbm".into()));
        }
        let spec = if self.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        };
        let (src_m, src_p) =
            Self::scaled_members(&self.src_members, &self.src_propensity, self.spec.n_src, n_src);
        let (dst_m, dst_p) =
            Self::scaled_members(&self.dst_members, &self.dst_propensity, self.spec.n_dst, n_dst);
        let block_table = AliasTable::new(&self.block_mass);
        let src_tables: Vec<AliasTable> = src_p.iter().map(|p| AliasTable::new(p)).collect();
        let dst_tables: Vec<AliasTable> = dst_p.iter().map(|p| AliasTable::new(p)).collect();
        // block-buffered draws: the three alias lookups per edge decode
        // from a prefetched batch (bit-identical stream to a bare Pcg64)
        let mut rng = BlockRng::new(Pcg64::new(seed));
        let mut out = EdgeList::with_capacity(spec, edges as usize);
        for _ in 0..edges {
            let pair = block_table.sample_with(&mut rng);
            let (bs, bd) = (pair / self.blocks, pair % self.blocks);
            if src_m[bs].is_empty() || dst_m[bd].is_empty() {
                // degenerate block after scaling; fall back to uniform
                out.push(rng.below(n_src), rng.below(n_dst));
                continue;
            }
            let s = src_m[bs][src_tables[bs].sample_with(&mut rng)];
            let d = dst_m[bd][dst_tables[bd].sample_with(&mut rng)];
            out.push(s, d);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;

    fn skewed_graph() -> EdgeList {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(512), 10_000);
        g.generate(1, 5).unwrap()
    }

    #[test]
    fn fit_partitions_all_nodes() {
        let e = skewed_graph();
        let m = DcSbm::fit(&e, 8);
        let total: usize = m.src_members.iter().map(|v| v.len()).sum();
        assert_eq!(total, 512);
        assert_eq!(m.block_mass.iter().sum::<f64>() as usize, e.len());
    }

    #[test]
    fn generates_count_and_bounds() {
        let e = skewed_graph();
        let m = DcSbm::fit(&e, 8);
        let g = m.generate(1, 3).unwrap();
        assert_eq!(g.len(), 10_000);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn preserves_head_better_than_uniform() {
        // DC-SBM with power-law propensities should produce a much
        // heavier max degree than a uniform generator would (the exact
        // sequence is *not* memorized — GraphWorld fits a parametric
        // model, see assign_blocks)
        let e = skewed_graph();
        let m = DcSbm::fit(&e, 8);
        let g = m.generate(1, 11).unwrap();
        let synth_max = *g.out_degrees().iter().max().unwrap() as f64;
        let uniform_mean = 10_000.0 / 512.0;
        assert!(synth_max > 3.0 * uniform_mean, "synth_max={synth_max}");
    }

    #[test]
    fn scaling_replicates_nodes() {
        let e = skewed_graph();
        let m = DcSbm::fit(&e, 4);
        let g = m.generate(2, 1).unwrap();
        assert_eq!(g.spec.n_src, 1024);
        assert_eq!(g.len(), 40_000);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn unfitted_generation_errors() {
        let empty = EdgeList::new(PartiteSpec::square(0));
        let m = DcSbm::fit(&empty, 4);
        assert!(m.generate_sized(10, 10, 5, 1).is_err());
    }
}
