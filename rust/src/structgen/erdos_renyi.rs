//! Erdős–Rényi random generator — the paper's "random" baseline
//! (G(n, E) variant: E edges sampled uniformly over the n×m cells).

use super::StructureGenerator;
use crate::error::{Error, Result};
use crate::graph::{EdgeList, PartiteSpec};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::threadpool::{default_threads, par_map};

/// Uniform random structure generator fitted only to (N, M, E).
#[derive(Clone, Copy, Debug)]
pub struct ErdosRenyi {
    /// Partite sizes of the original graph (scale 1).
    pub spec: PartiteSpec,
    /// Edge count of the original graph.
    pub edges: u64,
}

impl ErdosRenyi {
    /// "Fit" to an input graph: record its sizes.
    pub fn fit(edges: &EdgeList) -> Self {
        ErdosRenyi { spec: edges.spec, edges: edges.len() as u64 }
    }

    /// Reconstruct from a `.sggm` artifact state.
    pub fn from_state(state: &Json) -> Result<ErdosRenyi> {
        Ok(ErdosRenyi {
            spec: PartiteSpec::from_json(state.req("spec")?)?,
            edges: state.req_u64("edges")?,
        })
    }
}

impl StructureGenerator for ErdosRenyi {
    fn name(&self) -> &'static str {
        "random"
    }

    fn base(&self) -> (PartiteSpec, u64) {
        (self.spec, self.edges)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("spec", self.spec.to_json()),
            ("edges", Json::u64_exact(self.edges)),
        ]))
    }

    fn generate_sized(&self, n_src: u64, n_dst: u64, edges: u64, seed: u64) -> Result<EdgeList> {
        if n_src == 0 || n_dst == 0 {
            return Err(Error::Config("empty partite".into()));
        }
        let spec = if self.spec.square {
            PartiteSpec::square(n_src)
        } else {
            PartiteSpec::bipartite(n_src, n_dst)
        };
        // parallel uniform sampling with per-shard streams
        let threads = default_threads();
        let per = edges / threads as u64;
        let rem = edges % threads as u64;
        let shards = par_map(threads, threads, |t| {
            let mut rng = Pcg64::with_stream(seed, t as u64 + 1);
            let count = per + if (t as u64) < rem { 1 } else { 0 };
            let mut src = Vec::with_capacity(count as usize);
            let mut dst = Vec::with_capacity(count as usize);
            for _ in 0..count {
                src.push(rng.below(n_src));
                dst.push(rng.below(n_dst));
            }
            (src, dst)
        });
        let mut out = EdgeList::with_capacity(spec, edges as usize);
        for (src, dst) in shards {
            out.src.extend_from_slice(&src);
            out.dst.extend_from_slice(&dst);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_count_and_bounds() {
        let g = ErdosRenyi { spec: PartiteSpec::bipartite(100, 30), edges: 5_000 };
        let e = g.generate(1, 1).unwrap();
        assert_eq!(e.len(), 5_000);
        assert!(e.validate().is_ok());
    }

    #[test]
    fn approximately_uniform_degrees() {
        let g = ErdosRenyi { spec: PartiteSpec::square(100), edges: 100_000 };
        let e = g.generate(1, 3).unwrap();
        let deg = e.out_degrees();
        let mean = 1_000.0;
        let max = *deg.iter().max().unwrap() as f64;
        let min = *deg.iter().min().unwrap() as f64;
        // Binomial(1e5, 1/100): std≈31; 6 sigma bounds
        assert!(max < mean + 6.0 * 31.5, "max={max}");
        assert!(min > mean - 6.0 * 31.5, "min={min}");
    }

    #[test]
    fn fit_records_shape() {
        let e = EdgeList::from_pairs(PartiteSpec::bipartite(10, 20), &[(0, 0), (1, 1)]);
        let g = ErdosRenyi::fit(&e);
        assert_eq!(g.spec, e.spec);
        assert_eq!(g.edges, 2);
    }

    #[test]
    fn deterministic() {
        let g = ErdosRenyi { spec: PartiteSpec::square(64), edges: 1000 };
        let a = g.generate(1, 5).unwrap();
        let b = g.generate(1, 5).unwrap();
        assert_eq!(a.src, b.src);
    }
}
