//! node2vec embeddings (Grover & Leskovec 2016) — the alternative
//! structural feature set compared in paper Table 9.
//!
//! Biased second-order random walks (return parameter p, in-out q) over
//! the undirected CSR, followed by skip-gram with negative sampling
//! trained by SGD. Scaled-down defaults (dim 16) since the aligner only
//! consumes the embeddings as GBT input features.

use crate::graph::Csr;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::Result;

/// node2vec hyper-parameters.
#[derive(Clone, Debug)]
pub struct Node2VecConfig {
    /// Embedding dimension.
    pub dim: usize,
    /// Walks per node.
    pub walks_per_node: usize,
    /// Walk length.
    pub walk_length: usize,
    /// Skip-gram window.
    pub window: usize,
    /// Negative samples per positive.
    pub negatives: usize,
    /// SGD epochs over the walk corpus.
    pub epochs: usize,
    /// Return parameter p (likelihood of revisiting the previous node).
    pub p: f64,
    /// In-out parameter q (BFS- vs DFS-like exploration).
    pub q: f64,
    /// Learning rate.
    pub lr: f32,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Node2VecConfig {
    fn default() -> Self {
        Node2VecConfig {
            dim: 16,
            walks_per_node: 4,
            walk_length: 20,
            window: 4,
            negatives: 3,
            epochs: 2,
            p: 1.0,
            q: 1.0,
            lr: 0.025,
            seed: 0x6e32_7665, // "n2ve"
        }
    }
}

impl Node2VecConfig {
    /// Serialize for a `.sggm` model artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::from(self.dim)),
            ("walks_per_node", Json::from(self.walks_per_node)),
            ("walk_length", Json::from(self.walk_length)),
            ("window", Json::from(self.window)),
            ("negatives", Json::from(self.negatives)),
            ("epochs", Json::from(self.epochs)),
            ("p", Json::from(self.p)),
            ("q", Json::from(self.q)),
            ("lr", Json::from(self.lr)),
            ("seed", Json::u64_exact(self.seed)),
        ])
    }

    /// Inverse of [`Node2VecConfig::to_json`].
    pub fn from_json(v: &Json) -> Result<Node2VecConfig> {
        Ok(Node2VecConfig {
            dim: v.req_usize("dim")?,
            walks_per_node: v.req_usize("walks_per_node")?,
            walk_length: v.req_usize("walk_length")?,
            window: v.req_usize("window")?,
            negatives: v.req_usize("negatives")?,
            epochs: v.req_usize("epochs")?,
            p: v.req_f64("p")?,
            q: v.req_f64("q")?,
            lr: v.req_f64("lr")? as f32,
            seed: v.req_u64("seed")?,
        })
    }
}

/// One biased walk from `start`.
fn walk(csr: &Csr, start: u64, cfg: &Node2VecConfig, rng: &mut Pcg64) -> Vec<u64> {
    let mut path = Vec::with_capacity(cfg.walk_length);
    path.push(start);
    let mut prev: Option<u64> = None;
    let mut cur = start;
    for _ in 1..cfg.walk_length {
        let nbrs = csr.neighbors(cur);
        if nbrs.is_empty() {
            break;
        }
        // biased choice: weight 1/p to return, 1 for common neighbors of
        // prev, 1/q otherwise (rejection sampling over uniform proposals)
        let next = if let Some(pv) = prev {
            let max_w = (1.0 / cfg.p).max(1.0).max(1.0 / cfg.q);
            let mut chosen = None;
            for _ in 0..16 {
                let cand = nbrs[rng.below_usize(nbrs.len())];
                let w = if cand == pv {
                    1.0 / cfg.p
                } else if csr.has_edge(cand, pv) {
                    1.0
                } else {
                    1.0 / cfg.q
                };
                if rng.f64() < w / max_w {
                    chosen = Some(cand);
                    break;
                }
            }
            chosen.unwrap_or(nbrs[rng.below_usize(nbrs.len())])
        } else {
            nbrs[rng.below_usize(nbrs.len())]
        };
        path.push(next);
        prev = Some(cur);
        cur = next;
    }
    path
}

/// Train node2vec embeddings; returns a row-major `n_nodes × dim` f32
/// matrix.
pub fn node2vec_embeddings(csr: &Csr, cfg: &Node2VecConfig) -> Vec<f32> {
    let n = csr.n_nodes as usize;
    let dim = cfg.dim;
    let mut rng = Pcg64::new(cfg.seed);
    // init small random
    let mut emb: Vec<f32> = (0..n * dim).map(|_| (rng.f32() - 0.5) / dim as f32).collect();
    let mut ctx: Vec<f32> = vec![0.0; n * dim];
    if n == 0 {
        return emb;
    }

    // degree-weighted negative table (unigram^0.75)
    let weights: Vec<f64> = (0..n)
        .map(|v| (csr.degree(v as u64) as f64 + 1.0).powf(0.75))
        .collect();
    let neg_table = crate::util::rng::AliasTable::new(&weights);

    for _ in 0..cfg.epochs {
        for start in 0..n as u64 {
            for _ in 0..cfg.walks_per_node {
                let path = walk(csr, start, cfg, &mut rng);
                for (i, &center) in path.iter().enumerate() {
                    let lo = i.saturating_sub(cfg.window);
                    let hi = (i + cfg.window + 1).min(path.len());
                    for &context in &path[lo..hi] {
                        if context == center {
                            continue;
                        }
                        sgns_update(
                            &mut emb,
                            &mut ctx,
                            center as usize,
                            context as usize,
                            true,
                            dim,
                            cfg.lr,
                        );
                        for _ in 0..cfg.negatives {
                            let neg = neg_table.sample(&mut rng);
                            if neg as u64 != context {
                                sgns_update(&mut emb, &mut ctx, center as usize, neg, false, dim, cfg.lr);
                            }
                        }
                    }
                }
            }
        }
    }
    emb
}

#[inline]
fn sgns_update(
    emb: &mut [f32],
    ctx: &mut [f32],
    center: usize,
    other: usize,
    positive: bool,
    dim: usize,
    lr: f32,
) {
    let (e0, c0) = (center * dim, other * dim);
    let mut dot = 0.0f32;
    for d in 0..dim {
        dot += emb[e0 + d] * ctx[c0 + d];
    }
    let label = if positive { 1.0 } else { 0.0 };
    let sigma = 1.0 / (1.0 + (-dot).exp());
    let g = lr * (label - sigma);
    for d in 0..dim {
        let e = emb[e0 + d];
        let c = ctx[c0 + d];
        emb[e0 + d] += g * c;
        ctx[c0 + d] += g * e;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeList, PartiteSpec};

    fn two_cliques() -> Csr {
        // two 5-cliques joined by one edge
        let mut pairs = Vec::new();
        for a in 0..5u64 {
            for b in (a + 1)..5 {
                pairs.push((a, b));
                pairs.push((a + 5, b + 5));
            }
        }
        pairs.push((0, 5));
        Csr::undirected(&EdgeList::from_pairs(PartiteSpec::square(10), &pairs))
    }

    fn cosine(a: &[f32], b: &[f32]) -> f32 {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    }

    #[test]
    fn walks_stay_on_graph() {
        let csr = two_cliques();
        let cfg = Node2VecConfig::default();
        let mut rng = Pcg64::new(1);
        let p = walk(&csr, 0, &cfg, &mut rng);
        assert!(p.len() > 1);
        for w in p.windows(2) {
            assert!(csr.has_edge(w[0], w[1]), "{w:?} not an edge");
        }
    }

    #[test]
    fn community_structure_in_embeddings() {
        let csr = two_cliques();
        let cfg = Node2VecConfig { epochs: 4, walks_per_node: 8, ..Default::default() };
        let emb = node2vec_embeddings(&csr, &cfg);
        let dim = cfg.dim;
        // avg intra-clique cosine should exceed inter-clique cosine
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut ni = 0;
        let mut nx = 0;
        for a in 0..10usize {
            for b in (a + 1)..10 {
                let c = cosine(&emb[a * dim..(a + 1) * dim], &emb[b * dim..(b + 1) * dim]);
                if (a < 5) == (b < 5) {
                    intra += c;
                    ni += 1;
                } else {
                    inter += c;
                    nx += 1;
                }
            }
        }
        let intra = intra / ni as f32;
        let inter = inter / nx as f32;
        assert!(intra > inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn embedding_shape() {
        let csr = two_cliques();
        let cfg = Node2VecConfig { dim: 8, epochs: 1, ..Default::default() };
        let emb = node2vec_embeddings(&csr, &cfg);
        assert_eq!(emb.len(), 10 * 8);
        assert!(emb.iter().any(|&x| x != 0.0));
    }
}
