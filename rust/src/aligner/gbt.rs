//! Gradient-boosted regression trees — the from-scratch stand-in for the
//! RAPIDS XGBoost models the paper's aligner uses (§3.4, §12).
//!
//! Histogram-based: each feature is quantized into ≤64 bins at fit time;
//! split finding scans bin histograms of (gradient, hessian) sums. Squared
//! loss (gradient = residual, hessian = 1), depth-limited trees, shrinkage
//! (learning rate), and L2 leaf regularization `alpha` (the paper sets
//! alpha = 10, lr = 0.1, max_depth = 5, 100 estimators).
//!
//! Categorical targets are handled one-vs-rest by
//! [`GbtClassifier`], matching "a separate model per feature" in App. 7.

use crate::util::json::Json;
use crate::util::threadpool::{default_threads, par_map};
use crate::{Error, Result};

/// GBT hyper-parameters (defaults from paper §12).
#[derive(Clone, Debug)]
pub struct GbtConfig {
    /// Boosting rounds.
    pub n_trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Shrinkage applied to each tree's contribution.
    pub learning_rate: f64,
    /// L2 regularization on leaf values (XGBoost's lambda; paper α=10).
    pub l2: f64,
    /// Minimum samples to split a node.
    pub min_samples_split: usize,
    /// Histogram bins per feature.
    pub n_bins: usize,
}

impl Default for GbtConfig {
    fn default() -> Self {
        GbtConfig {
            n_trees: 100,
            max_depth: 5,
            learning_rate: 0.1,
            l2: 10.0,
            min_samples_split: 8,
            n_bins: 64,
        }
    }
}

impl GbtConfig {
    /// Cheaper settings used inside large experiment sweeps.
    pub fn fast() -> Self {
        GbtConfig { n_trees: 30, max_depth: 4, ..Default::default() }
    }
}

#[derive(Clone, Debug)]
struct Node {
    /// Split feature (bin threshold applies to binned values).
    feature: u16,
    /// Go left if bin <= threshold.
    threshold: u8,
    left: u32,
    right: u32,
    /// Leaf value (valid when is_leaf).
    value: f64,
    is_leaf: bool,
}

#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    /// Parallel-array artifact encoding (one entry per node).
    fn to_json(&self) -> Json {
        fn col<T: Into<Json>>(nodes: &[Node], f: impl Fn(&Node) -> T) -> Json {
            Json::Arr(nodes.iter().map(|n| f(n).into()).collect())
        }
        Json::obj(vec![
            ("feature", col(&self.nodes, |n| n.feature)),
            ("threshold", col(&self.nodes, |n| n.threshold as u32)),
            ("left", col(&self.nodes, |n| n.left)),
            ("right", col(&self.nodes, |n| n.right)),
            ("value", col(&self.nodes, |n| n.value)),
            ("leaf", col(&self.nodes, |n| n.is_leaf)),
        ])
    }

    fn from_json(v: &Json) -> Result<Tree> {
        let feature = v.req_u32s("feature")?;
        let threshold = v.req_u32s("threshold")?;
        let left = v.req_u32s("left")?;
        let right = v.req_u32s("right")?;
        let value = v.req_f64s("value")?;
        let leaf = v
            .req_arr("leaf")?
            .iter()
            .map(|b| {
                b.as_bool()
                    .ok_or_else(|| Error::Data("artifact: tree `leaf` must hold bools".into()))
            })
            .collect::<Result<Vec<bool>>>()?;
        let n = feature.len();
        if [threshold.len(), left.len(), right.len(), value.len(), leaf.len()]
            .iter()
            .any(|&l| l != n)
            || n == 0
        {
            return Err(Error::Data("artifact: tree node arrays empty or mismatched".into()));
        }
        let nodes = (0..n)
            .map(|i| {
                // children must point strictly forward: `grow` always
                // pushes children after their parent, and enforcing it
                // here makes `predict_binned`'s descent provably finite
                // even on corrupted or adversarial artifacts
                if !leaf[i]
                    && (left[i] as usize >= n
                        || right[i] as usize >= n
                        || left[i] as usize <= i
                        || right[i] as usize <= i)
                {
                    return Err(Error::Data(format!(
                        "artifact: tree node {i} has non-forward child links"
                    )));
                }
                if feature[i] > u16::MAX as u32 || threshold[i] > u8::MAX as u32 {
                    return Err(Error::Data(format!(
                        "artifact: tree node {i} feature/threshold out of range"
                    )));
                }
                Ok(Node {
                    feature: feature[i] as u16,
                    threshold: threshold[i] as u8,
                    left: left[i],
                    right: right[i],
                    value: value[i],
                    is_leaf: leaf[i],
                })
            })
            .collect::<Result<Vec<Node>>>()?;
        Ok(Tree { nodes })
    }

    fn predict_binned(&self, row: &[u8]) -> f64 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.is_leaf {
                return n.value;
            }
            i = if row[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }
}

/// Per-feature bin edges learned on the training data.
#[derive(Clone, Debug)]
pub struct Binner {
    /// edges[f] sorted ascending; bin = #edges < x, clamped to n_bins-1.
    edges: Vec<Vec<f64>>,
}

impl Binner {
    /// Quantile binning on column-major access into a row-major matrix.
    pub fn fit(x: &[f64], n_rows: usize, n_cols: usize, n_bins: usize) -> Binner {
        let mut edges = Vec::with_capacity(n_cols);
        for f in 0..n_cols {
            let mut col: Vec<f64> = (0..n_rows).map(|r| x[r * n_cols + f]).collect();
            col.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            col.dedup();
            let mut e = Vec::with_capacity(n_bins - 1);
            if col.len() > 1 {
                for b in 1..n_bins.min(col.len()) {
                    let idx = b * (col.len() - 1) / n_bins.min(col.len());
                    let v = col[idx.min(col.len() - 1)];
                    if e.last().map(|&l| v > l).unwrap_or(true) {
                        e.push(v);
                    }
                }
            }
            edges.push(e);
        }
        Binner { edges }
    }

    /// Bin a full row-major matrix.
    pub fn transform(&self, x: &[f64], n_rows: usize, n_cols: usize) -> Vec<u8> {
        let mut out = vec![0u8; n_rows * n_cols];
        for r in 0..n_rows {
            for f in 0..n_cols {
                let v = x[r * n_cols + f];
                let e = &self.edges[f];
                // binary search: number of edges <= v
                let bin = e.partition_point(|&t| t < v);
                out[r * n_cols + f] = bin.min(255) as u8;
            }
        }
        out
    }

    fn n_cols(&self) -> usize {
        self.edges.len()
    }

    /// Artifact encoding: the per-feature bin-edge arrays.
    fn to_json(&self) -> Json {
        Json::obj(vec![(
            "edges",
            Json::Arr(self.edges.iter().map(|e| Json::from(e.clone())).collect()),
        )])
    }

    fn from_json(v: &Json) -> Result<Binner> {
        let edges = v
            .req_arr("edges")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| Error::Data("artifact: binner `edges` must hold arrays".into()))?
                    .iter()
                    .map(|x| {
                        x.as_f64().ok_or_else(|| {
                            Error::Data("artifact: binner edges must be numbers".into())
                        })
                    })
                    .collect()
            })
            .collect::<Result<Vec<Vec<f64>>>>()?;
        Ok(Binner { edges })
    }
}

/// Gradient-boosted regressor with squared loss.
#[derive(Clone, Debug)]
pub struct GbtRegressor {
    binner: Binner,
    trees: Vec<Tree>,
    base: f64,
    lr: f64,
    n_cols: usize,
}

impl GbtRegressor {
    /// Fit on a row-major `n_rows × n_cols` matrix and target vector.
    pub fn fit(x: &[f64], y: &[f64], n_cols: usize, cfg: &GbtConfig) -> GbtRegressor {
        let n_rows = y.len();
        assert_eq!(x.len(), n_rows * n_cols, "x shape mismatch");
        let binner = Binner::fit(x, n_rows, n_cols, cfg.n_bins);
        let xb = binner.transform(x, n_rows, n_cols);
        let base = crate::util::stats::mean(y);
        let mut pred = vec![base; n_rows];
        let mut trees = Vec::with_capacity(cfg.n_trees);
        let mut grad = vec![0.0f64; n_rows];
        for _ in 0..cfg.n_trees {
            for i in 0..n_rows {
                grad[i] = y[i] - pred[i]; // negative gradient of squared loss
            }
            let tree = build_tree(&xb, &grad, n_rows, n_cols, cfg);
            for i in 0..n_rows {
                pred[i] += cfg.learning_rate * tree.predict_binned(&xb[i * n_cols..(i + 1) * n_cols]);
            }
            trees.push(tree);
        }
        GbtRegressor { binner, trees, base, lr: cfg.learning_rate, n_cols }
    }

    /// Predict a single row.
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        let binned = self.binner.transform(row, 1, self.n_cols);
        self.base
            + self.lr
                * self
                    .trees
                    .iter()
                    .map(|t| t.predict_binned(&binned))
                    .sum::<f64>()
    }

    /// Serialize the fitted model for a `.sggm` artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("binner", self.binner.to_json()),
            ("trees", Json::Arr(self.trees.iter().map(Tree::to_json).collect())),
            ("base", Json::from(self.base)),
            ("lr", Json::from(self.lr)),
            ("n_cols", Json::from(self.n_cols)),
        ])
    }

    /// Inverse of [`GbtRegressor::to_json`] — predictions of the loaded
    /// model are bit-identical to the fitted one.
    pub fn from_json(v: &Json) -> Result<GbtRegressor> {
        let binner = Binner::from_json(v.req("binner")?)?;
        let n_cols = v.req_usize("n_cols")?;
        if binner.n_cols() != n_cols {
            return Err(Error::Data(format!(
                "artifact: gbt binner has {} feature columns, expected {n_cols}",
                binner.n_cols()
            )));
        }
        let trees = v
            .req_arr("trees")?
            .iter()
            .map(Tree::from_json)
            .collect::<Result<Vec<Tree>>>()?;
        for t in &trees {
            if let Some(node) = t.nodes.iter().find(|n| !n.is_leaf && n.feature as usize >= n_cols)
            {
                return Err(Error::Data(format!(
                    "artifact: tree split on feature {} but model has {n_cols} columns",
                    node.feature
                )));
            }
        }
        Ok(GbtRegressor {
            binner,
            trees,
            base: v.req_f64("base")?,
            lr: v.req_f64("lr")?,
            n_cols,
        })
    }

    /// Predict many rows (row-major), parallelized.
    pub fn predict(&self, x: &[f64], n_rows: usize) -> Vec<f64> {
        let xb = self.binner.transform(x, n_rows, self.n_cols);
        let threads = default_threads();
        let chunk = n_rows.div_ceil(threads.max(1)).max(1);
        let n_chunks = n_rows.div_ceil(chunk);
        let parts = par_map(n_chunks, threads, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n_rows);
            let mut out = Vec::with_capacity(hi - lo);
            for r in lo..hi {
                let row = &xb[r * self.n_cols..(r + 1) * self.n_cols];
                let mut v = self.base;
                for t in &self.trees {
                    v += self.lr * t.predict_binned(row);
                }
                out.push(v);
            }
            out
        });
        parts.concat()
    }
}

/// One-vs-rest GBT classifier for categorical targets.
#[derive(Clone, Debug)]
pub struct GbtClassifier {
    models: Vec<GbtRegressor>,
}

impl GbtClassifier {
    /// Fit `cardinality` one-vs-rest regressors.
    pub fn fit(x: &[f64], y: &[u32], n_cols: usize, cardinality: u32, cfg: &GbtConfig) -> Self {
        let models = (0..cardinality)
            .map(|c| {
                let target: Vec<f64> =
                    y.iter().map(|&v| if v == c { 1.0 } else { 0.0 }).collect();
                GbtRegressor::fit(x, &target, n_cols, cfg)
            })
            .collect();
        GbtClassifier { models }
    }

    /// Serialize the one-vs-rest ensemble for a `.sggm` artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::Arr(self.models.iter().map(GbtRegressor::to_json).collect()),
        )])
    }

    /// Inverse of [`GbtClassifier::to_json`].
    pub fn from_json(v: &Json) -> Result<GbtClassifier> {
        Ok(GbtClassifier {
            models: v
                .req_arr("models")?
                .iter()
                .map(GbtRegressor::from_json)
                .collect::<Result<Vec<GbtRegressor>>>()?,
        })
    }

    /// Per-class scores for many rows: row-major `n_rows × cardinality`.
    pub fn predict_scores(&self, x: &[f64], n_rows: usize) -> Vec<f64> {
        let k = self.models.len();
        let mut out = vec![0.0f64; n_rows * k];
        for (c, m) in self.models.iter().enumerate() {
            let scores = m.predict(x, n_rows);
            for r in 0..n_rows {
                out[r * k + c] = scores[r];
            }
        }
        out
    }

    /// Argmax class per row.
    pub fn predict(&self, x: &[f64], n_rows: usize) -> Vec<u32> {
        let k = self.models.len();
        let scores = self.predict_scores(x, n_rows);
        (0..n_rows)
            .map(|r| {
                let row = &scores[r * k..(r + 1) * k];
                let mut best = 0u32;
                let mut bv = f64::NEG_INFINITY;
                for (c, &s) in row.iter().enumerate() {
                    if s > bv {
                        bv = s;
                        best = c as u32;
                    }
                }
                best
            })
            .collect()
    }
}

/// Grow one tree on binned features against the gradient (residual).
fn build_tree(xb: &[u8], grad: &[f64], n_rows: usize, n_cols: usize, cfg: &GbtConfig) -> Tree {
    let mut nodes: Vec<Node> = Vec::new();
    let rows: Vec<u32> = (0..n_rows as u32).collect();
    grow(&mut nodes, xb, grad, rows, n_cols, 0, cfg);
    Tree { nodes }
}

fn leaf_value(grad_sum: f64, count: f64, l2: f64) -> f64 {
    grad_sum / (count + l2)
}

fn grow(
    nodes: &mut Vec<Node>,
    xb: &[u8],
    grad: &[f64],
    rows: Vec<u32>,
    n_cols: usize,
    depth: usize,
    cfg: &GbtConfig,
) -> u32 {
    let idx = nodes.len() as u32;
    let g_total: f64 = rows.iter().map(|&r| grad[r as usize]).sum();
    let n = rows.len() as f64;
    nodes.push(Node {
        feature: 0,
        threshold: 0,
        left: 0,
        right: 0,
        value: leaf_value(g_total, n, cfg.l2),
        is_leaf: true,
    });
    if depth >= cfg.max_depth || rows.len() < cfg.min_samples_split {
        return idx;
    }

    // histogram split search over all features
    let mut best_gain = 1e-12;
    let mut best: Option<(u16, u8)> = None;
    let parent_score = g_total * g_total / (n + cfg.l2);
    let mut hist_g = vec![0.0f64; cfg.n_bins];
    let mut hist_n = vec![0.0f64; cfg.n_bins];
    for f in 0..n_cols {
        hist_g.iter_mut().for_each(|v| *v = 0.0);
        hist_n.iter_mut().for_each(|v| *v = 0.0);
        for &r in &rows {
            let b = xb[r as usize * n_cols + f] as usize;
            let b = b.min(cfg.n_bins - 1);
            hist_g[b] += grad[r as usize];
            hist_n[b] += 1.0;
        }
        let mut gl = 0.0;
        let mut nl = 0.0;
        for t in 0..cfg.n_bins - 1 {
            gl += hist_g[t];
            nl += hist_n[t];
            let nr = n - nl;
            if nl < 1.0 || nr < 1.0 {
                continue;
            }
            let gr = g_total - gl;
            let gain = gl * gl / (nl + cfg.l2) + gr * gr / (nr + cfg.l2) - parent_score;
            if gain > best_gain {
                best_gain = gain;
                best = Some((f as u16, t as u8));
            }
        }
    }

    if let Some((f, t)) = best {
        let (mut lrows, mut rrows) = (Vec::new(), Vec::new());
        for &r in &rows {
            if xb[r as usize * n_cols + f as usize] <= t {
                lrows.push(r);
            } else {
                rrows.push(r);
            }
        }
        if lrows.is_empty() || rrows.is_empty() {
            return idx;
        }
        let left = grow(nodes, xb, grad, lrows, n_cols, depth + 1, cfg);
        let right = grow(nodes, xb, grad, rrows, n_cols, depth + 1, cfg);
        let node = &mut nodes[idx as usize];
        node.feature = f;
        node.threshold = t;
        node.left = left;
        node.right = right;
        node.is_leaf = false;
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn make_xy(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        // y = 3*x0 - 2*x1 + noise, x2 irrelevant
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::with_capacity(n * 3);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.normal();
            let b = rng.normal();
            let c = rng.normal();
            x.extend_from_slice(&[a, b, c]);
            y.push(3.0 * a - 2.0 * b + 0.1 * rng.normal());
        }
        (x, y)
    }

    #[test]
    fn learns_linear_function() {
        let (x, y) = make_xy(2000, 1);
        let cfg = GbtConfig { n_trees: 60, ..Default::default() };
        let m = GbtRegressor::fit(&x, &y, 3, &cfg);
        let pred = m.predict(&x, 2000);
        let mse: f64 =
            pred.iter().zip(&y).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / y.len() as f64;
        let var = crate::util::stats::variance(&y);
        assert!(mse < 0.2 * var, "mse={mse} var={var}");
    }

    #[test]
    fn generalizes_to_test_set() {
        let (xtr, ytr) = make_xy(3000, 2);
        let (xte, yte) = make_xy(500, 3);
        let m = GbtRegressor::fit(&xtr, &ytr, 3, &GbtConfig::fast());
        let pred = m.predict(&xte, 500);
        let mse: f64 =
            pred.iter().zip(&yte).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / 500.0;
        let var = crate::util::stats::variance(&yte);
        assert!(mse < 0.4 * var, "mse={mse} var={var}");
    }

    #[test]
    fn predict_row_matches_batch() {
        let (x, y) = make_xy(500, 4);
        let m = GbtRegressor::fit(&x, &y, 3, &GbtConfig::fast());
        let batch = m.predict(&x, 500);
        for r in [0usize, 13, 499] {
            let single = m.predict_row(&x[r * 3..(r + 1) * 3]);
            assert!((single - batch[r]).abs() < 1e-9);
        }
    }

    #[test]
    fn constant_target_predicts_constant() {
        let x: Vec<f64> = (0..300).map(|i| i as f64).collect();
        let y = vec![5.0; 100];
        let m = GbtRegressor::fit(&x, &y, 3, &GbtConfig::fast());
        let p = m.predict_row(&[1.0, 2.0, 3.0]);
        assert!((p - 5.0).abs() < 0.2, "p={p}");
    }

    #[test]
    fn classifier_separates_classes() {
        let mut rng = Pcg64::new(5);
        let n = 1200;
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(3) as u32;
            let cx = [0.0, 4.0, -4.0][cls as usize] + rng.normal() * 0.5;
            let cy = [3.0, -3.0, 0.0][cls as usize] + rng.normal() * 0.5;
            x.extend_from_slice(&[cx, cy]);
            y.push(cls);
        }
        let m = GbtClassifier::fit(&x, &y, 2, 3, &GbtConfig::fast());
        let pred = m.predict(&x, n);
        let acc = pred.iter().zip(&y).filter(|(a, b)| a == b).count() as f64 / n as f64;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn depth_limit_respected() {
        let (x, y) = make_xy(500, 7);
        let cfg = GbtConfig { n_trees: 1, max_depth: 2, ..Default::default() };
        let m = GbtRegressor::fit(&x, &y, 3, &cfg);
        // depth-2 tree has at most 7 nodes
        assert!(m.trees[0].nodes.len() <= 7);
    }

    #[test]
    fn json_roundtrip_predicts_identically() {
        let (x, y) = make_xy(500, 11);
        let m = GbtRegressor::fit(&x, &y, 3, &GbtConfig::fast());
        // through the serialized *text*, like a real artifact on disk
        let text = m.to_json().to_string();
        let re = GbtRegressor::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m.predict(&x, 500), re.predict(&x, 500));
    }

    #[test]
    fn binner_monotone() {
        let x: Vec<f64> = vec![1.0, 5.0, 2.0, 9.0, 3.0, 7.0];
        let b = Binner::fit(&x, 6, 1, 4);
        let t = b.transform(&x, 6, 1);
        // larger values never get smaller bins
        let mut pairs: Vec<(f64, u8)> = x.iter().copied().zip(t.iter().copied()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in pairs.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }
}
