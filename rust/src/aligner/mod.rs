//! The aligner (paper §3.4 + Appendix 7): maps generated feature rows onto
//! the generated structure so that structure↔feature correlations of the
//! original graph are preserved.
//!
//! Training: extract per-node structural features F_S (degree, PageRank,
//! Katz centrality, clustering coefficient — [`structfeat`]; optionally
//! node2vec embeddings — [`node2vec`]) from the *original* graph, then
//! train one gradient-boosted-tree regressor/classifier per feature column
//! ([`gbt`], the from-scratch XGBoost stand-in) to predict the column from
//! (F_S(src), F_S(dst)) for edge features or F_S(v) for node features.
//!
//! Generation: compute the same structural features on the *generated*
//! graph, predict each edge/node's expected features, and rank-assign the
//! generated feature rows by similarity (eq. 17–19) — [`ranking`].

pub mod gbt;
pub mod node2vec;
pub mod ranking;
pub mod structfeat;

use crate::featgen::FeatureTable;
use crate::graph::EdgeList;
use crate::util::rng::Pcg64;
use crate::Result;

pub use ranking::LearnedAligner;
pub use structfeat::{StructFeatConfig, StructFeatures};

/// Which aligner a pipeline uses (ablation axis of Table 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlignKind {
    /// Learned XGBoost-style aligner ("xgboost").
    Learned,
    /// Random assignment ("random").
    Random,
}

impl std::str::FromStr for AlignKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "xgboost" | "learned" | "gbt" => Ok(AlignKind::Learned),
            "random" => Ok(AlignKind::Random),
            other => Err(format!("unknown aligner `{other}`")),
        }
    }
}

/// Randomly permute generated rows onto the structure — the trivial
/// aligner of §3.4 and the "random" arm of Table 6.
pub fn random_alignment(
    generated: &FeatureTable,
    n_targets: usize,
    seed: u64,
) -> Result<FeatureTable> {
    let n = generated.n_rows();
    let mut rng = Pcg64::new(seed);
    let perm: Vec<usize> = (0..n_targets)
        .map(|i| if n == 0 { 0 } else if i < n { i } else { rng.below_usize(n) })
        .collect();
    let mut shuffled = perm;
    rng.shuffle(&mut shuffled);
    Ok(generated.gather(&shuffled))
}

/// Convenience: structural features with the paper's default set
/// (degrees, PageRank, Katz — Table 9's best combination).
pub fn default_struct_features(edges: &EdgeList) -> StructFeatures {
    structfeat::compute(edges, &StructFeatConfig::default())
}
