//! The aligner (paper §3.4 + Appendix 7): maps generated feature rows onto
//! the generated structure so that structure↔feature correlations of the
//! original graph are preserved.
//!
//! Training: extract per-node structural features F_S (degree, PageRank,
//! Katz centrality, clustering coefficient — [`structfeat`]; optionally
//! node2vec embeddings — [`node2vec`]) from the *original* graph, then
//! train one gradient-boosted-tree regressor/classifier per feature column
//! ([`gbt`], the from-scratch XGBoost stand-in) to predict the column from
//! (F_S(src), F_S(dst)) for edge features or F_S(v) for node features.
//!
//! Generation: compute the same structural features on the *generated*
//! graph, predict each edge/node's expected features, and rank-assign the
//! generated feature rows by similarity (eq. 17–19) — [`ranking`].
//!
//! Both the learned and the trivial random assignment implement the
//! [`Aligner`] trait; backends register in the pipeline's aligner
//! [`Registry`] via [`register_builtins`].

pub mod gbt;
pub mod node2vec;
pub mod ranking;
pub mod structfeat;

use crate::featgen::FeatureTable;
use crate::graph::EdgeList;
use crate::pipeline::registry::Registry;
use crate::pipeline::spec::Params;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::Result;
use gbt::GbtConfig;

pub use ranking::{LearnedAligner, Target};
pub use structfeat::{StructFeatConfig, StructFeatures};

/// A fitted aligner: assigns rows from a generated feature pool onto a
/// generated structure (one row per edge, or per source node for the
/// node-feature leg).
pub trait Aligner {
    /// Name used in experiment tables ("xgboost" / "random").
    fn name(&self) -> &'static str;

    /// Assign `pool` rows onto `structure`.
    fn align(&self, structure: &EdgeList, pool: &FeatureTable, seed: u64)
        -> Result<FeatureTable>;

    /// Serialize the fitted state for a `.sggm` model artifact. The
    /// state loader registered under [`Self::name`] must reconstruct an
    /// aligner whose assignments are bit-identical for every seed.
    fn save_state(&self) -> Result<Json>;
}

impl Aligner for LearnedAligner {
    fn name(&self) -> &'static str {
        "xgboost"
    }

    fn align(
        &self,
        structure: &EdgeList,
        pool: &FeatureTable,
        seed: u64,
    ) -> Result<FeatureTable> {
        LearnedAligner::align(self, structure, pool, seed)
    }

    fn save_state(&self) -> Result<Json> {
        LearnedAligner::save_state(self)
    }
}

/// The trivial aligner of §3.4: a random permutation of the pool.
pub struct RandomAligner {
    /// What the rows attach to (decides the output row count).
    pub target: Target,
}

impl Aligner for RandomAligner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn align(
        &self,
        structure: &EdgeList,
        pool: &FeatureTable,
        seed: u64,
    ) -> Result<FeatureTable> {
        let n_targets = match self.target {
            Target::Edges => structure.len(),
            Target::Nodes => structure.spec.n_src as usize,
        };
        random_alignment(pool, n_targets, seed)
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![("target", Json::from(self.target.as_state_str()))]))
    }
}

/// Everything an aligner factory sees at fit time.
pub struct AlignerFitContext<'a> {
    /// Original structure to learn structure↔feature coupling from.
    pub edges: &'a EdgeList,
    /// Original features (one row per edge, or per source node).
    pub features: &'a FeatureTable,
    /// Edge- or node-feature leg.
    pub target: Target,
    /// Backend parameters from the scenario spec / builder.
    pub params: &'a Params,
    /// Typed GBT override (set by the builder); scalar params like
    /// `trees` still apply on top.
    pub gbt: Option<&'a GbtConfig>,
    /// Typed structural-feature override.
    pub struct_feats: Option<&'a StructFeatConfig>,
}

/// Factory signature for registry-registered aligner backends.
pub type AlignerFactory = fn(&AlignerFitContext<'_>) -> Result<Box<dyn Aligner>>;

fn make_learned(ctx: &AlignerFitContext<'_>) -> Result<Box<dyn Aligner>> {
    let mut gbt = ctx.gbt.cloned().unwrap_or_else(GbtConfig::fast);
    gbt.n_trees = ctx.params.usize_or("trees", gbt.n_trees)?.max(1);
    gbt.max_depth = ctx.params.usize_or("depth", gbt.max_depth)?.max(1);
    let feat_cfg = ctx.struct_feats.cloned().unwrap_or_default();
    let mut aligner =
        LearnedAligner::fit(ctx.edges, ctx.features, ctx.target, feat_cfg, &gbt)?;
    aligner.exact_below = ctx.params.usize_or("exact_below", aligner.exact_below)?;
    Ok(Box::new(aligner))
}

fn make_random(ctx: &AlignerFitContext<'_>) -> Result<Box<dyn Aligner>> {
    Ok(Box::new(RandomAligner { target: ctx.target }))
}

/// Register every built-in aligner backend into `reg`.
pub fn register_builtins(reg: &mut Registry<AlignerFactory>) {
    reg.register("learned", make_learned);
    reg.register("random", make_random);
    reg.alias("xgboost", "learned");
    reg.alias("gbt", "learned");
}

/// Loader signature for `.sggm` artifact state: the inverse of
/// [`Aligner::save_state`], keyed by backend name.
pub type AlignerStateLoader = fn(&Json) -> Result<Box<dyn Aligner>>;

fn load_learned(state: &Json) -> Result<Box<dyn Aligner>> {
    Ok(Box::new(LearnedAligner::load_state(state)?))
}

fn load_random(state: &Json) -> Result<Box<dyn Aligner>> {
    Ok(Box::new(RandomAligner {
        target: ranking::Target::from_state_str(state.req_str("target")?)?,
    }))
}

/// Register every built-in aligner state loader. Keys mirror
/// [`register_builtins`], with the extra `xgboost` alias matching the
/// learned aligner's display name (what [`Aligner::name`] writes into an
/// artifact).
pub fn register_state_loaders(reg: &mut Registry<AlignerStateLoader>) {
    reg.register("learned", load_learned);
    reg.register("random", load_random);
    reg.alias("xgboost", "learned");
    reg.alias("gbt", "learned");
}

/// Which aligner a pipeline uses (ablation axis of Table 6). Legacy
/// closed enum — new code names backends by registry string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlignKind {
    /// Learned XGBoost-style aligner ("xgboost").
    Learned,
    /// Random assignment ("random").
    Random,
}

impl AlignKind {
    /// Canonical registry name of this kind.
    pub fn registry_name(&self) -> &'static str {
        match self {
            AlignKind::Learned => "learned",
            AlignKind::Random => "random",
        }
    }
}

impl std::str::FromStr for AlignKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "xgboost" | "learned" | "gbt" => Ok(AlignKind::Learned),
            "random" => Ok(AlignKind::Random),
            other => Err(format!("unknown aligner `{other}`")),
        }
    }
}

/// Randomly permute generated rows onto the structure — the trivial
/// aligner of §3.4 and the "random" arm of Table 6.
pub fn random_alignment(
    generated: &FeatureTable,
    n_targets: usize,
    seed: u64,
) -> Result<FeatureTable> {
    let n = generated.n_rows();
    let mut rng = Pcg64::new(seed);
    let perm: Vec<usize> = (0..n_targets)
        .map(|i| if n == 0 { 0 } else if i < n { i } else { rng.below_usize(n) })
        .collect();
    let mut shuffled = perm;
    rng.shuffle(&mut shuffled);
    Ok(generated.gather(&shuffled))
}

/// Convenience: structural features with the paper's default set
/// (degrees, PageRank, Katz — Table 9's best combination).
pub fn default_struct_features(edges: &EdgeList) -> StructFeatures {
    structfeat::compute(edges, &StructFeatConfig::default())
}
