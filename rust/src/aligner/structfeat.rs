//! Per-node structural features F_S : V → ℝ^d (paper §3.4 / Appendix 7):
//! in/out/total degree, PageRank, Katz centrality, local clustering
//! coefficient, and optionally node2vec embeddings (Table 9 ablation).

use super::node2vec::{node2vec_embeddings, Node2VecConfig};
use crate::graph::{Csr, EdgeList};
use crate::util::json::Json;
use crate::Result;

/// Which structural features to extract (Table 9's rows toggle these).
#[derive(Clone, Debug)]
pub struct StructFeatConfig {
    /// In/out degree columns.
    pub degrees: bool,
    /// PageRank column.
    pub pagerank: bool,
    /// Katz centrality column.
    pub katz: bool,
    /// Local clustering-coefficient column.
    pub clustering: bool,
    /// Optional node2vec embedding columns.
    pub node2vec: Option<Node2VecConfig>,
    /// PageRank/Katz iteration count.
    pub iterations: usize,
}

impl Default for StructFeatConfig {
    fn default() -> Self {
        // the paper's best combination in Table 9: degrees+pagerank+katz
        StructFeatConfig {
            degrees: true,
            pagerank: true,
            katz: true,
            clustering: false,
            node2vec: None,
            iterations: 20,
        }
    }
}

impl StructFeatConfig {
    /// Serialize for a `.sggm` model artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("degrees", Json::from(self.degrees)),
            ("pagerank", Json::from(self.pagerank)),
            ("katz", Json::from(self.katz)),
            ("clustering", Json::from(self.clustering)),
            (
                "node2vec",
                match &self.node2vec {
                    Some(cfg) => cfg.to_json(),
                    None => Json::Null,
                },
            ),
            ("iterations", Json::from(self.iterations)),
        ])
    }

    /// Inverse of [`StructFeatConfig::to_json`].
    pub fn from_json(v: &Json) -> Result<StructFeatConfig> {
        Ok(StructFeatConfig {
            degrees: v.req_bool("degrees")?,
            pagerank: v.req_bool("pagerank")?,
            katz: v.req_bool("katz")?,
            clustering: v.req_bool("clustering")?,
            node2vec: match v.opt("node2vec") {
                Some(cfg) => Some(Node2VecConfig::from_json(cfg)?),
                None => None,
            },
            iterations: v.req_usize("iterations")?,
        })
    }
}

/// Node-major structural feature matrix over the *global* node id space.
#[derive(Clone, Debug)]
pub struct StructFeatures {
    /// Row-major `n_nodes × dim` matrix.
    pub data: Vec<f64>,
    /// Number of rows (global node count).
    pub n_nodes: usize,
    /// Number of feature columns.
    pub dim: usize,
    /// Column labels.
    pub names: Vec<String>,
}

impl StructFeatures {
    /// Feature row of node `v`.
    pub fn row(&self, v: u64) -> &[f64] {
        &self.data[v as usize * self.dim..(v as usize + 1) * self.dim]
    }
}

/// PageRank with damping 0.85 on the undirected view.
pub fn pagerank(csr: &Csr, iters: usize) -> Vec<f64> {
    let n = csr.n_nodes as usize;
    if n == 0 {
        return Vec::new();
    }
    let damping = 0.85;
    let mut rank = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..iters {
        for x in next.iter_mut() {
            *x = (1.0 - damping) / n as f64;
        }
        let mut dangling = 0.0;
        for v in 0..n {
            let deg = csr.degree(v as u64);
            if deg == 0 {
                dangling += rank[v];
                continue;
            }
            let share = damping * rank[v] / deg as f64;
            for &w in csr.neighbors(v as u64) {
                next[w as usize] += share;
            }
        }
        let dangling_share = damping * dangling / n as f64;
        for x in next.iter_mut() {
            *x += dangling_share;
        }
        std::mem::swap(&mut rank, &mut next);
    }
    rank
}

/// Katz centrality: x = Σ_k α^k A^k 1, computed iteratively with
/// α < 1/λ_max approximated via max degree.
pub fn katz(csr: &Csr, iters: usize) -> Vec<f64> {
    let n = csr.n_nodes as usize;
    if n == 0 {
        return Vec::new();
    }
    let max_deg = (0..n).map(|v| csr.degree(v as u64)).max().unwrap_or(1).max(1);
    let alpha = 0.5 / max_deg as f64;
    let mut x = vec![1.0f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..iters {
        for xi in next.iter_mut() {
            *xi = 1.0;
        }
        for v in 0..n {
            for &w in csr.neighbors(v as u64) {
                next[v] += alpha * x[w as usize];
            }
        }
        std::mem::swap(&mut x, &mut next);
    }
    x
}

/// Local clustering coefficient per node (undirected view).
pub fn clustering_coefficient(csr: &Csr) -> Vec<f64> {
    let n = csr.n_nodes as usize;
    let mut cc = vec![0.0f64; n];
    for v in 0..n {
        let nbrs = csr.neighbors(v as u64);
        let k = nbrs.len();
        if k < 2 {
            continue;
        }
        let mut links = 0usize;
        for (i, &a) in nbrs.iter().enumerate() {
            if a == v as u64 {
                continue;
            }
            for &b in &nbrs[i + 1..] {
                if b == a || b == v as u64 {
                    continue;
                }
                if csr.has_edge(a, b) {
                    links += 1;
                }
            }
        }
        cc[v] = 2.0 * links as f64 / (k * (k - 1)) as f64;
    }
    cc
}

/// Compute the configured features over the global node space.
pub fn compute(edges: &EdgeList, cfg: &StructFeatConfig) -> StructFeatures {
    let csr = Csr::undirected(edges);
    let n = csr.n_nodes as usize;
    let mut cols: Vec<(String, Vec<f64>)> = Vec::new();
    if cfg.degrees {
        cols.push(("degree".into(), csr.degrees_f64()));
        // log-degree stabilizes GBT splits over power-law degrees
        cols.push((
            "log_degree".into(),
            (0..n).map(|v| ((csr.degree(v as u64) + 1) as f64).ln()).collect(),
        ));
    }
    if cfg.pagerank {
        cols.push(("pagerank".into(), pagerank(&csr, cfg.iterations)));
    }
    if cfg.katz {
        cols.push(("katz".into(), katz(&csr, cfg.iterations)));
    }
    if cfg.clustering {
        cols.push(("clustering".into(), clustering_coefficient(&csr)));
    }
    if let Some(n2v) = &cfg.node2vec {
        let emb = node2vec_embeddings(&csr, n2v);
        for d in 0..n2v.dim {
            cols.push((
                format!("n2v_{d}"),
                (0..n).map(|v| emb[v * n2v.dim + d] as f64).collect(),
            ));
        }
    }
    let dim = cols.len();
    let mut data = vec![0.0f64; n * dim];
    for (j, (_, col)) in cols.iter().enumerate() {
        for (i, &x) in col.iter().enumerate() {
            data[i * dim + j] = x;
        }
    }
    StructFeatures {
        data,
        n_nodes: n,
        dim,
        names: cols.into_iter().map(|(n, _)| n).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;

    fn star() -> EdgeList {
        // hub 0 connected to 1..=4
        EdgeList::from_pairs(
            PartiteSpec::square(5),
            &[(0, 1), (0, 2), (0, 3), (0, 4)],
        )
    }

    #[test]
    fn pagerank_hub_highest() {
        let csr = Csr::undirected(&star());
        let pr = pagerank(&csr, 30);
        assert!((pr.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for v in 1..5 {
            assert!(pr[0] > pr[v]);
        }
    }

    #[test]
    fn katz_hub_highest() {
        let csr = Csr::undirected(&star());
        let k = katz(&csr, 30);
        for v in 1..5 {
            assert!(k[0] > k[v]);
        }
    }

    #[test]
    fn clustering_triangle() {
        let e = EdgeList::from_pairs(PartiteSpec::square(4), &[(0, 1), (1, 2), (2, 0), (0, 3)]);
        let csr = Csr::undirected(&e);
        let cc = clustering_coefficient(&csr);
        // node 1 and 2 have cc=1 (their 2 neighbors are connected)
        assert!((cc[1] - 1.0).abs() < 1e-12);
        assert!((cc[2] - 1.0).abs() < 1e-12);
        // node 0 has 3 neighbors {1,2,3}, one link (1-2): cc = 1/3
        assert!((cc[0] - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(cc[3], 0.0);
    }

    #[test]
    fn compute_shapes_and_names() {
        let f = compute(&star(), &StructFeatConfig::default());
        assert_eq!(f.n_nodes, 5);
        assert_eq!(f.dim, 4); // degree, log_degree, pagerank, katz
        assert_eq!(f.names, vec!["degree", "log_degree", "pagerank", "katz"]);
        assert_eq!(f.row(0)[0], 4.0);
        assert_eq!(f.row(1)[0], 1.0);
    }

    #[test]
    fn clustering_flag_adds_column() {
        let cfg = StructFeatConfig { clustering: true, ..Default::default() };
        let f = compute(&star(), &cfg);
        assert_eq!(f.dim, 5);
    }
}
