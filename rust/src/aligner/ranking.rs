//! The learned aligner: per-column GBT prediction + similarity ranking
//! (paper eq. 15–19, Appendix 7).
//!
//! Training pairs each original edge's (F_S(src), F_S(dst)) — or node's
//! F_S(v) — with its observed features; one GBT model per feature column.
//! At generation time the models predict expected features for every
//! generated edge/node; generated feature rows are then assigned by
//! similarity ranking: continuous columns by negative squared error
//! (eq. 18), categorical by cosine over the class scores (eq. 19).
//!
//! Exact greedy argmax assignment is O(n²); for large n we use the
//! rank-matching optimization: both predictions and generated rows are
//! sorted by a shared scalar score and matched by rank, which preserves
//! the joint (degree, feature) distribution the paper's
//! Degree-Feat-Dist-Dist metric measures. `exact_below` controls the
//! crossover.

use super::gbt::{GbtClassifier, GbtConfig, GbtRegressor};
use super::structfeat::{compute, StructFeatConfig, StructFeatures};
use crate::featgen::table::{Column, ColumnData, FeatureTable};
use crate::graph::EdgeList;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// One model per feature column.
enum ColModel {
    Continuous { name: String, model: GbtRegressor },
    Categorical { name: String, model: GbtClassifier, cardinality: u32 },
}

/// What the aligner's targets are attached to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    /// Edge features: inputs are concat(F_S(src), F_S(dst)).
    Edges,
    /// Node features over source-partite nodes: inputs are F_S(v).
    Nodes,
}

impl Target {
    /// Artifact encoding (`"edges"` / `"nodes"`).
    pub fn as_state_str(&self) -> &'static str {
        match self {
            Target::Edges => "edges",
            Target::Nodes => "nodes",
        }
    }

    /// Inverse of [`Target::as_state_str`].
    pub fn from_state_str(s: &str) -> Result<Target> {
        match s {
            "edges" => Ok(Target::Edges),
            "nodes" => Ok(Target::Nodes),
            other => Err(Error::Data(format!("artifact: unknown aligner target `{other}`"))),
        }
    }
}

/// Fitted learned aligner.
pub struct LearnedAligner {
    models: Vec<ColModel>,
    feat_cfg: StructFeatConfig,
    target: Target,
    /// Use exact O(n²) greedy assignment below this many rows.
    pub exact_below: usize,
}

impl LearnedAligner {
    /// Train on the original graph + its features.
    ///
    /// For `Target::Edges`, `features` must have one row per edge of
    /// `original`; for `Target::Nodes`, one row per source-partite node.
    pub fn fit(
        original: &EdgeList,
        features: &FeatureTable,
        target: Target,
        feat_cfg: StructFeatConfig,
        gbt_cfg: &GbtConfig,
    ) -> Result<LearnedAligner> {
        let sf = compute(original, &feat_cfg);
        let x = build_inputs(original, &sf, target);
        let n_cols = input_dim(&sf, target);
        let n_rows = features.n_rows();
        let expected = match target {
            Target::Edges => original.len(),
            Target::Nodes => original.spec.n_src as usize,
        };
        if n_rows != expected {
            return Err(crate::Error::Data(format!(
                "aligner fit: features have {n_rows} rows, expected {expected}"
            )));
        }
        let models = features
            .columns
            .iter()
            .map(|c| match &c.data {
                ColumnData::Continuous(v) => ColModel::Continuous {
                    name: c.name.clone(),
                    model: GbtRegressor::fit(&x, v, n_cols, gbt_cfg),
                },
                ColumnData::Categorical { codes, cardinality } => ColModel::Categorical {
                    name: c.name.clone(),
                    model: GbtClassifier::fit(&x, codes, n_cols, *cardinality, gbt_cfg),
                    cardinality: *cardinality,
                },
            })
            .collect();
        Ok(LearnedAligner { models, feat_cfg, target, exact_below: 2048 })
    }

    /// Serialize the fitted aligner (per-column GBT models + structural
    /// feature config) for a `.sggm` model artifact.
    pub fn save_state(&self) -> Result<Json> {
        let models = self
            .models
            .iter()
            .map(|m| match m {
                ColModel::Continuous { name, model } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("continuous")),
                    ("model", model.to_json()),
                ]),
                ColModel::Categorical { name, model, cardinality } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("categorical")),
                    ("cardinality", Json::from(*cardinality)),
                    ("model", model.to_json()),
                ]),
            })
            .collect();
        Ok(Json::obj(vec![
            ("models", Json::Arr(models)),
            ("struct_feats", self.feat_cfg.to_json()),
            ("target", Json::from(self.target.as_state_str())),
            ("exact_below", Json::from(self.exact_below)),
        ]))
    }

    /// Inverse of [`LearnedAligner::save_state`] — the loaded aligner's
    /// predictions and rank assignments are bit-identical to the fitted
    /// one's for every seed.
    pub fn load_state(state: &Json) -> Result<LearnedAligner> {
        let models = state
            .req_arr("models")?
            .iter()
            .map(|m| {
                let name = m.req_str("name")?.to_string();
                match m.req_str("kind")? {
                    "continuous" => Ok(ColModel::Continuous {
                        name,
                        model: GbtRegressor::from_json(m.req("model")?)?,
                    }),
                    "categorical" => Ok(ColModel::Categorical {
                        name,
                        model: GbtClassifier::from_json(m.req("model")?)?,
                        cardinality: m.req_u32("cardinality")?,
                    }),
                    other => Err(Error::Data(format!(
                        "artifact: unknown aligner column kind `{other}`"
                    ))),
                }
            })
            .collect::<Result<Vec<ColModel>>>()?;
        Ok(LearnedAligner {
            models,
            feat_cfg: StructFeatConfig::from_json(state.req("struct_feats")?)?,
            target: Target::from_state_str(state.req_str("target")?)?,
            exact_below: state.req_usize("exact_below")?,
        })
    }

    /// Align `generated_features` onto `generated_structure`: returns a
    /// table with one row per edge (or per source node), drawn from the
    /// generated rows.
    pub fn align(
        &self,
        generated_structure: &EdgeList,
        generated_features: &FeatureTable,
        seed: u64,
    ) -> Result<FeatureTable> {
        let sf = compute(generated_structure, &self.feat_cfg);
        let x = build_inputs(generated_structure, &sf, self.target);
        let n_targets = match self.target {
            Target::Edges => generated_structure.len(),
            Target::Nodes => generated_structure.spec.n_src as usize,
        };
        let n_gen = generated_features.n_rows();
        if n_gen == 0 {
            return Err(crate::Error::Data("no generated feature rows".into()));
        }

        // predicted feature matrix (continuous cols predicted directly;
        // categorical cols contribute their argmax class for the scoring
        // key and class scores for exact similarity)
        let mut pred_cont: Vec<(usize, Vec<f64>)> = Vec::new(); // col idx -> predictions
        let mut pred_cat: Vec<(usize, Vec<f64>, u32)> = Vec::new(); // col idx -> scores, k
        for (ci, m) in self.models.iter().enumerate() {
            match m {
                ColModel::Continuous { model, .. } => {
                    pred_cont.push((ci, model.predict(&x, n_targets)));
                }
                ColModel::Categorical { model, cardinality, .. } => {
                    pred_cat.push((ci, model.predict_scores(&x, n_targets), *cardinality));
                }
            }
        }

        let assignment = if n_targets.max(n_gen) <= self.exact_below {
            self.assign_exact(&pred_cont, &pred_cat, generated_features, n_targets, seed)
        } else {
            self.assign_by_rank(&pred_cont, &pred_cat, generated_features, n_targets, seed)
        };
        Ok(generated_features.gather(&assignment))
    }

    /// Exact greedy: per target, pick the most similar generated row
    /// (eq. 17); rows may be reused (generated set is a pool).
    fn assign_exact(
        &self,
        pred_cont: &[(usize, Vec<f64>)],
        pred_cat: &[(usize, Vec<f64>, u32)],
        generated: &FeatureTable,
        n_targets: usize,
        seed: u64,
    ) -> Vec<usize> {
        let n_gen = generated.n_rows();
        let mut rng = Pcg64::new(seed);
        // column stds for scale-free MSE
        let stds: Vec<f64> = pred_cont
            .iter()
            .map(|(ci, _)| match &generated.columns[*ci].data {
                ColumnData::Continuous(v) => crate::util::stats::std_dev(v).max(1e-9),
                _ => 1.0,
            })
            .collect();
        let mut out = Vec::with_capacity(n_targets);
        for t in 0..n_targets {
            let mut best = 0usize;
            let mut best_sim = f64::NEG_INFINITY;
            let mut ties = 1u64;
            for g in 0..n_gen {
                // eq. 18: -Σ (pred - x)^2 (standardized)
                let mut sim = 0.0;
                for (k, (ci, preds)) in pred_cont.iter().enumerate() {
                    if let ColumnData::Continuous(v) = &generated.columns[*ci].data {
                        let d = (preds[t] - v[g]) / stds[k];
                        sim -= d * d;
                    }
                }
                // eq. 19: cosine between class-score vector and one-hot
                for (ci, scores, kk) in pred_cat.iter() {
                    if let ColumnData::Categorical { codes, .. } = &generated.columns[*ci].data {
                        let k = *kk as usize;
                        let row = &scores[t * k..(t + 1) * k];
                        let norm: f64 = row.iter().map(|s| s * s).sum::<f64>().sqrt().max(1e-12);
                        sim += row[codes[g] as usize % k] / norm;
                    }
                }
                if sim > best_sim {
                    best_sim = sim;
                    best = g;
                    ties = 1;
                } else if sim == best_sim {
                    // reservoir tie-break (paper: "ties are assigned randomly")
                    ties += 1;
                    if rng.below(ties) == 0 {
                        best = g;
                    }
                }
            }
            out.push(best);
        }
        out
    }

    /// Rank matching: sort targets by predicted scalar key and generated
    /// rows by their own key; match by rank (pool wraps if sizes differ).
    fn assign_by_rank(
        &self,
        pred_cont: &[(usize, Vec<f64>)],
        pred_cat: &[(usize, Vec<f64>, u32)],
        generated: &FeatureTable,
        n_targets: usize,
        _seed: u64,
    ) -> Vec<usize> {
        let n_gen = generated.n_rows();
        // scalar key: standardized sum of continuous predictions (+ class
        // index as a weak key for categorical-only tables)
        let key_t: Vec<f64> = (0..n_targets)
            .map(|t| {
                let mut k = 0.0;
                for (ci, preds) in pred_cont {
                    let _ = ci;
                    k += preds[t];
                }
                for (_, scores, kk) in pred_cat {
                    let kkk = *kk as usize;
                    let row = &scores[t * kkk..(t + 1) * kkk];
                    let argmax = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    k += argmax as f64 * 1e-3;
                }
                k
            })
            .collect();
        let key_g: Vec<f64> = (0..n_gen)
            .map(|g| {
                let mut k = 0.0;
                for c in &generated.columns {
                    match &c.data {
                        ColumnData::Continuous(v) => k += v[g],
                        ColumnData::Categorical { codes, .. } => k += codes[g] as f64 * 1e-3,
                    }
                }
                k
            })
            .collect();
        let mut t_order: Vec<usize> = (0..n_targets).collect();
        t_order.sort_by(|&a, &b| key_t[a].partial_cmp(&key_t[b]).unwrap());
        let mut g_order: Vec<usize> = (0..n_gen).collect();
        g_order.sort_by(|&a, &b| key_g[a].partial_cmp(&key_g[b]).unwrap());
        let mut out = vec![0usize; n_targets];
        for (rank, &t) in t_order.iter().enumerate() {
            // map target rank onto generated rank (proportional stretch)
            let gr = rank * n_gen / n_targets.max(1);
            out[t] = g_order[gr.min(n_gen - 1)];
        }
        out
    }
}

fn input_dim(sf: &StructFeatures, target: Target) -> usize {
    match target {
        Target::Edges => 2 * sf.dim,
        Target::Nodes => sf.dim,
    }
}

/// Build the GBT input matrix: per edge concat(F_S(src), F_S(dst)), or
/// per source node F_S(v).
fn build_inputs(edges: &EdgeList, sf: &StructFeatures, target: Target) -> Vec<f64> {
    match target {
        Target::Edges => {
            let d = sf.dim;
            let mut x = Vec::with_capacity(edges.len() * 2 * d);
            for (s, t) in edges.iter() {
                x.extend_from_slice(sf.row(edges.spec.src_global(s)));
                x.extend_from_slice(sf.row(edges.spec.dst_global(t)));
            }
            x
        }
        Target::Nodes => {
            let d = sf.dim;
            let mut x = Vec::with_capacity(edges.spec.n_src as usize * d);
            for v in 0..edges.spec.n_src {
                x.extend_from_slice(sf.row(edges.spec.src_global(v)));
            }
            x
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PartiteSpec;
    use crate::structgen::kronecker::KroneckerGen;
    use crate::structgen::theta::ThetaS;
    use crate::structgen::StructureGenerator;

    /// Graph whose edge feature is strongly correlated with src degree.
    fn correlated_dataset() -> (EdgeList, FeatureTable) {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(256), 4_000);
        let edges = g.generate(1, 3).unwrap();
        let deg = edges.out_degrees();
        let mut rng = Pcg64::new(7);
        let vals: Vec<f64> = edges
            .iter()
            .map(|(s, _)| (deg[s as usize] as f64).ln() + rng.normal() * 0.1)
            .collect();
        let cat: Vec<u32> = edges
            .iter()
            .map(|(s, _)| if deg[s as usize] > 30 { 1 } else { 0 })
            .collect();
        let t = FeatureTable::new(vec![
            Column::continuous("logdeg_feat", vals),
            Column::categorical("hub", cat),
        ])
        .unwrap();
        (edges, t)
    }

    #[test]
    fn learned_aligner_preserves_degree_feature_correlation() {
        let (edges, feats) = correlated_dataset();
        let aligner = LearnedAligner::fit(
            &edges,
            &feats,
            Target::Edges,
            StructFeatConfig::default(),
            &GbtConfig::fast(),
        )
        .unwrap();
        // generate a same-size structure, align the *same* feature pool
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(256), 4_000);
        let synth = g.generate(1, 11).unwrap();
        let aligned = aligner.align(&synth, &feats, 1).unwrap();
        assert_eq!(aligned.n_rows(), synth.len());
        // correlation between src degree and aligned feature should be
        // strongly positive, as in the original
        let deg = synth.out_degrees();
        let xs: Vec<f64> = synth.iter().map(|(s, _)| (deg[s as usize] as f64).ln()).collect();
        let ys = aligned.column("logdeg_feat").unwrap().as_continuous();
        let corr = crate::util::stats::pearson(&xs, ys);
        assert!(corr > 0.6, "corr={corr}");
    }

    #[test]
    fn random_alignment_destroys_correlation() {
        let (edges, feats) = correlated_dataset();
        let aligned = super::super::random_alignment(&feats, edges.len(), 5).unwrap();
        let deg = edges.out_degrees();
        let xs: Vec<f64> = edges.iter().map(|(s, _)| (deg[s as usize] as f64).ln()).collect();
        let ys = aligned.column("logdeg_feat").unwrap().as_continuous();
        let corr = crate::util::stats::pearson(&xs, ys).abs();
        assert!(corr < 0.2, "corr={corr}");
    }

    #[test]
    fn rank_matching_agrees_with_exact_on_correlation() {
        let (edges, feats) = correlated_dataset();
        let mut aligner = LearnedAligner::fit(
            &edges,
            &feats,
            Target::Edges,
            StructFeatConfig::default(),
            &GbtConfig::fast(),
        )
        .unwrap();
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(256), 4_000);
        let synth = g.generate(1, 13).unwrap();
        let deg = synth.out_degrees();
        let xs: Vec<f64> = synth.iter().map(|(s, _)| (deg[s as usize] as f64).ln()).collect();

        aligner.exact_below = usize::MAX; // force exact
        let exact = aligner.align(&synth, &feats, 1).unwrap();
        let c_exact = crate::util::stats::pearson(
            &xs,
            exact.column("logdeg_feat").unwrap().as_continuous(),
        );
        aligner.exact_below = 0; // force rank matching
        let ranked = aligner.align(&synth, &feats, 1).unwrap();
        let c_rank = crate::util::stats::pearson(
            &xs,
            ranked.column("logdeg_feat").unwrap().as_continuous(),
        );
        assert!(c_exact > 0.5, "exact={c_exact}");
        assert!(c_rank > 0.5, "rank={c_rank}");
        assert!((c_exact - c_rank).abs() < 0.3, "exact={c_exact} rank={c_rank}");
    }

    #[test]
    fn node_target_alignment() {
        let g = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(128), 2_000);
        let edges = g.generate(1, 2).unwrap();
        let deg = edges.out_degrees();
        let vals: Vec<f64> = deg.iter().map(|&d| d as f64 * 2.0 + 1.0).collect();
        let feats = FeatureTable::new(vec![Column::continuous("f", vals)]).unwrap();
        let aligner = LearnedAligner::fit(
            &edges,
            &feats,
            Target::Nodes,
            StructFeatConfig::default(),
            &GbtConfig::fast(),
        )
        .unwrap();
        let synth = g.generate(1, 4).unwrap();
        let aligned = aligner.align(&synth, &feats, 3).unwrap();
        assert_eq!(aligned.n_rows(), 128);
        let sdeg: Vec<f64> = synth.out_degrees().iter().map(|&d| d as f64).collect();
        let corr = crate::util::stats::pearson(&sdeg, aligned.column("f").unwrap().as_continuous());
        assert!(corr > 0.7, "corr={corr}");
    }

    #[test]
    fn fit_rejects_row_mismatch() {
        let (edges, feats) = correlated_dataset();
        let bad = feats.gather(&[0, 1, 2]); // wrong row count
        let r = LearnedAligner::fit(
            &edges,
            &bad,
            Target::Edges,
            StructFeatConfig::default(),
            &GbtConfig::fast(),
        );
        assert!(r.is_err());
    }
}
